"""Shared benchmark plumbing: fake-device meshes, result records, tables."""
from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def ensure_devices(n: int = 8):
    """Must be called before jax import wherever multi-device CPU is needed."""
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"


def save_result(name: str, record: Dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(record, f, indent=1, default=str)


def table(rows: List[List], headers: List[str]) -> str:
    cols = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in cols) for i in range(len(headers))]
    def fmt(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    out = [fmt(headers), fmt(["-" * w for w in widths])]
    out += [fmt(r) for r in rows]
    return "\n".join(out)


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PiB"


def fmt_bw(b: float) -> str:
    for unit in ("B/s", "KB/s", "MB/s", "GB/s", "TB/s"):
        if abs(b) < 1000:
            return f"{b:.2f}{unit}"
        b /= 1000
    return f"{b:.2f}PB/s"
