"""Paper Fig. 13 — HPL performance vs matrix size on a single device, two
block sizes (the paper sweeps block 512 vs 256), plus both distributed
backends at a fixed size for the communication-overlap comparison."""
from __future__ import annotations

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.core.hpl import run_hpl  # noqa: E402
from repro.core.hpl_blocked import run_hpl_single  # noqa: E402
from repro.launch.mesh import make_torus_mesh  # noqa: E402


def main(quick: bool = False, schedule=None):
    sizes = [128, 256, 384] if quick else [128, 256, 384, 512, 768]
    blocks = [32, 64]

    print("== HPL matrix-size sweep, single device (paper Fig. 13) ==")
    rows = []
    record = {"single": {}}
    curve = {}
    for b in blocks:
        for n in sizes:
            if n % b:
                continue
            res = run_hpl_single(n=n, b=b, reps=2)
            rows.append([n, b, f"{res.metric:.3f}", f"{res.error:.2e}",
                         f"{res.times['best']*1e3:.1f}ms"])
            record["single"][f"n{n}_b{b}"] = {
                "gflops": res.metric, "err": res.error}
            if b == 64:
                curve[n] = res.metric
    print(table(rows, ["n", "block", "GFLOP/s", "resid", "time"]))

    print("\n== HPL distributed 2x2 torus, both backends (Fig. 13 PCIe vs IEC) ==")
    n_dev = len(jax.devices())
    if n_dev >= 4:
        mesh = make_torus_mesh(2)
        n = 256 if quick else 512
        rows = []
        if schedule:  # one engine schedule suite-wide (--schedule NAME)
            cells = [(CT.ICI_DIRECT, schedule), (CT.HOST_STAGED, schedule)]
        else:
            cells = [(CT.ICI_DIRECT, "chain"), (CT.ICI_DIRECT, "native"),
                     (CT.ICI_DIRECT, "ring2d"), (CT.HOST_STAGED, "staged")]
        for ct, sched in cells:
            res = run_hpl(mesh, ct, n=n, b=64, schedule=sched, reps=1)
            used = res.details["schedule"]
            rows.append([ct.value, used, n, f"{res.metric:.3f}",
                         f"{res.error:.2e}"])
            record[f"dist/{ct.value}/{used}"] = {"gflops": res.metric,
                                                 "err": res.error,
                                                 "schedule": used}
        print(table(rows, ["backend", "schedule", "n", "GFLOP/s", "resid"]))

    record["single_curve_b64"] = curve
    save_result("hpl_matrix_sweep", record)
    return record


if __name__ == "__main__":
    main()
