"""Beyond-paper — LM train/serve step timings (reduced configs, measured on
CPU for regression) + the production-mesh roofline summary per assigned
architecture (read from the dry-run results), plus two explicit-vs-GSPMD
comparisons on the simulated multi-device mesh:

* the qwen3-moe expert *layer* once through GSPMD ``apply_moe`` and once
  through the engine-routed ``apply_moe_explicit``;
* the *whole model* (tiny qwen3-moe) trained one step through
  ``make_whole_model_train_step_explicit`` in both attention modes (``tp``
  head-parallel, ``sp`` ring) against the GSPMD ``make_train_step`` on the
  same mesh — loss / grad-norm / updated-param parity recorded.

Both record every per-callsite resolved schedule (``moe.dispatch`` /
``moe.combine`` / ``tp.qkv`` / ``sp.kv`` / ``dp.grads`` / ...) — never the
literal ``"auto"``. The module fails with SystemExit(1) if any resolution
names an unregistered schedule (the same gate ``--autotune`` applies)."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import RunConfig, get_config, list_archs, reduced  # noqa: E402
from repro.configs.qwen3_moe_235b_a22b import tiny  # noqa: E402
from repro.data import DataConfig, SyntheticLMDataset  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.train.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.step import init_train_state, make_train_step  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

MOE_ARCH = "qwen3-moe-235b-a22b"


def _moe_explicit_section(quick: bool, schedule):
    """Explicit-vs-GSPMD MoE through the collective engine.

    Runs the reduced qwen3-moe expert layer twice on the live mesh — the
    GSPMD ``apply_moe`` with a batch-sharded input, and the engine-routed
    ``apply_moe_explicit`` (dispatch/combine as tagged ``all_to_all_tiles``,
    pipelined ``nchunks="auto"``) — plus one explicit-DP train step so the
    ``dp.grads`` bucket reduction resolves against real payload sizes.
    Returns the result record with every per-callsite resolved schedule.
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.comm.engine import CollectiveEngine
    from repro.compat import make_mesh
    from repro.core.hpcc import timeit
    from repro.models import moe as MOE
    from repro.train.step import GRADS_CALLSITE, make_dp_train_step_explicit

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"explicit MoE needs >= 2 devices, have {ndev}"}

    requested = schedule or "auto"
    # one expert (shard) per device; capacity generous enough to drop nothing
    cfg = tiny(ndev, layers=1)
    mesh = make_mesh((ndev,), ("x",))
    engine = CollectiveEngine.for_mesh(mesh, schedule=requested)

    B, S, D = ndev, (16 if quick else 32), cfg.d_model
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (B, S, D), jnp.float32)

    # GSPMD path: one jit over the batch-sharded input, XLA schedules the
    # expert resharding itself
    xs = jax.device_put(x, NamedSharding(mesh, P("x", None, None)))
    gspmd = jax.jit(lambda p, x: MOE.apply_moe(p, cfg, x))
    out_g, t_gspmd = timeit(gspmd, p, xs, reps=2)

    # explicit path: engine-routed exchanges, pipelined capacity strips
    explicit = MOE.make_apply_moe_explicit(cfg, mesh, engine=engine,
                                           nchunks="auto")
    out_e, t_explicit = timeit(explicit, p, x, reps=2)
    err = float(np.max(np.abs(np.asarray(out_e, np.float32)
                              - np.asarray(out_g, np.float32))))

    # one explicit-DP step on the same config: the dp.grads bucket payload
    data = SyntheticLMDataset(DataConfig(cfg.vocab_size, B, S))
    batch = {"tokens": jnp.asarray(data.batch(0)["tokens"])}
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(2))
    step = make_dp_train_step_explicit(
        model, RunConfig(learning_rate=1e-3, warmup_steps=1), mesh,
        schedule_kind=requested)
    grad_bytes = 4 * sum(v.size for v in jax.tree.leaves(state.params))
    # the step donates its state: compile with the fresh state, time the
    # second step on the returned one
    state2, _ = jax.block_until_ready(step(state, batch))
    t0 = time.perf_counter()
    _, metrics = jax.block_until_ready(step(state2, batch))
    t_dp = time.perf_counter() - t0

    # per-callsite provenance at the actual payload sizes, matching the
    # hpl/ptrans convention: resolved names recorded, never "auto"
    C = MOE._capacity(cfg, S)
    exchange_bytes = (B // ndev) * cfg.num_experts * C * D * 4
    bucket_bytes = engine.bucket_bytes_for("x")
    # resolve dp.grads at the payloads the bucketed reduction actually runs
    # (greedy leaf packing can leave a small trailing bucket in a different
    # cost band than min(bucket, total) would suggest)
    from repro.comm.overlap import pack_buckets
    leaves = jax.tree.leaves(state.params)
    bucket_payloads = sorted({
        sum(leaves[i].size * 4 for i in b if leaves[i].size)
        for b in pack_buckets(leaves, bucket_bytes)} - {0})
    per_bucket = [engine.schedule_for("allreduce", nbytes=nb, axis="x",
                                      callsite=GRADS_CALLSITE)
                  for nb in bucket_payloads]
    resolved = {
        "moe.dispatch": engine.schedule_for(
            "all_to_all_tiles", nbytes=exchange_bytes, axis="x",
            callsite=MOE.DISPATCH_CALLSITE),
        "moe.combine": engine.schedule_for(
            "all_to_all_tiles", nbytes=exchange_bytes, axis="x",
            callsite=MOE.COMBINE_CALLSITE),
        # headline name: the largest bucket dominates the wire time; the
        # full per-bucket map below captures band-crossing resolutions
        "dp.grads": per_bucket[-1],
    }
    nchunks = engine.pipeline_chunks("all_to_all_tiles",
                                     nbytes=exchange_bytes, axis="x",
                                     callsite=MOE.DISPATCH_CALLSITE)
    return {
        "arch": MOE_ARCH, "devices": ndev,
        "time": t_explicit, "t_explicit_s": t_explicit,
        "t_gspmd_s": t_gspmd, "t_dp_step_s": t_dp,
        "dp_loss": float(metrics["loss"]),
        "max_abs_err_vs_gspmd": err,
        "schedule": resolved["moe.dispatch"],
        "schedule_requested": requested,
        "resolved": resolved, "nchunks": nchunks,
        "dp_grads_bucket_payloads": bucket_payloads,
        "dp_grads_resolved_per_bucket": per_bucket,
        "exchange_bytes": exchange_bytes, "bucket_bytes": bucket_bytes,
        "grad_bytes": grad_bytes,
    }


def _whole_model_section(quick: bool, schedule):
    """Whole-model explicit-vs-GSPMD: tiny qwen3-moe, one train step.

    The explicit step (:func:`make_whole_model_train_step_explicit`) runs
    the full forward+backward inside one ``shard_map`` — attention
    activations exchanged under ``tp.*`` / ``sp.*`` tags, MoE dispatch/
    combine under ``moe.*``, gradient buckets under ``dp.grads`` — and is
    compared against the GSPMD :func:`make_train_step` on the same mesh
    from identical init: loss, grad norm, and every updated parameter.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.comm.callsites import (MOE_COMBINE, MOE_DISPATCH, SP_KV,
                                      SP_OUT, SP_QKV, TP_OUT, TP_QKV)
    from repro.comm.engine import CollectiveEngine
    from repro.comm.overlap import pack_buckets
    from repro.compat import make_mesh
    from repro.models import moe as MOE
    from repro.models.parallel import ATTN_MODES
    from repro.train.step import (GRADS_CALLSITE,
                                  make_whole_model_train_step_explicit,
                                  whole_model_param_specs)

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped":
                f"whole-model explicit needs >= 2 devices, have {ndev}"}

    requested = schedule or "auto"
    cfg = tiny(ndev, layers=1)
    mesh = make_mesh((ndev,), ("x",))
    engine = CollectiveEngine.for_mesh(mesh, schedule=requested)
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=1)

    B, S = ndev, (16 if quick else 32)
    model = build_model(cfg)
    data = SyntheticLMDataset(DataConfig(cfg.vocab_size, B, S))
    batch = {"tokens": jnp.asarray(data.batch(0)["tokens"])}

    # GSPMD reference on the same ring mesh (pure DP, params replicated)
    state0 = init_train_state(model, jax.random.key(0))
    ref_step = make_train_step(model, run_cfg, mesh, donate=False)
    ref_state, ref_metrics = jax.block_until_ready(ref_step(state0, batch))
    ref_leaves = [np.asarray(v, np.float32)
                  for v in jax.tree.leaves(ref_state.params)]

    modes = {}
    for mode in ATTN_MODES:
        step = make_whole_model_train_step_explicit(
            model, run_cfg, mesh, attn_mode=mode, schedule_kind=requested,
            nchunks="auto")
        st = init_train_state(model, jax.random.key(0))
        new_state, metrics = jax.block_until_ready(step(st, batch))
        # parity against the GSPMD step from identical init (host copies
        # first: the timing step below donates new_state's buffers)
        param_err = max(
            float(np.max(np.abs(np.asarray(a, np.float32) - b)))
            if a.size else 0.0
            for a, b in zip(jax.tree.leaves(new_state.params), ref_leaves))
        loss_err = abs(float(metrics["loss"]) - float(ref_metrics["loss"]))
        gnorm_err = abs(float(metrics["grad_norm"])
                        - float(ref_metrics["grad_norm"]))
        t0 = time.perf_counter()
        jax.block_until_ready(step(new_state, batch))
        t_step = time.perf_counter() - t0
        modes[mode] = {"t_step_s": t_step, "loss": float(metrics["loss"]),
                       "loss_err_vs_gspmd": loss_err,
                       "grad_norm_err_vs_gspmd": gnorm_err,
                       "max_abs_param_err_vs_gspmd": param_err}

    # per-callsite provenance at the actual per-rank payloads — resolved
    # names recorded, never "auto"
    H, KV, hd, D = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                    cfg.d_model)
    C = MOE._capacity(cfg, S)
    attn_bytes = (B // ndev) * S * H * hd * 4   # q/k/v a2a payload
    kv_ring_bytes = B * (S // ndev) * KV * 2 * hd * 4  # concat [k|v] block
    moe_bytes = (B // ndev) * cfg.num_experts * C * D * 4

    def a2a(nbytes, cs):
        return engine.schedule_for("all_to_all_tiles", nbytes=nbytes,
                                   axis="x", callsite=cs)

    resolved = {
        TP_QKV: a2a(attn_bytes, TP_QKV),
        TP_OUT: a2a(attn_bytes, TP_OUT),
        SP_QKV: a2a(attn_bytes, SP_QKV),
        SP_OUT: a2a(attn_bytes, SP_OUT),
        SP_KV: engine.schedule_for("ring_exchange", nbytes=kv_ring_bytes,
                                   axis="x", callsite=SP_KV),
        MOE_DISPATCH: a2a(moe_bytes, MOE_DISPATCH),
        MOE_COMBINE: a2a(moe_bytes, MOE_COMBINE),
    }
    # dp.grads reduces the REPLICATED leaves only (expert shards are
    # complete per-rank and never ride the wire)
    specs = whole_model_param_specs(state0.params)
    s_leaves = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    rep_leaves = [v for v, s in zip(jax.tree.leaves(state0.params), s_leaves)
                  if s == P()]
    bucket_bytes = engine.bucket_bytes_for("x")
    bucket_payloads = sorted({
        sum(rep_leaves[i].size * 4 for i in b if rep_leaves[i].size)
        for b in pack_buckets(rep_leaves, bucket_bytes)} - {0})
    per_bucket = [engine.schedule_for("allreduce", nbytes=nb, axis="x",
                                      callsite=GRADS_CALLSITE)
                  for nb in bucket_payloads]
    resolved[GRADS_CALLSITE] = per_bucket[-1]
    nchunks = engine.pipeline_chunks("all_to_all_tiles", nbytes=moe_bytes,
                                     axis="x", callsite=MOE_DISPATCH)
    return {
        "arch": MOE_ARCH, "devices": ndev,
        "schedule_requested": requested,
        "modes": modes, "resolved": resolved, "nchunks": nchunks,
        "dp_grads_bucket_payloads": bucket_payloads,
        "dp_grads_resolved_per_bucket": per_bucket,
        "attn_exchange_bytes": attn_bytes,
        "kv_ring_bytes": kv_ring_bytes,
        "moe_exchange_bytes": moe_bytes,
    }


# callsite tag -> engine op, for the resolution gate below
_GATE_OPS = {
    "moe.dispatch": "all_to_all_tiles", "moe.combine": "all_to_all_tiles",
    "tp.qkv": "all_to_all_tiles", "tp.out": "all_to_all_tiles",
    "sp.qkv": "all_to_all_tiles", "sp.out": "all_to_all_tiles",
    "sp.kv": "ring_exchange",
    "dp.grads": "allreduce",
}


def _gate_resolved(section) -> None:
    """SystemExit(1) if any explicit-path resolution is unregistered or
    still the literal "auto" — the same gate as ``--autotune``."""
    from repro.comm.engine import schedules_for

    resolved = (section or {}).get("resolved")
    if not resolved:
        return
    checks = list(resolved.items()) + [
        ("dp.grads", n) for n in section.get("dp_grads_resolved_per_bucket", ())]
    bad = [(cs, name) for cs, name in checks
           if name == "auto" or name not in schedules_for(_GATE_OPS[cs])]
    if bad:
        print("UNREGISTERED explicit-path resolutions:", bad)
        raise SystemExit(1)


def main(quick: bool = False, schedule=None):
    # GSPMD-scheduled train/decode steps (XLA picks the collectives);
    # ``schedule`` applies to the explicit-MoE section below
    archs = (["llama3-8b", "mamba2-130m", "qwen3-moe-235b-a22b"]
             if quick else list_archs())
    if schedule not in (None, "auto"):
        # a fixed schedule only affects the explicit-MoE section: skip the
        # schedule-invariant GSPMD arch timings (--sweep-schedules invokes
        # this module once per registered all_to_all_tiles schedule)
        archs = []
    B, S = 4, 64

    if archs:
        print("== LM step bench (reduced configs, CPU wall-time) ==")
    rows = []
    record = {}
    for arch in archs:
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        data = SyntheticLMDataset(DataConfig(cfg.vocab_size, B, S))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((B, cfg.num_patches,
                                               cfg.vision_dim), jnp.float32)
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((B, cfg.audio_ctx, cfg.d_model),
                                        jnp.float32)

        state = init_train_state(model, jax.random.key(0))
        step = make_train_step(
            model, RunConfig(learning_rate=1e-3, warmup_steps=1),
            jax.sharding.Mesh(jax.devices()[:1], ("x",)), donate=False)
        state, _ = step(state, batch)  # compile
        t0 = time.perf_counter()
        state, metrics = jax.block_until_ready(step(state, batch))
        t_train = time.perf_counter() - t0

        cache = model.init_cache(B, S + 8, jnp.float32)
        prefill = make_prefill_step(model)
        decode = make_decode_step(model)
        logits, cache = prefill(state.params, batch, cache)
        dec_extras = {k: v for k, v in batch.items()
                      if k not in ("tokens", "frames")}
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        _, cache = decode(state.params, tok, cache, dec_extras)  # compile
        t0 = time.perf_counter()
        _, cache = jax.block_until_ready(
            decode(state.params, tok, cache, dec_extras))
        t_decode = time.perf_counter() - t0

        rows.append([arch, f"{t_train*1e3:.1f}ms", f"{t_decode*1e3:.2f}ms",
                     f"{float(metrics['loss']):.3f}"])
        record[arch] = {"train_step_s": t_train, "decode_step_s": t_decode}
    if rows:
        print(table(rows, ["arch", "train_step", "decode_step", "loss"]))

    # explicit-vs-GSPMD MoE through the engine (simulated multi-device mesh)
    moe = _moe_explicit_section(quick, schedule)
    record["moe_explicit"] = moe
    if "skipped" in moe:
        print(f"\n-- explicit MoE: {moe['skipped']} --")
    else:
        print("\n-- explicit-vs-GSPMD MoE (engine-routed exchanges) --")
        print(table(
            [[moe["arch"], f"{moe['t_gspmd_s']*1e3:.1f}ms",
              f"{moe['t_explicit_s']*1e3:.1f}ms",
              f"{moe['t_dp_step_s']*1e3:.1f}ms",
              moe["resolved"]["moe.dispatch"],
              moe["resolved"]["moe.combine"],
              moe["resolved"]["dp.grads"], str(moe["nchunks"]),
              f"{moe['max_abs_err_vs_gspmd']:.2e}"]],
            ["arch", "gspmd", "explicit", "dp_step", "dispatch", "combine",
             "dp.grads", "S", "max|err|"]))
    _gate_resolved(moe)

    # whole-model explicit-vs-GSPMD training step (both attention modes)
    whole = _whole_model_section(quick, schedule)
    record["whole_model"] = whole
    if "skipped" in whole:
        print(f"\n-- whole-model explicit: {whole['skipped']} --")
    else:
        print("\n-- whole-model explicit-vs-GSPMD train step --")
        print(table(
            [[mode, f"{m['t_step_s']*1e3:.1f}ms",
              f"{m['loss']:.4f}", f"{m['loss_err_vs_gspmd']:.2e}",
              f"{m['grad_norm_err_vs_gspmd']:.2e}",
              f"{m['max_abs_param_err_vs_gspmd']:.2e}"]
             for mode, m in whole["modes"].items()],
            ["mode", "step", "loss", "|dloss|", "|dgnorm|", "max|dparam|"]))
        print("   resolved: " + " ".join(
            f"{cs}={name}" for cs, name in sorted(whole["resolved"].items())))
    _gate_resolved(whole)

    # production roofline per arch (train_4k, single pod) from the dry-run
    if os.path.isdir(DRYRUN_DIR):
        rows = []
        for arch in archs:
            tag = f"{arch}__train_4k__single.json"
            path = os.path.join(DRYRUN_DIR, tag)
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            bound = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
            rows.append([arch, f"{rec['compute_s']:.3g}",
                         f"{rec['memory_s']:.3g}",
                         f"{rec['collective_s']:.3g}", rec["dominant"],
                         f"{rec['useful_ratio']:.1%}"])
        if rows:
            print("\n-- production mesh (train_4k, 256 chips) roofline --")
            print(table(rows, ["arch", "compute_s", "memory_s", "coll_s",
                               "dominant", "useful"]))
    save_result("lm_step_bench", record)
    return record


if __name__ == "__main__":
    main()
