"""Beyond-paper — LM train/serve step timings (reduced configs, measured on
CPU for regression) + the production-mesh roofline summary per assigned
architecture (read from the dry-run results)."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import RunConfig, get_config, list_archs, reduced  # noqa: E402
from repro.data import DataConfig, SyntheticLMDataset  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.train.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.step import init_train_state, make_train_step  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def main(quick: bool = False, schedule=None):
    # GSPMD-scheduled steps (XLA picks the collectives); ``schedule``
    # accepted for driver uniformity
    archs = (["llama3-8b", "mamba2-130m", "qwen3-moe-235b-a22b"]
             if quick else list_archs())
    B, S = 4, 64

    print("== LM step bench (reduced configs, CPU wall-time) ==")
    rows = []
    record = {}
    for arch in archs:
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        data = SyntheticLMDataset(DataConfig(cfg.vocab_size, B, S))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((B, cfg.num_patches,
                                               cfg.vision_dim), jnp.float32)
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((B, cfg.audio_ctx, cfg.d_model),
                                        jnp.float32)

        state = init_train_state(model, jax.random.key(0))
        step = make_train_step(
            model, RunConfig(learning_rate=1e-3, warmup_steps=1),
            jax.sharding.Mesh(jax.devices()[:1], ("x",)), donate=False)
        state, _ = step(state, batch)  # compile
        t0 = time.perf_counter()
        state, metrics = jax.block_until_ready(step(state, batch))
        t_train = time.perf_counter() - t0

        cache = model.init_cache(B, S + 8, jnp.float32)
        prefill = make_prefill_step(model)
        decode = make_decode_step(model)
        logits, cache = prefill(state.params, batch, cache)
        dec_extras = {k: v for k, v in batch.items()
                      if k not in ("tokens", "frames")}
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        _, cache = decode(state.params, tok, cache, dec_extras)  # compile
        t0 = time.perf_counter()
        _, cache = jax.block_until_ready(
            decode(state.params, tok, cache, dec_extras))
        t_decode = time.perf_counter() - t0

        rows.append([arch, f"{t_train*1e3:.1f}ms", f"{t_decode*1e3:.2f}ms",
                     f"{float(metrics['loss']):.3f}"])
        record[arch] = {"train_step_s": t_train, "decode_step_s": t_decode}
    print(table(rows, ["arch", "train_step", "decode_step", "loss"]))

    # production roofline per arch (train_4k, single pod) from the dry-run
    if os.path.isdir(DRYRUN_DIR):
        rows = []
        for arch in archs:
            tag = f"{arch}__train_4k__single.json"
            path = os.path.join(DRYRUN_DIR, tag)
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            bound = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
            mfu_bound = rec["model_flops"] / 512 / (bound * 197e12 * 256 / 512) \
                if bound else 0
            rows.append([arch, f"{rec['compute_s']:.3g}",
                         f"{rec['memory_s']:.3g}",
                         f"{rec['collective_s']:.3g}", rec["dominant"],
                         f"{rec['useful_ratio']:.1%}"])
        if rows:
            print("\n-- production mesh (train_4k, 256 chips) roofline --")
            print(table(rows, ["arch", "compute_s", "memory_s", "coll_s",
                               "dominant", "useful"]))
    save_result("lm_step_bench", record)
    return record


if __name__ == "__main__":
    main()
