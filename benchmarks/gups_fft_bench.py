"""Distributed GUPS + FFT — the legacy suite's two kernels, engine-routed.

The HPCC adaptation (arXiv:2004.11059) frames RandomAccess and FFT as the
latency- and all-to-all-bandwidth corners of the suite; this module runs
both corners through the :class:`~repro.comm.engine.CollectiveEngine`
(callsite tags ``ra.updates`` / ``fft.transpose``) next to their
zero-communication legacy references from ``legacy_suite``:

* RandomAccess: drop-local reference vs the routed path that forwards
  every update to its owning rank over ``all_to_all_tiles`` — validated by
  exact inverse-sequence restore (``err`` must be exactly 0.0);
* FFT: per-device batched reference vs the pencil-decomposed transform
  whose two global transposes ride the engine — the distributed output is
  bitwise ``jnp.fft.fft`` at the per-rank block shape (the exchanges
  localize full signals before transforming), so ``err`` vs ``np.fft.fft``
  matches the local path's.

Like lm/serve, the module itself exits 1 if either routed section's
resolved schedule is the literal ``"auto"`` or an unregistered name, or if
the correctness gates fail — the same gate as ``--autotune``; CI re-asserts
from the saved record.
"""
from __future__ import annotations

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

from repro.comm.engine import schedules_for  # noqa: E402
from repro.core.fft import run_fft, run_fft_dist  # noqa: E402
from repro.core.randomaccess import (  # noqa: E402
    run_randomaccess, run_randomaccess_dist)
from repro.launch.mesh import make_ring_mesh  # noqa: E402


def main(quick: bool = False, schedule=None):
    mesh = make_ring_mesh()
    n = mesh.devices.size
    sched = schedule or "auto"

    print(f"== distributed GUPS + FFT over {n} devices "
          f"(schedule={sched}) ==")
    record = {"schedule_requested": sched}
    rows = []

    ra_kw = dict(table_log=16 if quick else 20,
                 updates_per_rng=1024 if quick else 4096)
    res = run_randomaccess(mesh, **ra_kw)
    rows.append(["RandomAccess local", "GUPS", f"{res.metric:.4f}",
                 "drop-local", f"{res.error:.2e}"])
    record["randomaccess_local"] = {"gups": res.metric, "err": res.error}

    res = run_randomaccess_dist(mesh, schedule=sched, **ra_kw)
    rows.append(["RandomAccess routed", "GUPS", f"{res.metric:.4f}",
                 res.details["schedule"], f"{res.error:.2e}"])
    record["randomaccess_routed"] = {
        "gups": res.metric, "err": res.error,
        "schedule": res.details["schedule"],
        "nchunks": res.details["nchunks"],
        "exchange_bytes": res.details["exchange_bytes"]}

    fft_kw = dict(log_size=10 if quick else 14,
                  batch_per_device=16 if quick else 64)
    res = run_fft(mesh, **fft_kw)
    rows.append(["FFT local", "GFLOP/s", f"{res.metric:.2f}",
                 "per-device", f"{res.error:.2e}"])
    record["fft_local"] = {"gflops": res.metric, "err": res.error}

    res = run_fft_dist(mesh, schedule=sched, **fft_kw)
    rows.append(["FFT pencil", "GFLOP/s", f"{res.metric:.2f}",
                 res.details["schedule"], f"{res.error:.2e}"])
    record["fft_dist"] = {
        "gflops": res.metric, "err": res.error,
        "schedule": res.details["schedule"],
        "nchunks": res.details["nchunks"],
        "exchange_bytes": res.details["exchange_bytes"]}

    print(table(rows, ["benchmark", "metric", "aggregate", "schedule",
                       "error"]))
    save_result("gups_fft_bench", record)

    # the --autotune gate, in-module: resolved schedules must be registered
    # names (never the literal "auto") and the correctness invariants must
    # hold — routed GUPS restores exactly, pencil FFT matches the reference
    a2a = schedules_for("all_to_all_tiles")
    bad = []
    for sec in ("randomaccess_routed", "fft_dist"):
        name = record[sec]["schedule"]
        if name == "auto" or name not in a2a:
            bad.append(f"{sec}: unregistered schedule {name!r}")
    if record["randomaccess_routed"]["err"] != 0.0:
        bad.append("randomaccess_routed: inverse restore not exact "
                   f"(err={record['randomaccess_routed']['err']})")
    if not record["fft_dist"]["err"] < 1e-5:
        bad.append(f"fft_dist: err={record['fft_dist']['err']} vs np.fft")
    if bad:
        print("GATE FAILURES:", bad)
        raise SystemExit(1)
    print("[gups_fft ok: resolved schedules registered, restore exact, "
          "fft matches reference]")
    return record


if __name__ == "__main__":
    main()
