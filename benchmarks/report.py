"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun."""
from __future__ import annotations

import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def fmt(v, pat="{:.3g}"):
    return pat.format(v)


def main(d=DRYRUN_DIR):
    recs = []
    skips = []
    for fn in sorted(os.listdir(d)):
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        (skips if r.get("status") == "skipped" else recs).append(r)
    recs = [r for r in recs if r.get("status") == "ok"]

    print("| arch | shape | mesh | FLOPs/dev | HBM B/dev | wire B/dev | "
          "compute_s | memory_s | coll_s | dominant | useful | rf |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ideal = r["model_flops"] / r["chips"] / 197e12
        rf = ideal / step if step else 0.0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt(r['flops_per_device'])} "
              f"| {fmt(r['hbm_bytes_per_device'])} "
              f"| {fmt(r['collective_wire_bytes'])} "
              f"| {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
              f"| {fmt(r['collective_s'])} | {r['dominant']} "
              f"| {r['useful_ratio']:.1%} | {rf:.3f} |")
    print(f"\n{len(recs)} cells compiled ok; {len(skips)} documented skips "
          "(long_500k on pure full-attention archs).")
    # fitting summary
    over = [r for r in recs
            if r.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
            + r.get("memory_analysis", {}).get("argument_size_in_bytes", 0)
            > 16 * 2**30]
    if over:
        print(f"cells above 16 GiB/device (args+temp): "
              f"{[(r['arch'], r['shape'], r['mesh']) for r in over]}")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
