"""Paper Fig. 16 — the legacy HPCC benchmarks (STREAM, RandomAccess, FFT,
GEMM) scaled over devices, normalized like the paper normalizes per memory
bank / kernel replication."""
from __future__ import annotations

from benchmarks.common import ensure_devices, fmt_bw, save_result, table

ensure_devices()

import jax  # noqa: E402

from repro.core.fft import run_fft  # noqa: E402
from repro.core.gemm import run_gemm  # noqa: E402
from repro.core.randomaccess import run_randomaccess  # noqa: E402
from repro.core.stream import run_stream  # noqa: E402
from repro.launch.mesh import make_ring_mesh  # noqa: E402


def main(quick: bool = False, schedule=None):
    # the legacy kernels are embarrassingly parallel (no inter-device
    # schedule to select); ``schedule`` is accepted for driver uniformity
    mesh = make_ring_mesh()
    n = mesh.devices.size

    print(f"== legacy suite (paper Fig. 16) over {n} devices ==")
    record = {"schedule": schedule or "n/a"}
    rows = []

    res = run_stream(mesh, elems_per_device=(1 << 18) if quick else (1 << 20))
    rows.append(["STREAM", "triad B/s", fmt_bw(res.metric),
                 fmt_bw(res.metric / n) + "/dev", f"{res.error:.2e}"])
    record["stream"] = {"triad_bps": res.metric,
                        "bandwidth": res.details["bandwidth"]}

    res = run_randomaccess(mesh, table_log=16 if quick else 20,
                           updates_per_rng=1024 if quick else 4096)
    rows.append(["RandomAccess", "GUPS", f"{res.metric:.4f}",
                 f"{res.metric / n:.4f}/dev", f"{res.error:.2e}"])
    record["randomaccess"] = {"gups": res.metric, "err": res.error}

    res = run_fft(mesh, log_size=10 if quick else 14,
                  batch_per_device=16 if quick else 64)
    rows.append(["FFT", "GFLOP/s", f"{res.metric:.2f}",
                 f"{res.metric / n:.2f}/dev", f"{res.error:.2e}"])
    record["fft"] = {"gflops": res.metric, "err": res.error}

    res = run_gemm(mesh, m=256 if quick else 512)
    rows.append(["GEMM", "GFLOP/s", f"{res.metric:.2f}",
                 f"{res.metric / n:.2f}/dev", f"{res.error:.2e}"])
    record["gemm"] = {"gflops": res.metric, "err": res.error}

    print(table(rows, ["benchmark", "metric", "aggregate", "normalized",
                       "error"]))
    save_result("legacy_suite", record)
    return record


if __name__ == "__main__":
    main()
