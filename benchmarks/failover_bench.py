"""Beyond-paper — hard-failure survival benchmark.

PR 8's resilience bench covered *degraded* links (slow but alive); this
module covers links and ranks that are **gone**. Three gated sections:

* **link-down reroute** (GATED, fully deterministic) — one ring hop is
  marked hard-down (:meth:`~repro.comm.faults.FaultInjector.down_link`)
  and the health mask lands on the live engine through
  ``CollectiveEngine.invalidate_resolutions(health=...)``. The cost model
  prices every route crossing the cut at infinity, so bcast and allreduce
  re-resolve onto the rooted-chain schedule that detours away from the
  break. Recorded: the per-phase resolutions, the recovery latency (down
  event -> first successful rerouted collective), a
  :func:`~repro.comm.autotune.route_links` proof that the chosen route
  excludes the cut, and bit-identity of the outputs across all three
  phases. SystemExit(1) unless the schedule provably flips away and back
  AND the rerouted outputs are bit-identical to the healthy ones.
* **rank-loss elastic resume** (GATED) — a real ``explicit_tp``
  :func:`~repro.train.loop.train_loop_elastic` run loses a device
  mid-run (:meth:`FaultInjector.fail_rank`): the loop raises
  ``RankLostError``, rebuilds the mesh on the largest survivor count
  dividing the global batch, restores the latest checkpoint *resharded*
  onto it, and resumes. A control run restores the identical snapshot on
  an identical survivor mesh; the gate requires the resumed losses to
  match the control **bitwise**.
* **serve rank loss** (GATED) — the continuous-batching engine drains
  every request whose KV pages died with a lost rank (pages stripe
  ``p % nranks``): drained requests re-queue with ``tokens_so_far``
  intact and re-prefill onto surviving pages. Gate: every in-flight
  request completes token-identical to a fault-free run — zero lost
  tokens — with at least one drain observed, and tok/s recorded
  before/during/after the loss.
"""
from __future__ import annotations

import shutil
import tempfile
import time

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.comm.autotune import CostModel, route_links  # noqa: E402
from repro.comm.engine import CollectiveEngine, schedules_for  # noqa: E402
from repro.comm.faults import FaultInjector, FaultSchedule  # noqa: E402
from repro.comm.topology import MeshTopology  # noqa: E402
from repro.comm.types import TPU_V5E  # noqa: E402
from repro.compat import make_mesh, shard_map  # noqa: E402

P = jax.sharding.PartitionSpec

NBYTES = 16384          # per-shard payload for the rerouted collectives
DOWN_HOP = 3            # the severed ring hop (wire between ranks 3 and 4)


def _link_down_section(quick: bool):
    """Sever one ring hop; the engine must re-resolve both ops onto a
    route that provably avoids it, bit-identically, then flip back."""
    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"needs >= 2 devices, have {ndev}"}

    mesh = make_mesh((ndev,), ("x",))
    topo = MeshTopology.from_mesh(mesh)
    axes = (topo.axis("x"),)
    inj = FaultInjector(hw=TPU_V5E)
    # explicit analytic cost model: isolated from any measured tuning.json
    engine = CollectiveEngine.for_mesh(mesh,
                                       cost_model=CostModel(hw=TPU_V5E))

    n_ints = NBYTES // 4
    x = np.arange(ndev * n_ints, dtype=np.int32).reshape(ndev, -1)

    def _run():
        # rebuilt per phase from the SAME engine object: the reroute must
        # land through re-tracing alone, never through a new engine
        fn = jax.jit(shard_map(
            lambda v: (engine.bcast(v[0], "x", 0)[None],
                       engine.allreduce(v, "x")),
            mesh=mesh, in_specs=(P("x", None),),
            out_specs=(P("x", None), P("x", None)), check_vma=False))
        b, a = fn(jnp.asarray(x))
        return np.asarray(b), np.asarray(a)

    def _resolved():
        return {op: engine.schedule_for(op, nbytes=NBYTES, axis="x")
                for op in ("bcast", "allreduce")}

    res_before = _resolved()
    out_before = _run()

    t0 = time.perf_counter()
    inj.down_link("x", DOWN_HOP)
    down = inj.down_links()
    engine.invalidate_resolutions(health=down)
    res_during = _resolved()
    out_during = _run()           # first rerouted collective, jit included
    recovery_s = time.perf_counter() - t0

    # proof: the chosen route's link set exists and avoids the cut
    routes = {op: route_links(op, res_during[op], axes, health=down)
              for op in ("bcast", "allreduce")}
    excluded = all(r is not None and not (r & down)
                   for r in routes.values())

    inj.heal("x", DOWN_HOP)
    engine.invalidate_resolutions(health=inj.down_links())
    res_after = _resolved()
    out_after = _run()

    bit_identical = all(
        np.array_equal(out_before[i], out_during[i])
        and np.array_equal(out_before[i], out_after[i]) for i in (0, 1))
    ref_b = np.broadcast_to(x[0], x.shape)
    ref_a = np.broadcast_to(x.sum(axis=0), x.shape)
    return {
        "devices": ndev, "nbytes": NBYTES, "down_hop": DOWN_HOP,
        "resolved_before": res_before, "resolved_during": res_during,
        "resolved_after": res_after,
        "route_during": {op: sorted(map(list, r)) if r is not None else None
                         for op, r in routes.items()},
        "route_excludes_cut": excluded,
        "recovery_s": recovery_s,
        "bit_identical": bit_identical,
        "bcast_correct": bool(np.array_equal(out_before[0], ref_b)),
        "allreduce_correct": bool(np.array_equal(out_before[1], ref_a)),
        "time": recovery_s,
        "schedule": res_during["bcast"],
    }


def _gate_link_down(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    for op in ("bcast", "allreduce"):
        if sec["resolved_during"][op] == sec["resolved_before"][op]:
            bad.append(f"{op} never rerouted off the severed link")
        if sec["resolved_after"][op] != sec["resolved_before"][op]:
            bad.append(f"{op} never flipped back after the repair")
        if sec["resolved_during"][op] not in schedules_for(op):
            bad.append(f"unregistered {op} resolution "
                       f"{sec['resolved_during'][op]!r}")
    if not sec["route_excludes_cut"]:
        bad.append("a resolved route traverses the down link")
    if not sec["bit_identical"]:
        bad.append("outputs diverged across the reroute")
    if not (sec["bcast_correct"] and sec["allreduce_correct"]):
        bad.append("collective output wrong vs the reference")
    if bad:
        print("LINK-DOWN GATE FAILED:", bad)
        raise SystemExit(1)


def _rank_loss_section(quick: bool):
    """Lose a device mid-train; elastic resume must land bitwise on a
    control run restored from the identical checkpoint snapshot."""
    from repro.configs import RunConfig
    from repro.configs.qwen3_moe_235b_a22b import tiny
    from repro.data import DataConfig
    from repro.train.loop import (TrainLoopConfig, train_loop,
                                  train_loop_elastic)

    ndev = len(jax.devices())
    if ndev < 4:
        return {"skipped": f"needs >= 4 devices, have {ndev}"}
    steps, fail_at, lost_rank = (6, 4, ndev - 1) if quick \
        else (10, 6, ndev - 1)
    cfg = tiny(ndev, layers=2)
    data = DataConfig(cfg.vocab_size, ndev, 16)
    mesh = make_mesh((ndev,), ("x",))
    ck = tempfile.mkdtemp(prefix="failover_ck_")
    snap = tempfile.mkdtemp(prefix="failover_snap_")
    try:
        run = RunConfig(checkpoint_dir=ck, checkpoint_every=2,
                        learning_rate=1e-3, warmup_steps=1)
        inj = FaultInjector(hw=TPU_V5E)
        fault = FaultSchedule.rank_loss(inj, fail_at, rank=lost_rank)
        hist, rec = train_loop_elastic(
            cfg, run, data,
            TrainLoopConfig(steps=steps, step_mode="explicit_tp",
                            fault_schedule=fault),
            mesh=mesh, snapshot_dir=snap)

        # control: a fresh loop restoring the snapshot the recovery used,
        # on an identically-chosen survivor mesh
        devices = list(np.asarray(mesh.devices).flat)
        survivors = [d for i, d in enumerate(devices) if i != lost_rank]
        ctrl_mesh = make_mesh((rec["new_size"],), ("x",),
                              devices=np.array(survivors[:rec["new_size"]]))
        ctrl_run = RunConfig(checkpoint_dir=snap, checkpoint_every=2,
                             learning_rate=1e-3, warmup_steps=1)
        ctrl = train_loop(cfg, ctrl_run, data,
                          TrainLoopConfig(steps=steps,
                                          step_mode="explicit_tp"),
                          mesh=ctrl_mesh)
        i = hist["step"].index(rec["resume_step"])
        resumed_losses = hist["loss"][i:]
        return {
            "devices": ndev, "steps": steps, "fail_at": fail_at,
            "lost_rank": lost_rank,
            "recovery": rec,
            "completed": hist["step"][-1] == steps - 1 if hist["step"]
            else False,
            "resumed_losses": resumed_losses,
            "control_losses": list(ctrl["loss"]),
            "loss_bitwise": resumed_losses == list(ctrl["loss"]),
            "recovery_s": rec["recovery_s"],
            "time": rec["recovery_s"],
        }
    finally:
        shutil.rmtree(ck, ignore_errors=True)
        shutil.rmtree(snap, ignore_errors=True)


def _gate_rank_loss(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    rec = sec["recovery"]
    if rec is None:
        bad.append("rank loss never triggered elastic recovery")
    else:
        if rec["new_size"] >= rec["old_size"]:
            bad.append(f"survivor mesh did not shrink ({rec['old_size']} -> "
                       f"{rec['new_size']})")
        if rec["resume_step"] > rec["fail_step"]:
            bad.append(f"resume step {rec['resume_step']} past the failure "
                       f"at {rec['fail_step']}")
    if not sec["completed"]:
        bad.append("the resumed run never reached the final step")
    if not sec["loss_bitwise"]:
        bad.append("resumed losses diverge from the from-checkpoint control")
    if bad:
        print("RANK-LOSS GATE FAILED:", bad)
        raise SystemExit(1)


def _tok_per_s(stats, lo, hi):
    window = [s for s in stats[lo:hi] if s["decode_tokens"]]
    toks = sum(s["decode_tokens"] for s in window)
    secs = sum(s["decode_s"] for s in window)
    return toks / secs if secs > 0 else 0.0


def _serve_rank_loss_section(quick: bool):
    """Kill a rank mid-serve; every in-flight request must still finish
    with the token stream a fault-free run produces."""
    from repro.configs import get_config, reduced
    from repro.models.kvcache import PagedCacheConfig
    from repro.models.model import build_model
    from repro.serve import ServeEngine

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"needs >= 2 devices, have {ndev}"}
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    n_req, max_new = 3, 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
               for _ in range(n_req)]
    mesh = make_mesh((ndev,), ("x",))
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=4,
                            max_seq=16)

    ref_eng = ServeEngine(model, params, pcfg, mesh=mesh)
    for p in prompts:
        ref_eng.submit(p, max_new)
    ref = ref_eng.run()

    fail_at, lost_rank = 3, 3
    inj = FaultInjector(hw=TPU_V5E)
    fault = FaultSchedule.rank_loss(inj, fail_at, rank=lost_rank)
    eng = ServeEngine(model, params, pcfg, mesh=mesh, preempt=True,
                      fault_schedule=fault)
    for p in prompts:
        eng.submit(p, max_new)
    out, stats = eng.run(collect_stats=True)

    drained = sum(s["drained"] for s in stats)
    lost = sum(int(ref[r].shape[0] - out[r].shape[0]) for r in ref)
    return {
        "devices": ndev, "requests": n_req, "max_new": max_new,
        "fail_at": fail_at, "lost_rank": lost_rank,
        "steps": len(stats), "drained": drained,
        "tok_per_s_before": _tok_per_s(stats, 1, fail_at),
        "tok_per_s_during": _tok_per_s(stats, fail_at, fail_at + 2),
        "tok_per_s_after": _tok_per_s(stats, fail_at + 2, len(stats)),
        "tokens_lost": lost,
        "token_identical": all(np.array_equal(ref[r], out[r]) for r in ref),
    }


def _gate_serve_rank_loss(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    if not sec["token_identical"] or sec["tokens_lost"]:
        bad.append(f"rank loss lost tokens (lost={sec['tokens_lost']})")
    if sec["drained"] < 1:
        bad.append("the lost rank's pages never drained a request")
    if bad:
        print("SERVE-RANK-LOSS GATE FAILED:", bad)
        raise SystemExit(1)


def main(quick: bool = False, schedule=None):
    if schedule not in (None, "auto"):
        print(f"[failover: --schedule {schedule} ignored — this module "
              "measures the health-masked auto path]")
    record = {}

    ld = _link_down_section(quick)
    record["link_down"] = ld
    if "skipped" in ld:
        print(f"-- link-down reroute: {ld['skipped']} --")
    else:
        print(f"-- reroute around a severed ring hop "
              f"(hop {DOWN_HOP} hard-down) --")
        print(table(
            [[op, ld["resolved_before"][op], ld["resolved_during"][op],
              ld["resolved_after"][op]] for op in ("bcast", "allreduce")],
            ["op", "healthy", "severed", "repaired"]))
        print(f"   reroute latency {ld['recovery_s'] * 1e3:.1f}ms "
              f"(jit included); route excludes cut="
              f"{ld['route_excludes_cut']}; "
              f"bit-identical={ld['bit_identical']}")
    _gate_link_down(ld)

    rl = _rank_loss_section(quick)
    record["rank_loss"] = rl
    if "skipped" in rl:
        print(f"\n-- rank-loss elastic resume: {rl['skipped']} --")
    else:
        rec = rl["recovery"]
        print("\n-- elastic resume after losing rank "
              f"{rl['lost_rank']} at step {rl['fail_at']} --")
        print(table([[rec["old_size"], rec["new_size"], rec["fail_step"],
                      rec["resume_step"], f"{rec['recovery_s']:.2f}s",
                      rl["loss_bitwise"]]],
                    ["mesh", "survivors", "fail step", "resume step",
                     "recovery", "loss bitwise"]))
    _gate_rank_loss(rl)

    sl = _serve_rank_loss_section(quick)
    record["serve_rank_loss"] = sl
    if "skipped" in sl:
        print(f"\n-- serve rank loss: {sl['skipped']} --")
    else:
        print("\n-- serve through a rank loss (KV pages drained) --")
        print(table([[sl["drained"], sl["tokens_lost"],
                      f"{sl['tok_per_s_before']:.1f}",
                      f"{sl['tok_per_s_during']:.1f}",
                      f"{sl['tok_per_s_after']:.1f}",
                      sl["token_identical"]]],
                    ["drained", "lost", "tok/s before", "during", "after",
                     "token-exact"]))
    _gate_serve_rank_loss(sl)

    save_result("failover_bench", record)
    return record


if __name__ == "__main__":
    main()
