"""Paper Figs. 5/7 analogue — communication/compute overlap benchmarks.

Three overlap structures, all engine-driven:

1. **HPL lookahead vs eager** per registered bcast schedule and per
   pipeline depth d: the depth-d factorization issues iterations
   k+1..k+d's panel broadcasts before iteration k's bulk trailing GEMM
   (the paper's headline LINPACK optimization), so XLA can hide the
   chain/ring2d hops behind the update. Output is bit-identical to eager
   mode by construction at every depth.

2. **Chunked vs monolithic PTRANS** per chunk count S: the strip-wise
   ``engine.pipelined`` grid transpose overlaps strip i's transpose-add
   with strip i+1's wire hops. The autotuned (cost-model) S is its own
   row; when it resolves to 1 the monolithic measurement is reused, so the
   recorded pipelined-vs-monolithic ratio is <= 1.0 whenever the model
   declines to chunk (the CI no-regression gate).

3. **Bucketed vs monolithic gradient reduction** per registered allreduce
   schedule: ``CollectiveEngine.allreduce_tree`` packs a synthetic gradient
   pytree into buckets; independent buckets give the backward-overlap
   structure, a single monolithic bucket is the baseline, leaf-wise is the
   pathological many-small-collectives end.
"""
from __future__ import annotations

from functools import partial

from benchmarks.common import ensure_devices, fmt_bytes, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comm.engine import CollectiveEngine, schedules_for  # noqa: E402
from repro.comm.overlap import tree_bytes  # noqa: E402
from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.compat import make_mesh, shard_map  # noqa: E402
from repro.core.hpcc import timeit  # noqa: E402
from repro.core.hpl import run_hpl  # noqa: E402
from repro.launch.mesh import make_torus_mesh  # noqa: E402


def _hpl_lookahead(quick: bool, schedules, record):
    n = 256 if quick else 512
    b = 64
    g = 2
    depths = (1, 2) if quick else (1, 2, 3)
    if not schedules:
        return
    if len(jax.devices()) < g * g:
        print("-- skipping HPL lookahead (needs 4 devices) --")
        return
    mesh = make_torus_mesh(g)
    print(f"== HPL lookahead vs eager (paper Figs. 5/7), n={n}, "
          f"{g}x{g} torus, depths {depths} ==")
    rows = []
    for schedule in schedules:
        perf = {}
        for lookahead in (False,) + depths:
            res = run_hpl(mesh, CT.ICI_DIRECT, n=n, b=b, schedule=schedule,
                          reps=1, lookahead=lookahead)
            mode = "eager" if not lookahead else f"d{int(lookahead)}"
            perf[mode] = res.metric
            record[f"hpl/{schedule}/{mode}"] = {
                "n": n, "gflops": res.metric, "err": res.error,
                "schedule": res.details["schedule"],
                "lookahead_depth": res.details["lookahead_depth"],
                "time": res.times["best"]}
        rows.append([schedule, f"{perf['eager']:.3f}"]
                    + [f"{perf[f'd{d}']:.3f}" for d in depths]
                    + [f"{perf[f'd{d}'] / perf['eager']:.2f}x"
                       for d in depths])
    print(table(rows, ["bcast schedule", "eager GFLOP/s"]
                + [f"d={d} GFLOP/s" for d in depths]
                + [f"d={d} ratio" for d in depths]))
    print()


def _ptrans_pipeline(quick: bool, record):
    """Chunked vs monolithic PTRANS (the in-flight strip pipeline). The
    autotuned chunk count is its own row; when it resolves to S=1 the
    monolithic timing is reused so the recorded ratio is exactly 1.0 —
    the model chose not to chunk, and chunking cannot regress."""
    g = 2
    if len(jax.devices()) < g * g:
        print("-- skipping PTRANS pipeline (needs 4 devices) --")
        return
    from repro.core.ptrans import CALLSITE, run_ptrans
    n = 256 if quick else 512
    b = 64
    mesh = make_torus_mesh(g)
    local_bytes = (n // g) * (n // g) * 4
    eng = CollectiveEngine.for_mesh(mesh)
    s_auto = eng.pipeline_chunks("grid_transpose", nbytes=local_bytes,
                                 axis=("rows", "cols"), callsite=CALLSITE)
    print(f"== chunked vs monolithic PTRANS, n={n}, {g}x{g} torus "
          f"(local payload {fmt_bytes(local_bytes)}, autotuned S={s_auto}) ==")
    reps = 2 if quick else 3
    times = {}
    rows = []
    for s in (1, 2, 4):
        res = run_ptrans(mesh, CT.ICI_DIRECT, n=n, b=b, reps=reps,
                         nchunks=s, validate=(s == 1))
        times[s] = res.times["best"]
        record[f"ptrans_pipe/S{s}"] = {
            "n": n, "nchunks": s, "time": times[s], "gflops": res.metric,
            "schedule": res.details["schedule"]}
        rows.append([f"S={s}", f"{times[s] * 1e3:.2f}ms",
                     f"{times[1] / times[s]:.2f}x"])
    t_auto = times[s_auto] if s_auto in times else run_ptrans(
        mesh, CT.ICI_DIRECT, n=n, b=b, reps=reps, nchunks=s_auto,
        validate=False).times["best"]
    ratio = t_auto / times[1]
    record["ptrans_pipe/auto"] = {
        "n": n, "nchunks": s_auto, "time": t_auto,
        "ratio_vs_monolithic": ratio}
    rows.append([f"auto (S={s_auto})", f"{t_auto * 1e3:.2f}ms",
                 f"{1 / ratio:.2f}x"])
    print(table(rows, ["chunks", "time", "speedup vs mono"]))
    print()


def _grad_tree(quick: bool):
    """Synthetic gradient pytree shaped like a small LM backward pass:
    a few large matmul grads plus a tail of small bias/norm grads."""
    scale = 1 if quick else 4
    rng = np.random.default_rng(0)
    tree = {}
    for i in range(4 * scale):
        tree[f"layer{i}/w"] = rng.integers(
            -8, 8, (128, 256)).astype(np.float32)
        tree[f"layer{i}/b"] = rng.integers(-8, 8, (256,)).astype(np.float32)
        tree[f"layer{i}/ln"] = rng.integers(-8, 8, (128,)).astype(np.float32)
    return jax.tree.map(jnp.asarray, tree)


def _bucketed_reduction(quick: bool, schedules, record):
    if not schedules:
        return
    ndev = len(jax.devices())
    mesh = make_mesh((ndev,), ("x",))
    tree = _grad_tree(quick)
    total = tree_bytes(tree)
    nleaves = len(jax.tree.leaves(tree))
    # monolithic = one bucket; bucketed = a few buckets; model = the
    # topology-derived size (pipeline depth x per-hop latency-bw product);
    # leafwise = the pathological many-small-collectives end
    model_bytes = CollectiveEngine.for_mesh(mesh).bucket_bytes_for("x")
    bucket_modes = {"monolithic": 1 << 40, "bucketed": max(total // 4, 1),
                    "model": model_bytes, "leafwise": 1}
    print(f"== bucketed vs monolithic gradient reduction "
          f"({nleaves} leaves, {fmt_bytes(total)}, ring of {ndev}, "
          f"model bucket {fmt_bytes(model_bytes)}) ==")
    rows = []
    for schedule in schedules:
        eng = CollectiveEngine.for_mesh(mesh, schedule=schedule)
        times = {}
        for mode, bucket_bytes in bucket_modes.items():
            # resolved name at this mode's bucket payload (allreduce_tree
            # resolves per bucket, so the mode's effective payload — one
            # bucket, capped by the whole tree — is what auto actually sees)
            resolved = eng.schedule_for(
                "allreduce", nbytes=min(bucket_bytes, total), axis="x")
            fn = jax.jit(shard_map(
                partial(eng.allreduce_tree, axis="x",
                        bucket_bytes=bucket_bytes),
                mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False))
            _, t = timeit(fn, tree, reps=2 if quick else 3)
            times[mode] = t
            record[f"reduce/{schedule}/{mode}"] = {
                "bytes": total, "leaves": nleaves, "time": t,
                "bucket_bytes": bucket_bytes, "schedule": resolved,
                "gbps": total / t / 1e9}
        rows.append([schedule] + [f"{times[m] * 1e3:.2f}ms"
                                  for m in bucket_modes]
                    + [f"{times['monolithic'] / times['bucketed']:.2f}x"])
    print(table(rows, ["allreduce schedule"] + list(bucket_modes)
                + ["mono/bucketed"]))
    print()


def main(quick: bool = False, schedule=None):
    record = {}
    bcasts = [s for s in schedules_for("bcast") if s != "staged"]
    reduces = [s for s in schedules_for("allreduce") if s != "staged"]
    if schedule == "auto":
        # cost-model resolution per callsite — its own sweep column
        bcasts, reduces = ["auto"], ["auto"]
    elif schedule is not None:  # sweep mode: restrict to the swept schedule;
        # a schedule with no counterpart for an op skips that half rather
        # than duplicating another schedule's measurement in the sweep
        bcasts = [s for s in bcasts if s == schedule]
        reduces = [s for s in reduces if s == schedule]
    _hpl_lookahead(quick, bcasts, record)
    if schedule in (None, "auto"):
        # the strip pipeline resolves its own schedule per callsite
        _ptrans_pipeline(quick, record)
    _bucketed_reduction(quick, reduces, record)
    save_result("overlap_bench", record)
    return record


if __name__ == "__main__":
    main()
