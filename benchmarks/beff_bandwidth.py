"""Paper Fig. 10 + Fig. 11 + Eqs. 1/2/4 — b_eff bandwidth vs message size and
ring-size scaling, for both communication backends, with the analytical
model overlays (520N constants validate the reproduction; TPU v5e constants
give the production prediction)."""
from __future__ import annotations

from benchmarks.common import ensure_devices, fmt_bw, save_result, table

ensure_devices()

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.core import models  # noqa: E402
from repro.core.beff import run_beff  # noqa: E402
from repro.launch.mesh import make_ring_mesh  # noqa: E402


def main(quick: bool = False, schedule=None):
    mesh = make_ring_mesh()
    n = mesh.devices.size
    max_log = 12 if quick else 16
    reps = 2 if quick else 3

    print(f"== b_eff (paper Fig. 10/11) over {n} devices ==")
    results = {}
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        res = run_beff(mesh, ct, max_log=max_log, reps=reps, rounds=2,
                       schedule=schedule or "auto")
        results[ct.value] = res
        rows = []
        for L, bw in sorted(res.details["bandwidth_by_size"].items()):
            rows.append([L, fmt_bw(bw),
                         fmt_bw(models.beff_ici_model(L)),
                         fmt_bw(models.beff_host_staged_model(L)),
                         fmt_bw(models.beff_csn_model_520n(L))])
        print(f"\n-- backend={ct.value}  b_eff={fmt_bw(res.metric)} "
              f"errors={res.error}")
        print(table(rows, ["msg_B", "measured", "model:ICI(v5e)",
                           "model:PCIe+MPI(v5e)", "model:CSN(520N Eq.4)"]))

    ratio = results["ici_direct"].metric / max(results["host_staged"].metric, 1e-9)
    print(f"\nICI_DIRECT / HOST_STAGED effective-bandwidth ratio: {ratio:.2f}x "
          "(paper: direct CSN wins, Fig. 10)")
    save_result("beff_bandwidth", {
        k: {"b_eff": v.metric, "bandwidth_by_size": v.details["bandwidth_by_size"],
            "error": v.error, "schedule": v.details["schedule"]}
        for k, v in results.items()})
    return results


if __name__ == "__main__":
    main()
