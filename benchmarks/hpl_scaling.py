"""Paper Figs. 14/15 — HPL weak/strong scaling over the torus plus the
single-device extrapolation model (the paper's Fig. 15 colored lines)."""
from __future__ import annotations

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.core.hpl import run_hpl  # noqa: E402
from repro.core.hpl_blocked import run_hpl_single  # noqa: E402
from repro.core.models import hpl_strong_scaling_model  # noqa: E402
from repro.launch.mesh import make_torus_mesh  # noqa: E402


def main(quick: bool = False, schedule=None, pipeline=None):
    n_dev = len(jax.devices())
    grids = [g for g in (1, 2) if g * g <= n_dev]
    n_base = 256 if quick else 512
    b = 64
    # pipeline = lookahead depth for the overlapped runs (run.py
    # --sweep-schedules S column); None keeps depth 1, "auto" resolves from
    # the cost model. The depth only affects the multi-device ICI lookahead
    # rows, so a pipeline sweep pass skips every configuration that would
    # re-measure byte-identical data (single device, host-staged, the
    # extrapolation curve).
    depth = 1 if pipeline is None else \
        ("auto" if pipeline == "auto" else int(pipeline))
    pipeline_only = pipeline is not None
    if pipeline_only:
        grids = [g for g in grids if g > 1]

    print("== HPL scaling (paper Figs. 14/15) ==")
    record = {}
    rows = []
    base = {}
    # HOST_STAGED forces the `staged` schedule regardless of the flag, so an
    # explicit other schedule (e.g. a --sweep-schedules pass) would re-run
    # byte-identical host-staged configs — skip them in that case
    comms = ((CT.ICI_DIRECT,)
             if pipeline_only or schedule not in (None, "auto", "staged")
             else (CT.ICI_DIRECT, CT.HOST_STAGED))
    for label, strong in (("strong", True), ("weak", False)):
        for ct in comms:
            for g in grids:
                n = n_base if strong else n_base * g
                if (n // b) % max(g, 1):
                    continue
                # lookahead (paper Fig. 5/7 overlap) rides along for the
                # device-to-device backend; bit-identical LU, so one
                # validated eager run plus a timed lookahead run suffices
                lookaheads = ((False, True)
                              if g > 1 and ct is CT.ICI_DIRECT else (False,))
                for lookahead in lookaheads:
                    if g == 1:
                        res = run_hpl_single(n=n, b=b, reps=1)
                    else:
                        res = run_hpl(make_torus_mesh(g), ct, n=n, b=b,
                                      schedule=schedule or "auto", reps=1,
                                      lookahead=depth if lookahead else False,
                                      validate=not lookahead)
                    key = (label, ct.value)
                    if key not in base:
                        base[key] = res.metric
                    d = res.details.get("lookahead_depth", 0)
                    mode = f"lookahead(d={d})" if lookahead else "eager"
                    # lookahead runs skip validation (LU is bit-identical
                    # to the validated eager run) — report that, not 0.0
                    resid = "= eager" if lookahead else f"{res.error:.2e}"
                    rows.append([label, ct.value, f"{g}x{g}", n, mode,
                                 f"{res.metric:.3f}",
                                 f"{res.metric / base[key]:.2f}x", resid])
                    suffix = "/lookahead" if lookahead else ""
                    record[f"{label}/{ct.value}/g{g}{suffix}"] = {
                        "n": n, "gflops": res.metric,
                        "err": None if lookahead else res.error,
                        "lookahead": bool(lookahead),
                        "lookahead_depth": d,
                        "schedule": res.details.get("schedule", "local"),
                        "schedule_block": res.details.get("schedule_block"),
                        "schedule_panel": res.details.get("schedule_panel")}
    print(table(rows, ["scaling", "backend", "grid", "n", "mode", "GFLOP/s",
                       "speedup", "resid"]))

    # Fig. 15 extrapolation: single-device perf-vs-size curve -> predicted
    # aggregate strong-scaling performance on larger tori (pipeline-
    # invariant, so skipped on pipeline sweep passes)
    if not pipeline_only:
        print("\n-- strong-scaling extrapolation from the single-device "
              "curve (paper Fig. 15 model) --")
        sizes = [128, 256] if quick else [128, 256, 384, 512]
        curve = {}
        for n in sizes:
            res = run_hpl_single(n=n, b=b, reps=1, validate=False)
            curve[n] = res.metric
        model = hpl_strong_scaling_model(curve, n_base, [1, 4, 9, 16, 25])
        rows = [[d, f"{p:.3f}"] for d, p in model.items()]
        print(table(rows, ["devices", "predicted aggregate GFLOP/s"]))
        record["extrapolation"] = model
    save_result("hpl_scaling", record)
    return record


if __name__ == "__main__":
    main()
