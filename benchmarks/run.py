"""Benchmark driver:
``PYTHONPATH=src python -m benchmarks.run [--quick] [--schedule NAME]``.

``--schedule`` selects a registered collective-engine schedule (``chain``,
``native``, ``staged``, ``ring2d``, ``rs_ag``; see repro.comm.engine) for
every benchmark that communicates; the engine's resolved schedule name is
recorded in each result file.

One module per paper table/figure (DESIGN.md §6):
  beff_bandwidth   Fig. 10/11 + Eqs. 1/2/4
  ptrans_scaling   Fig. 12 + Eqs. 5/6
  hpl_matrix_sweep Fig. 13
  hpl_scaling      Figs. 14/15
  legacy_suite     Fig. 16
  resource_table   Table 7 analogue (production-mesh compiled footprints)
  lm_step_bench    beyond-paper LM roofline table
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import ensure_devices

ensure_devices()  # 8 placeholder CPU devices for every measured benchmark

MODULES = [
    "beff_bandwidth",
    "ptrans_scaling",
    "hpl_matrix_sweep",
    "hpl_scaling",
    "legacy_suite",
    "resource_table",
    "lm_step_bench",
]


def _parse_schedule(argv):
    """--schedule NAME or --schedule=NAME; validated against the registry."""
    schedule = None
    rest = []
    it = iter(argv)
    for a in it:
        if a == "--schedule":
            schedule = next(it, None)
            if schedule is None or schedule.startswith("-"):
                raise SystemExit("--schedule requires a value, e.g. "
                                 "--schedule ring2d")
        elif a.startswith("--schedule="):
            schedule = a.split("=", 1)[1]
        else:
            rest.append(a)
    if schedule is not None:
        # engine construction is the single source of schedule validation
        from repro.comm.engine import CollectiveEngine
        CollectiveEngine(schedule=schedule)
    return schedule, rest


def main():
    schedule, argv = _parse_schedule(sys.argv[1:])
    quick = "--quick" in argv
    only = [a for a in argv if not a.startswith("-")]
    failures = []
    for name in (only or MODULES):
        print("\n" + "=" * 78)
        print(f"### benchmarks.{name}"
              + (f" (schedule={schedule})" if schedule else ""))
        print("=" * 78)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=quick, schedule=schedule)
            print(f"[{name} done in {time.time() - t0:.1f}s]")
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"[{name} FAILED]\n{traceback.format_exc()[-3000:]}")
    print("\n" + "=" * 78)
    if failures:
        print("FAILED:", failures)
        raise SystemExit(1)
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
