"""Benchmark driver:
``PYTHONPATH=src python -m benchmarks.run [--quick] [--schedule NAME]
[--sweep-schedules] [modules...]``.

``--schedule`` selects a registered collective-engine schedule (``chain``,
``native``, ``staged``, ``ring2d``, ``rs_ag``, ``int8_ef``; see
repro.comm.engine) for every benchmark that communicates; the engine's
resolved schedule name is recorded in each result file.

``--sweep-schedules`` instead runs each selected benchmark once per schedule
registered for its primary collective op and emits one comparison table per
benchmark (the paper's Figs. 10-16 with schedules as columns), saved to
``results/bench/schedule_sweep.json``.

Module arguments accept short aliases: ``hpl`` -> hpl_scaling, ``ptrans`` ->
ptrans_scaling, ``beff`` -> beff_bandwidth, ``overlap`` -> overlap_bench.

One module per paper table/figure (DESIGN.md §6):
  beff_bandwidth   Fig. 10/11 + Eqs. 1/2/4
  ptrans_scaling   Fig. 12 + Eqs. 5/6
  hpl_matrix_sweep Fig. 13
  hpl_scaling      Figs. 14/15
  legacy_suite     Fig. 16
  resource_table   Table 7 analogue (production-mesh compiled footprints)
  lm_step_bench    beyond-paper LM roofline table
  overlap_bench    Figs. 5/7 analogue (lookahead HPL + bucketed reduction)
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()  # 8 placeholder CPU devices for every measured benchmark

MODULES = [
    "beff_bandwidth",
    "ptrans_scaling",
    "hpl_matrix_sweep",
    "hpl_scaling",
    "legacy_suite",
    "resource_table",
    "lm_step_bench",
    "overlap_bench",
]

ALIASES = {
    "hpl": "hpl_scaling",
    "ptrans": "ptrans_scaling",
    "beff": "beff_bandwidth",
    "overlap": "overlap_bench",
    "lm": "lm_step_bench",
}

# primary collective op per module: --sweep-schedules runs the module once
# per schedule registered for that op (None = no communication to sweep)
SWEEP_OPS = {
    "beff_bandwidth": "ring_exchange",
    "ptrans_scaling": "grid_transpose",
    "hpl_matrix_sweep": "bcast",
    "hpl_scaling": "bcast",
    "legacy_suite": None,      # embarrassingly parallel — ignores schedule
    "resource_table": None,
    "lm_step_bench": None,     # GSPMD path — XLA picks the collectives
    "overlap_bench": "allreduce",
}


def _parse_schedule(argv):
    """--schedule NAME or --schedule=NAME; validated against the registry."""
    schedule = None
    rest = []
    it = iter(argv)
    for a in it:
        if a == "--schedule":
            schedule = next(it, None)
            if schedule is None or schedule.startswith("-"):
                raise SystemExit("--schedule requires a value, e.g. "
                                 "--schedule ring2d")
        elif a.startswith("--schedule="):
            schedule = a.split("=", 1)[1]
        else:
            rest.append(a)
    if schedule is not None:
        # engine construction is the single source of schedule validation
        from repro.comm.engine import CollectiveEngine
        CollectiveEngine(schedule=schedule)
    return schedule, rest


def _run_module(name, quick, schedule):
    print("\n" + "=" * 78)
    print(f"### benchmarks.{name}"
          + (f" (schedule={schedule})" if schedule else ""))
    print("=" * 78)
    t0 = time.time()
    mod = __import__(f"benchmarks.{name}", fromlist=["main"])
    record = mod.main(quick=quick, schedule=schedule)
    print(f"[{name} done in {time.time() - t0:.1f}s]")
    return record


def _metric_rows(record):
    """(key, gflops-like scalar) pairs from a benchmark record, for the
    cross-schedule comparison table."""
    rows = []
    for key, val in (record or {}).items():
        if isinstance(val, dict):
            for field in ("gflops", "gbps", "gups", "bandwidth_gbs", "time"):
                if field in val:
                    rows.append((key, field, float(val[field])))
                    break
    return rows


def _sweep(modules, quick):
    from repro.comm.engine import schedules_for
    sweep_record = {}
    failures = []
    for name in modules:
        op = SWEEP_OPS.get(name)
        schedules = list(schedules_for(op)) if op else [None]
        per_schedule = {}
        for s in schedules:
            try:
                per_schedule[s or "default"] = _run_module(name, quick, s)
            except Exception:  # noqa: BLE001
                failures.append(f"{name}[{s}]")
                print(f"[{name} schedule={s} FAILED]\n"
                      f"{traceback.format_exc()[-3000:]}")
        sweep_record[name] = per_schedule

        # one comparison table per module: record keys x schedules
        cols = list(per_schedule)
        cells = {}
        metric_field = {}
        for s, rec in per_schedule.items():
            for key, field, v in _metric_rows(rec):
                cells.setdefault(key, {})[s] = v
                metric_field[key] = field
        if cells:
            print(f"\n-- {name}: schedule comparison "
                  f"({op or 'no collective op'}) --")
            rows = [[key, metric_field[key]]
                    + [f"{cells[key].get(s, float('nan')):.4g}" for s in cols]
                    for key in cells]
            print(table(rows, ["config", "metric"] + cols))
    save_result("schedule_sweep", sweep_record)
    return failures


def main():
    schedule, argv = _parse_schedule(sys.argv[1:])
    quick = "--quick" in argv
    sweep = "--sweep-schedules" in argv
    only = [ALIASES.get(a, a) for a in argv if not a.startswith("-")]
    for name in only:
        if name not in MODULES:
            raise SystemExit(f"unknown benchmark {name!r}; modules are "
                             f"{MODULES} (aliases: {ALIASES})")
    modules = only or MODULES

    if sweep:
        if schedule is not None:
            raise SystemExit("--sweep-schedules and --schedule are "
                             "mutually exclusive")
        failures = _sweep(modules, quick)
    else:
        failures = []
        for name in modules:
            try:
                _run_module(name, quick, schedule)
            except Exception:  # noqa: BLE001
                failures.append(name)
                print(f"[{name} FAILED]\n{traceback.format_exc()[-3000:]}")
    print("\n" + "=" * 78)
    if failures:
        print("FAILED:", failures)
        raise SystemExit(1)
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
