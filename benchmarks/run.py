"""Benchmark driver:
``PYTHONPATH=src python -m benchmarks.run [--quick] [--schedule NAME]
[--sweep-schedules] [--autotune] [modules...]``.

``--schedule`` selects a registered collective-engine schedule (``chain``,
``native``, ``staged``, ``ring2d``, ``rs_ag``, ``int8_ef``; see
repro.comm.engine) for every benchmark that communicates; the engine's
resolved schedule name is recorded in each result file. Without the flag
(or with ``--schedule auto``) every engine resolves per callsite through
the cost model (repro.comm.autotune) and the driver prints the choices.

``--sweep-schedules`` instead runs each selected benchmark once per schedule
registered for its primary collective op — plus an ``auto`` row showing what
the cost model picks — and emits one comparison table per benchmark (the
paper's Figs. 10-16 with schedules as columns), saved to
``results/bench/schedule_sweep.json``. Modules with a software-pipeline
dimension (PTRANS chunk count, HPL lookahead depth) are additionally swept
over S in {1, 2, 4, auto} under ``schedule="auto"`` and get a second
pipeline-depth comparison table.

``--autotune`` microbenchmarks every registered schedule per op on the live
devices, persists the per-size winners to ``results/tuning.json`` (loaded by
every subsequent ``schedule="auto"`` engine), and fails if any ``auto``
resolution names an unregistered schedule. Combine with modules to run
benchmarks against the freshly measured table in the same invocation.

Module arguments accept short aliases: ``hpl`` -> hpl_scaling, ``ptrans`` ->
ptrans_scaling, ``beff`` -> beff_bandwidth, ``overlap`` -> overlap_bench,
``gups`` / ``fftd`` -> gups_fft_bench.

One module per paper table/figure (DESIGN.md §6):
  beff_bandwidth   Fig. 10/11 + Eqs. 1/2/4
  ptrans_scaling   Fig. 12 + Eqs. 5/6
  hpl_matrix_sweep Fig. 13
  hpl_scaling      Figs. 14/15
  legacy_suite     Fig. 16
  gups_fft_bench   beyond-paper distributed GUPS + pencil FFT: the legacy
                   suite's two kernels engine-routed (ra.updates /
                   fft.transpose callsites) next to their zero-comm
                   references (records the resolved schedules and exits 1
                   if any is unregistered — the --autotune gate)
  resource_table   Table 7 analogue (production-mesh compiled footprints)
  lm_step_bench    beyond-paper LM roofline table + explicit-vs-GSPMD MoE
                   (engine-routed expert exchanges; records the resolved
                   moe.dispatch / moe.combine / dp.grads schedules and
                   exits 1 if any is unregistered — the --autotune gate)
  overlap_bench    Figs. 5/7 analogue (lookahead HPL + bucketed reduction)
  serve_bench      beyond-paper continuous-batching serving loop: paged-KV
                   explicit-vs-GSPMD decode parity + tokens/sec and p50/p99
                   per-token latency vs batch size (records the resolved
                   decode.qkv / decode.out / decode.moe schedules and exits
                   1 if any is unregistered — the --autotune gate)
  resilience_bench beyond-paper degraded-link resilience: scripted fault ->
                   drift detection -> narrow retune -> mid-run schedule flip
                   (bit-exact, deterministic gate), plus straggler-flagged
                   train degradation and zero-lost-token serve preemption
  failover_bench   beyond-paper hard-failure survival: link-down ->
                   health-masked reroute (provably off the cut, bit-exact),
                   rank loss -> elastic resume from a resharded checkpoint
                   (bitwise vs control), and zero-lost-token serve drain
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()  # 8 placeholder CPU devices for every measured benchmark

MODULES = [
    "beff_bandwidth",
    "ptrans_scaling",
    "hpl_matrix_sweep",
    "hpl_scaling",
    "legacy_suite",
    "gups_fft_bench",
    "resource_table",
    "lm_step_bench",
    "overlap_bench",
    "serve_bench",
    "resilience_bench",
    "failover_bench",
]

ALIASES = {
    "hpl": "hpl_scaling",
    "ptrans": "ptrans_scaling",
    "beff": "beff_bandwidth",
    "overlap": "overlap_bench",
    "gups": "gups_fft_bench",
    "fftd": "gups_fft_bench",
    "lm": "lm_step_bench",
    "serve": "serve_bench",
    "resilience": "resilience_bench",
    "failover": "failover_bench",
}

# primary collective op per module: --sweep-schedules runs the module once
# per schedule registered for that op (None = no communication to sweep)
SWEEP_OPS = {
    "beff_bandwidth": "ring_exchange",
    "ptrans_scaling": "grid_transpose",
    "hpl_matrix_sweep": "bcast",
    "hpl_scaling": "bcast",
    "legacy_suite": None,      # embarrassingly parallel — ignores schedule
    # routed GUPS + pencil FFT both exchange over all_to_all_tiles (the
    # ra.updates / fft.transpose callsites): the sweep reruns both per
    # registered schedule next to their zero-comm references
    "gups_fft_bench": "all_to_all_tiles",
    "resource_table": None,
    # the GSPMD steps ignore schedule (XLA picks the collectives), but the
    # explicit-MoE section routes its dispatch/combine exchanges through the
    # engine — the sweep exercises every registered all_to_all_tiles schedule
    "lm_step_bench": "all_to_all_tiles",
    "overlap_bench": "allreduce",
    # the decode.qkv/decode.out/decode.moe exchanges are all_to_all_tiles:
    # the sweep reruns the serving loop once per registered schedule
    "serve_bench": "all_to_all_tiles",
    # the whole point is the *adaptive* auto path: a fixed-schedule sweep
    # would defeat the retune under test
    "resilience_bench": None,
    # likewise: the health-masked re-resolution IS the subject under test
    "failover_bench": None,
}

# modules with a software-pipeline dimension: --sweep-schedules also runs
# them once per pipeline depth S (chunk count for PTRANS, lookahead depth
# for HPL; "auto" = the cost-model resolution) under schedule="auto"
PIPELINE_SWEEP = ("ptrans_scaling", "hpl_scaling")
PIPELINE_DEPTHS = (1, 2, 4, "auto")


def _parse_schedule(argv):
    """--schedule NAME or --schedule=NAME; validated against the registry."""
    schedule = None
    rest = []
    it = iter(argv)
    for a in it:
        if a == "--schedule":
            schedule = next(it, None)
            if schedule is None or schedule.startswith("-"):
                raise SystemExit("--schedule requires a value, e.g. "
                                 "--schedule ring2d")
        elif a.startswith("--schedule="):
            schedule = a.split("=", 1)[1]
        else:
            rest.append(a)
    if schedule is not None:
        # engine construction is the single source of schedule validation
        from repro.comm.engine import CollectiveEngine
        CollectiveEngine(schedule=schedule)
    return schedule, rest


def _print_resolved(name, record):
    """Surface the cost-model choices: every resolved schedule recorded in
    the module's result dict (the literal "auto" never appears here)."""
    picks = sorted({str(v["schedule"]) for v in (record or {}).values()
                    if isinstance(v, dict) and "schedule" in v})
    if picks:
        print(f"[{name}: cost-model resolved schedule(s): "
              f"{', '.join(picks)}]")


def _run_module(name, quick, schedule, pipeline=None):
    print("\n" + "=" * 78)
    print(f"### benchmarks.{name}"
          + (f" (schedule={schedule})" if schedule else "")
          + (f" (pipeline={pipeline})" if pipeline is not None else ""))
    print("=" * 78)
    t0 = time.time()
    mod = __import__(f"benchmarks.{name}", fromlist=["main"])
    kw = {"quick": quick, "schedule": schedule}
    if pipeline is not None:
        kw["pipeline"] = pipeline
    record = mod.main(**kw)
    if schedule in (None, "auto"):
        _print_resolved(name, record)
    print(f"[{name} done in {time.time() - t0:.1f}s]")
    return record


def _autotune(quick):
    """Measure registered schedules on the live mesh, persist the tuning
    table, refresh the default cost model, and verify every auto resolution
    is a registered name (CI gate)."""
    import jax

    from repro.comm.autotune import (autotune_mesh, default_cost_model,
                                     default_table_path)
    from repro.comm.engine import OPS, schedules_for
    from repro.comm.topology import AxisTopology

    print("\n" + "=" * 78)
    print("### autotune: measuring registered schedules on the live mesh")
    print("=" * 78)
    table, record = autotune_mesh(quick=quick)
    path = table.save(default_table_path())
    save_result("autotune_raw", record)
    print(f"[tuning table -> {path}]")
    for op, sigs in table.entries.items():
        for sig, rows in sigs.items():
            bands = ", ".join(
                f"<= {b}B: {n}" if b is not None else f"rest: {n}"
                for b, n in rows)
            print(f"  {op:16s} {sig:28s} {bands}")

    model = default_cost_model(refresh=True)
    # gate: auto must resolve to a registered schedule for every op across
    # the measured topologies and a size ladder spanning the table bands
    bad = []
    probe_axes = {
        "ring": (AxisTopology("x", len(jax.devices()), "ring"),),
    }
    for op in OPS:
        for sig, axes in probe_axes.items():
            for lg in range(0, 27, 2):
                choice = model.choose(op, 1 << lg, axes)
                if choice is None or choice not in schedules_for(op):
                    bad.append((op, sig, 1 << lg, choice))
    for op, sigs in table.entries.items():
        base_op = op.split("@", 1)[0]  # callsite-tagged keys (bcast@hpl.panel)
        for sig, rows in sigs.items():
            for _, nm in rows:
                if nm not in schedules_for(base_op):
                    bad.append((op, sig, "table", nm))
    if bad:
        print("UNREGISTERED auto resolutions:", bad)
        raise SystemExit(1)
    print("[autotune ok: every auto resolution is a registered schedule]")


def _metric_rows(record):
    """(key, gflops-like scalar) pairs from a benchmark record, for the
    cross-schedule comparison table."""
    rows = []
    for key, val in (record or {}).items():
        if isinstance(val, dict):
            for field in ("gflops", "gbps", "gups", "bandwidth_gbs", "time"):
                if field in val:
                    rows.append((key, field, float(val[field])))
                    break
    return rows


def _sweep(modules, quick):
    from repro.comm.engine import schedules_for
    sweep_record = {}
    failures = []
    for name in modules:
        op = SWEEP_OPS.get(name)
        # "auto" rides along as its own column: the cost-model pick should
        # sit within noise of the best fixed schedule
        schedules = list(schedules_for(op)) + ["auto"] if op else [None]
        per_schedule = {}
        for s in schedules:
            try:
                per_schedule[s or "default"] = _run_module(name, quick, s)
            except Exception:  # noqa: BLE001
                failures.append(f"{name}[{s}]")
                print(f"[{name} schedule={s} FAILED]\n"
                      f"{traceback.format_exc()[-3000:]}")
        sweep_record[name] = per_schedule

        # one comparison table per module: record keys x schedules
        cols = list(per_schedule)
        cells = {}
        metric_field = {}
        for s, rec in per_schedule.items():
            for key, field, v in _metric_rows(rec):
                cells.setdefault(key, {})[s] = v
                metric_field[key] = field
        if cells:
            print(f"\n-- {name}: schedule comparison "
                  f"({op or 'no collective op'}) --")
            rows = [[key, metric_field[key]]
                    + [f"{cells[key].get(s, float('nan')):.4g}" for s in cols]
                    for key in cells]
            print(table(rows, ["config", "metric"] + cols))

        # pipeline-depth columns: the same module swept over the software-
        # pipeline dimension (chunk count / lookahead depth) under auto
        if name in PIPELINE_SWEEP:
            per_pipe = {}
            for s in PIPELINE_DEPTHS:
                try:
                    per_pipe[f"S={s}"] = _run_module(name, quick, "auto",
                                                     pipeline=s)
                except Exception:  # noqa: BLE001
                    failures.append(f"{name}[pipeline={s}]")
                    print(f"[{name} pipeline={s} FAILED]\n"
                          f"{traceback.format_exc()[-3000:]}")
            sweep_record[f"{name}/pipeline"] = per_pipe
            pcols = list(per_pipe)
            pcells, pfield = {}, {}
            for s, rec in per_pipe.items():
                for key, field, v in _metric_rows(rec):
                    pcells.setdefault(key, {})[s] = v
                    pfield[key] = field
            if pcells:
                print(f"\n-- {name}: pipeline-depth comparison "
                      f"(schedule=auto) --")
                rows = [[key, pfield[key]]
                        + [f"{pcells[key].get(s, float('nan')):.4g}"
                           for s in pcols]
                        for key in pcells]
                print(table(rows, ["config", "metric"] + pcols))
    save_result("schedule_sweep", sweep_record)
    return failures


def main():
    schedule, argv = _parse_schedule(sys.argv[1:])
    quick = "--quick" in argv
    sweep = "--sweep-schedules" in argv
    autotune = "--autotune" in argv
    only = [ALIASES.get(a, a) for a in argv if not a.startswith("-")]
    for name in only:
        if name not in MODULES:
            raise SystemExit(f"unknown benchmark {name!r}; modules are "
                             f"{MODULES} (aliases: {ALIASES})")
    modules = only or MODULES

    if autotune:
        _autotune(quick)  # SystemExit(1) on unregistered auto resolutions
        if not only and not sweep:
            return  # tune-only invocation (the CI smoke step)

    if sweep:
        if schedule is not None:
            raise SystemExit("--sweep-schedules and --schedule are "
                             "mutually exclusive")
        failures = _sweep(modules, quick)
    else:
        failures = []
        for name in modules:
            try:
                _run_module(name, quick, schedule)
            except Exception:  # noqa: BLE001
                failures.append(name)
                print(f"[{name} FAILED]\n{traceback.format_exc()[-3000:]}")
    print("\n" + "=" * 78)
    if failures:
        print("FAILED:", failures)
        raise SystemExit(1)
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
