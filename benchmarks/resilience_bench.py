"""Beyond-paper — degraded-link resilience benchmark.

The paper's circuit-switched network can silently fall back to slower
routing, and its barrier discipline means one slow link paces the whole
machine. This module measures the full adaptive loop on the simulated
mesh, four sections:

* **train retune** (GATED, fully deterministic) — a scripted
  :class:`~repro.comm.faults.FaultSchedule` degrades one ring link
  (``beta_scale`` bandwidth collapse) mid-run; the
  :class:`~repro.comm.retune.RetuneController` watches modeled step
  timings, detects the drift, re-prices the engine on the injector's
  degraded :class:`HardwareModel`, and
  ``CollectiveEngine.invalidate_resolutions`` swaps the ``hpl.panel``
  bcast schedule mid-run without rebuilding the engine. After the heal
  event the same two-sided detector flips it back. Recorded: detection
  latency (steps), retune latency (seconds), the per-phase resolved
  schedule, and the bit-identity of the actual jitted bcast outputs
  across all three phases. SystemExit(1) unless the schedule provably
  flips away and back AND the outputs stay bit-identical.
* **measured retune** (informational) — the narrow
  :func:`~repro.comm.autotune.autotune_mesh` ladder for the hot callsite
  with the injector active vs clean: measured winners on the simulated
  CPU mesh are noisy, so this section records but never gates.
* **train degradation** (GATED on detection) — a real
  :func:`~repro.train.loop.train_loop` run with an injected host-delay
  window: the StragglerMonitor must flag inside the window, and the
  'checkpoint' policy must have forced an off-cadence save.
* **serve degradation** (GATED, deterministic) — the continuous-batching
  engine on a page pool too small for its workload, ``preempt=True``,
  with a host-delay window on ``serve.step``: tokens/sec before/during/
  after the fault, preemption/flip counts, and token-exact equality
  against a never-preempting large-pool run (zero lost tokens).
"""
from __future__ import annotations

import shutil
import tempfile
import time

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.comm.autotune import (CostModel, _seg_time,  # noqa: E402
                                 autotune_mesh, segments)
from repro.comm.callsites import HPL_PANEL  # noqa: E402
from repro.comm.engine import CollectiveEngine, schedules_for  # noqa: E402
from repro.comm.faults import FaultInjector, FaultSchedule, injected  # noqa: E402
from repro.comm.retune import RetuneController, Watched  # noqa: E402
from repro.comm.topology import MeshTopology  # noqa: E402
from repro.comm.types import TPU_V5E  # noqa: E402
from repro.compat import make_mesh, shard_map  # noqa: E402

P = jax.sharding.PartitionSpec

NBYTES = 16384          # the watched hpl.panel payload (per shard)
BETA_SCALE = 64.0       # bandwidth collapse on the degraded link
FAULT_AT, HEAL_AT = 8, 20
STEPS = 30


def _modeled_step(inj: FaultInjector, axes, bcast_schedule: str) -> float:
    """Deterministic stand-in for one step's comm wall-time under the
    injector's current link state: the watched bcast at its *current*
    resolution plus a fixed-schedule gradient allreduce that always rides
    the ring — so a healed link shows up even while the bcast has been
    retuned onto a link-avoiding schedule."""
    hw = inj.hardware_view()
    t = 0.0
    for op, schedule in (("bcast", bcast_schedule), ("allreduce", "rs_ag")):
        t += sum(_seg_time(s, hw)
                 for s in segments(op, schedule, NBYTES, axes, hw))
    return t


def _train_retune_section(quick: bool):
    """Detect -> narrow retune -> invalidate -> schedule flip, bit-exact."""
    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"needs >= 2 devices, have {ndev}"}

    mesh = make_mesh((ndev,), ("x",))
    topo = MeshTopology.from_mesh(mesh)
    axes = (topo.axis("x"),)
    inj = FaultInjector(hw=TPU_V5E)
    fault = FaultSchedule.degrade_window(
        inj, FAULT_AT, HEAL_AT, axis="x", hop=0, beta_scale=BETA_SCALE)
    # explicit analytic-only cost model: isolated from any measured
    # tuning.json the CI autotune step produced for the CPU mesh
    engine = CollectiveEngine.for_mesh(mesh,
                                       cost_model=CostModel(hw=TPU_V5E))
    ctrl = RetuneController(
        engine, [Watched(HPL_PANEL, "bcast", NBYTES, "x")],
        drift_factor=1.75, recent=2, min_baseline=3, cooldown=2,
        hw_probe=inj.hardware_view)

    x = np.arange(ndev * (NBYTES // 4), dtype=np.int32).reshape(ndev, -1)

    def _run_bcast():
        # rebuilt per phase: the jitted program re-resolves at trace time,
        # from the SAME engine object — only the cost model was mutated
        fn = jax.jit(shard_map(
            lambda v: engine.bcast(v[0], "x", 0, callsite=HPL_PANEL)[None],
            mesh=mesh, in_specs=(P("x", None),), out_specs=P("x", None),
            check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    trace = []
    outputs = {}
    for step in range(STEPS):
        fault.apply(step)
        resolved = ctrl.resolutions()[HPL_PANEL]
        dur = _modeled_step(inj, axes, resolved)
        event = ctrl.observe(step, dur)
        trace.append({"step": step, "resolved": resolved,
                      "modeled_s": dur, "retuned": event is not None})
        phase = ("before" if step < FAULT_AT
                 else "during" if step < HEAL_AT else "after")
        if phase not in outputs:
            outputs[phase] = _run_bcast()

    by_phase = {ph: sorted({t["resolved"] for t in trace
                            if lo <= t["step"] < hi})
                for ph, lo, hi in (("before", 0, FAULT_AT),
                                   ("during", FAULT_AT, HEAL_AT),
                                   ("after", HEAL_AT, STEPS))}
    events = [{"step": e.step, "trigger": e.trigger,
               "detect_steps": e.detect_steps, "duration_s": e.duration_s,
               "changed": e.changed} for e in ctrl.events]
    flips = [e for e in ctrl.events if e.changed]
    bit_identical = all(
        np.array_equal(outputs["before"], outputs[ph]) for ph in outputs)
    ref = np.broadcast_to(x[0], outputs["before"].shape)
    return {
        "devices": ndev, "nbytes": NBYTES, "beta_scale": BETA_SCALE,
        "fault_at": FAULT_AT, "heal_at": HEAL_AT, "steps": STEPS,
        "resolved_before": trace[FAULT_AT - 1]["resolved"],
        "resolved_during": trace[HEAL_AT - 1]["resolved"],
        "resolved_after": trace[STEPS - 1]["resolved"],
        "by_phase": by_phase, "events": events,
        "flip_events": len(flips),
        "detect_degrade_steps": (flips[0].step - FAULT_AT) if flips else None,
        "detect_heal_steps": (flips[1].step - HEAL_AT) if len(flips) > 1
        else None,
        "retune_s": max((e.duration_s for e in ctrl.events), default=0.0),
        "time": max((e.duration_s for e in ctrl.events), default=0.0),
        "bit_identical": bit_identical,
        "bcast_correct": bool(np.array_equal(outputs["before"], ref)),
        "schedule": trace[HEAL_AT - 1]["resolved"],
    }


def _gate_train_retune(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    if sec["resolved_during"] == sec["resolved_before"]:
        bad.append("schedule never flipped away under the degraded link")
    if sec["resolved_after"] != sec["resolved_before"]:
        bad.append("schedule never flipped back after the heal")
    if sec["flip_events"] < 2:
        bad.append(f"expected >= 2 flip events, saw {sec['flip_events']}")
    if not sec["bit_identical"]:
        bad.append("bcast outputs diverged across schedule flips")
    if not sec["bcast_correct"]:
        bad.append("bcast output wrong vs the broadcast reference")
    for k in ("detect_degrade_steps", "detect_heal_steps"):
        if sec[k] is None or not 0 <= sec[k] <= 6:
            bad.append(f"{k}={sec[k]} outside [0, 6]")
    for name in (sec["resolved_before"], sec["resolved_during"]):
        if name not in schedules_for("bcast"):
            bad.append(f"unregistered resolution {name!r}")
    if bad:
        print("TRAIN-RETUNE GATE FAILED:", bad)
        raise SystemExit(1)


def _measured_retune_section(quick: bool):
    """Informational: the narrow measured ladder with the injector active.
    CPU-mesh microbenchmarks are noisy — recorded, never gated."""
    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"needs >= 2 devices, have {ndev}"}
    inj = FaultInjector(hw=TPU_V5E, delay_scale=1e4)
    inj.degrade_link("x", 0, beta_scale=BETA_SCALE)
    sizes = (NBYTES,) if quick else (NBYTES // 4, NBYTES, NBYTES * 4)
    t0 = time.perf_counter()
    clean, _ = autotune_mesh(ops=("bcast@hpl.panel",), sizes=sizes,
                             reps=1, quick=True)
    with injected(inj):
        degraded, _ = autotune_mesh(ops=("bcast@hpl.panel",), sizes=sizes,
                                    reps=1, quick=True)
    return {
        "devices": ndev, "sizes": list(sizes),
        "clean_winners": clean.entries.get("bcast@hpl.panel", {}),
        "degraded_winners": degraded.entries.get("bcast@hpl.panel", {}),
        "wall_s": time.perf_counter() - t0,
    }


def _train_degradation_section(quick: bool):
    """A real train_loop run through a host-delay window: the monitor must
    flag inside the window and force an off-cadence checkpoint."""
    from repro.checkpoint.manager import all_steps, restore
    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig
    from repro.data.pipeline import DataConfig
    from repro.train.loop import TrainLoopConfig, train_loop

    steps, lo, hi = 16, 10, 13
    delay_s = 0.25
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    ckdir = tempfile.mkdtemp(prefix="resilience_ck_")
    try:
        run = RunConfig(checkpoint_dir=ckdir, checkpoint_every=100,
                        learning_rate=1e-2, warmup_steps=2,
                        step_deadline_factor=2.0)
        data = DataConfig(vocab_size=cfg.vocab_size, global_batch=4,
                          seq_len=32)
        inj = FaultInjector(hw=TPU_V5E)
        fault = FaultSchedule.degrade_window(
            inj, lo, hi, axis="x", host_delay_s=delay_s,
            callsite="train.step")
        hist = train_loop(cfg, run, data, TrainLoopConfig(
            steps=steps, straggler_policy="checkpoint",
            fault_schedule=fault))
        flagged = hist["straggler"].get("flagged", [])
        forced = []
        for s in all_steps(ckdir):
            _, _, extra = restore(ckdir, {}, step=s)
            if extra.get("forced"):
                forced.append(s)
        times = hist["step_time"]
        return {
            "steps": steps, "fault_window": [lo, hi], "delay_s": delay_s,
            "flagged": flagged,
            "detected": any(lo <= f < hi for f in flagged),
            "forced_checkpoints": forced,
            "median_before_s": float(np.median(times[1:lo])),
            "median_during_s": float(np.median(times[lo:hi])),
            "median_after_s": float(np.median(times[hi:])),
            "time": float(np.median(times[lo:hi])),
        }
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


def _gate_train_degradation(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    if not sec["detected"]:
        bad.append(f"no straggler flag inside the fault window "
                   f"{sec['fault_window']} (flagged={sec['flagged']})")
    if not sec["forced_checkpoints"]:
        bad.append("the 'checkpoint' policy forced no off-cadence save")
    if bad:
        print("TRAIN-DEGRADATION GATE FAILED:", bad)
        raise SystemExit(1)


def _tok_per_s(stats, lo, hi):
    window = [s for s in stats[lo:hi] if s["decode_tokens"]]
    toks = sum(s["decode_tokens"] for s in window)
    secs = sum(s["decode_s"] for s in window)
    return toks / secs if secs > 0 else 0.0


def _serve_degradation_section(quick: bool):
    """Preempting small-pool engine under a host-delay window vs a large
    pool that never degrades: token-exact, with tok/s phases recorded."""
    from repro.configs import get_config, reduced
    from repro.models.kvcache import PagedCacheConfig
    from repro.models.model import build_model
    from repro.serve import ServeEngine

    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    n_req, max_new = 3, 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
               for _ in range(n_req)]

    big = ServeEngine(model, params, PagedCacheConfig(
        page_size=4, num_pages=16, max_slots=4, max_seq=16))
    for p in prompts:
        big.submit(p, max_new)
    ref = big.run()

    lo, hi = 4, 8
    inj = FaultInjector(hw=TPU_V5E)
    fault = FaultSchedule.degrade_window(
        inj, lo, hi, axis="x", host_delay_s=0.02, callsite="serve.step")
    small = ServeEngine(model, params, PagedCacheConfig(
        page_size=4, num_pages=4, max_slots=2, max_seq=16),
        preempt=True, fault_schedule=fault)
    for p in prompts:
        small.submit(p, max_new)
    out, stats = small.run(collect_stats=True)

    lost = sum(int(ref[r].shape[0] - out[r].shape[0]) for r in ref)
    return {
        "requests": n_req, "max_new": max_new,
        "small_pool_pages": 4, "big_pool_pages": 16,
        "fault_window": [lo, hi], "steps": len(stats),
        "preempted": small.scheduler.preempted_total,
        "timeouts": sum(s["timeouts"] for s in stats),
        "rejected": sum(s["rejected"] for s in stats),
        "tok_per_s_before": _tok_per_s(stats, 1, lo),
        "tok_per_s_during": _tok_per_s(stats, lo, hi),
        "tok_per_s_after": _tok_per_s(stats, hi, len(stats)),
        "tokens_lost": lost,
        "token_identical": all(np.array_equal(ref[r], out[r]) for r in ref),
        "time": _tok_per_s(stats, lo, hi) and
        1.0 / max(_tok_per_s(stats, lo, hi), 1e-9),
    }


def _gate_serve_degradation(sec) -> None:
    if "skipped" in sec:
        return
    bad = []
    if not sec["token_identical"] or sec["tokens_lost"]:
        bad.append(f"preemption lost tokens (lost={sec['tokens_lost']})")
    if sec["preempted"] < 1:
        bad.append("pool pressure never triggered a preemption")
    if bad:
        print("SERVE-DEGRADATION GATE FAILED:", bad)
        raise SystemExit(1)


def main(quick: bool = False, schedule=None):
    if schedule not in (None, "auto"):
        print(f"[resilience: --schedule {schedule} ignored — this module "
              "measures the adaptive auto path]")
    record = {}

    tr = _train_retune_section(quick)
    record["train_retune"] = tr
    if "skipped" in tr:
        print(f"-- train retune: {tr['skipped']} --")
    else:
        print("-- adaptive retune under a scripted degraded link "
              f"(beta/{BETA_SCALE:.0f} on one ring hop) --")
        print(table(
            [[ph, "/".join(tr["by_phase"][ph])]
             for ph in ("before", "during", "after")],
            ["phase", "hpl.panel resolution(s)"]))
        print(f"   detect: degrade +{tr['detect_degrade_steps']} steps, "
              f"heal +{tr['detect_heal_steps']} steps; "
              f"retune {tr['retune_s'] * 1e3:.1f}ms; "
              f"bit-identical={tr['bit_identical']}")
    _gate_train_retune(tr)

    mr = _measured_retune_section(quick)
    record["measured_retune"] = mr
    if "skipped" in mr:
        print(f"\n-- measured retune: {mr['skipped']} --")
    else:
        print("\n-- narrow measured ladder, injector active "
              "(informational — CPU timing noise) --")
        print(f"   clean:    {mr['clean_winners']}")
        print(f"   degraded: {mr['degraded_winners']}")

    td = _train_degradation_section(quick)
    record["train_degradation"] = td
    print("\n-- train loop through a host-delay window "
          f"({td['delay_s']*1e3:.0f}ms over steps {td['fault_window']}) --")
    print(table([[td["flagged"], td["forced_checkpoints"],
                  f"{td['median_before_s']*1e3:.1f}ms",
                  f"{td['median_during_s']*1e3:.1f}ms",
                  f"{td['median_after_s']*1e3:.1f}ms"]],
                ["flagged", "forced ckpt", "median before", "during",
                 "after"]))
    _gate_train_degradation(td)

    sd = _serve_degradation_section(quick)
    record["serve_degradation"] = sd
    print("\n-- serve under page exhaustion + host-delay window --")
    print(table([[sd["preempted"], sd["tokens_lost"],
                  f"{sd['tok_per_s_before']:.1f}",
                  f"{sd['tok_per_s_during']:.1f}",
                  f"{sd['tok_per_s_after']:.1f}",
                  sd["token_identical"]]],
                ["preempted", "lost", "tok/s before", "during", "after",
                 "token-exact"]))
    _gate_serve_degradation(sd)

    save_result("resilience_bench", record)
    return record


if __name__ == "__main__":
    main()
