"""Paper Table 7 analogue — per-benchmark "resource usage" on the production
mesh: compiled FLOPs / HBM bytes / collective bytes per device and the
three roofline terms, for the paper's communication benchmarks (b_eff,
PTRANS, HPL) lowered at production scale, plus the LM cells read from the
dry-run results.

The paper reports logic/BRAM/DSP/frequency per bitstream; the TPU analogue
of "resources a design consumes" is exactly what the compiled artifact
reports: bytes per device (fits/doesn't fit), FLOPs, and wire traffic.

This module needs the 512-device placeholder runtime; when invoked under a
smaller device count it re-execs itself in a fresh interpreter.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import RESULTS_DIR, fmt_bytes, save_result, table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _lower_hpcc():
    """Runs inside the 512-device interpreter: lower + analyse the paper's
    three communication benchmarks at production scale."""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import roofline as rl
    from repro.comm.engine import CollectiveEngine
    from repro.comm.types import CommunicationType as CT
    from repro.core import beff as beff_mod
    from repro.core import hpl as hpl_mod
    from repro.core import ptrans as ptrans_mod
    from repro.launch.mesh import make_mesh

    out = {}

    # --- b_eff: ring over one pod (256 chips), 1 MiB messages ----------------
    mesh = make_mesh((256,), ("x",))
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        step = beff_mod.make_step(mesh, CollectiveEngine.for_mesh(mesh, ct),
                                  rounds=4)
        L = 1 << 20
        spec = jax.ShapeDtypeStruct((256, L), np.uint8)
        with mesh:
            lowered = step.lower((spec, spec))
            compiled = lowered.compile()
        r = rl.from_compiled(compiled, chips=256,
                             model_flops=0.0)
        out[f"b_eff/{ct.value}"] = _terms(r)

    # --- PTRANS: 16x16 grid, n=32768 (paper's matrix), block 512 -------------
    mesh = make_mesh((16, 16), ("rows", "cols"))
    n, b = 32768, 512
    m = (n // b // 16) * b
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        step = ptrans_mod.make_step(mesh, 16,
                                    CollectiveEngine.for_mesh(mesh, ct),
                                    interpret=True)
        spec = jax.ShapeDtypeStruct((256, m, m), np.float32)
        with mesh:
            compiled = step.lower(spec, spec).compile()
        r = rl.from_compiled(compiled, chips=256,
                             model_flops=float(n) * n)  # n^2 adds
        out[f"ptrans/{ct.value}"] = _terms(r)

    # --- HPL: 16x16 torus, n=24576 (paper's multi-FPGA size), block 256 ------
    n, b = 24576, 256
    for ct, sched in ((CT.ICI_DIRECT, "chain"), (CT.ICI_DIRECT, "native"),
                      (CT.HOST_STAGED, "staged")):
        fact = hpl_mod.make_factorize(mesh, pg=16, nb=n // b, b=b, comm=ct,
                                      schedule=sched, interpret=True)
        m = (n // b // 16) * b
        spec = jax.ShapeDtypeStruct((256, m, m), np.float32)
        with mesh:
            compiled = fact.lower(spec).compile()
        r = rl.from_compiled(compiled, chips=256,
                             model_flops=2.0 * n ** 3 / 3.0)
        out[f"hpl/{ct.value}/{sched}"] = _terms(r)

    print(json.dumps(out))


def _terms(r):
    return {
        "flops_per_device": r.flops,
        "hbm_bytes_per_device": r.hbm_bytes,
        "collective_wire_bytes": r.coll_wire_bytes,
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "useful_ratio": r.useful_ratio, "per_op": r.details["per_op_bytes"],
    }


def main(quick: bool = False, schedule=None):
    # compiled-footprint analysis; no measured collectives, ``schedule``
    # accepted for driver uniformity
    print("== resource table (paper Table 7 analogue): production-mesh "
          "compiled footprints ==")
    # HPCC benchmarks, lowered in a fresh 512-device interpreter
    cache = os.path.join(RESULTS_DIR, "resource_table_hpcc.json")
    if os.path.exists(cache):
        with open(cache) as f:
            hpcc = json.load(f)
    else:
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=512",
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(os.path.dirname(__file__), "..", "src"),
                        os.path.join(os.path.dirname(__file__), ".."),
                        os.environ.get("PYTHONPATH", "")]))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.resource_table", "--hpcc-lower"],
            capture_output=True, text=True, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=3600)
        if proc.returncode:
            print("HPCC lowering failed:", proc.stderr[-2000:])
            hpcc = {}
        else:
            hpcc = json.loads(proc.stdout.strip().splitlines()[-1])
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(cache, "w") as f:
                json.dump(hpcc, f, indent=1)

    rows = []
    for name, t in hpcc.items():
        rows.append([name, f"{t['flops_per_device']:.3g}",
                     fmt_bytes(t["hbm_bytes_per_device"]),
                     fmt_bytes(t["collective_wire_bytes"]),
                     f"{t['compute_s']:.3g}", f"{t['memory_s']:.3g}",
                     f"{t['collective_s']:.3g}", t["dominant"]])
    print(table(rows, ["benchmark", "FLOPs/dev", "HBM/dev", "wire/dev",
                       "compute_s", "memory_s", "coll_s", "dominant"]))

    # LM cells from the dry-run sweep
    if os.path.isdir(DRYRUN_DIR):
        rows = []
        for fn in sorted(os.listdir(DRYRUN_DIR)):
            with open(os.path.join(DRYRUN_DIR, fn)) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            rows.append([rec["arch"], rec["shape"], rec["mesh"],
                         f"{rec['flops_per_device']:.3g}",
                         fmt_bytes(rec["hbm_bytes_per_device"]),
                         fmt_bytes(rec["collective_wire_bytes"]),
                         rec["dominant"], f"{rec['useful_ratio']:.1%}"])
        if rows:
            print("\n-- LM cells (from results/dryrun) --")
            print(table(rows, ["arch", "shape", "mesh", "FLOPs/dev",
                               "HBM/dev", "wire/dev", "dominant", "useful"]))
    save_result("resource_table", {"hpcc": hpcc})
    return hpcc


if __name__ == "__main__":
    if "--hpcc-lower" in sys.argv:
        _lower_hpcc()
    else:
        main()
