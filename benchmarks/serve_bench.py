"""Beyond-paper — continuous-batching serving benchmark (tiny qwen3-moe on
the simulated multi-device mesh).

Three sections:

* **decode equivalence** — the engine-routed explicit tensor-parallel
  decode step (``make_decode_step_explicit``, per-token collectives tagged
  ``decode.qkv`` / ``decode.out`` / ``decode.moe``) against the GSPMD
  paged decode from identical pages: logits AND cache parity per step,
  plus per-token step timings for both programs;
* **batch sweep** — the :class:`repro.serve.ServeEngine` loop at several
  slot counts: tokens/sec and p50/p99 per-token decode latency vs batch
  size, with the prefill-token budget set low enough that the scheduler
  interleaves prefill with in-flight decode (the mixed-step count is
  recorded);
* **mode comparison** — the same workload through the GSPMD and explicit
  decode programs, tokens/sec side by side.

Every section records the per-callsite resolved schedule at the actual
decode-regime payload sizes — never the literal ``"auto"`` — and the
module fails with SystemExit(1) if any resolution names an unregistered
schedule (the same gate as ``--autotune``)."""
from __future__ import annotations

import time

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.comm.callsites import DECODE_MOE, DECODE_OUT, DECODE_QKV  # noqa: E402
from repro.comm.engine import CollectiveEngine, schedules_for  # noqa: E402
from repro.compat import make_mesh  # noqa: E402
from repro.configs.qwen3_moe_235b_a22b import tiny  # noqa: E402
from repro.models import moe as MOE  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.kvcache import (PagedCacheConfig, PageAllocator,  # noqa: E402
                                  commit_prefill)
from repro.models.model import build_model  # noqa: E402
from repro.serve import ServeEngine  # noqa: E402
from repro.train.serve import (make_decode_step_explicit,  # noqa: E402
                               make_paged_decode_step, make_prefill_step)

ARCH = "qwen3-moe-235b-a22b"
PAGE = 4


def _resolved_decode(engine, cfg, slots, ndev):
    """Per-callsite resolutions at the decode-regime payloads the explicit
    step actually exchanges (single-token tiles, small batch)."""
    B_loc = max(slots // ndev, 1)
    qkv_bytes = B_loc * 1 * cfg.num_heads * cfg.head_dim * 4
    C = MOE._capacity(cfg, 1)
    moe_bytes = B_loc * cfg.num_experts * C * cfg.d_model * 4

    def a2a(nbytes, cs):
        return engine.schedule_for("all_to_all_tiles", nbytes=nbytes,
                                   axis="x", callsite=cs)

    return ({DECODE_QKV: a2a(qkv_bytes, DECODE_QKV),
             DECODE_OUT: a2a(qkv_bytes, DECODE_OUT),
             DECODE_MOE: a2a(moe_bytes, DECODE_MOE)},
            {"qkv_bytes": qkv_bytes, "moe_bytes": moe_bytes})


def _gate_resolved(section) -> None:
    """SystemExit(1) if any decode-path resolution is unregistered or still
    the literal "auto" — the same gate as ``--autotune``."""
    resolved = (section or {}).get("resolved")
    if not resolved:
        return
    registered = schedules_for("all_to_all_tiles")
    bad = [(cs, name) for cs, name in resolved.items()
           if name == "auto" or name not in registered]
    if bad:
        print("UNREGISTERED decode-path resolutions:", bad)
        raise SystemExit(1)


def _prefill_pages(model, pcfg, params, prompts, max_new):
    """Dense prefill each prompt into a fresh page pool; returns the pool,
    the allocator, and the first sampled token per slot."""
    B, S0 = prompts.shape
    prefill = make_prefill_step(model, None)
    alloc = PageAllocator(pcfg)
    pages = T.init_paged_cache(model.cfg, pcfg, jnp.float32)
    first = np.zeros((B, 1), np.int32)
    for b in range(B):
        slot = alloc.allocate(S0 + max_new)
        c1 = model.init_cache(1, S0, jnp.float32)
        lg, c1 = prefill(params, {"tokens": prompts[b:b + 1]}, c1)
        pages["layers"] = commit_prefill(
            pages["layers"], c1["layers"],
            jnp.asarray(alloc.block_table[slot]), S0,
            page_size=pcfg.page_size)
        alloc.commit(slot, S0)
        first[slot, 0] = int(jnp.argmax(lg[0, -1]))
    return pages, alloc, first


def _equivalence_section(quick: bool, schedule):
    """Explicit-vs-GSPMD paged decode from identical pages."""
    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"explicit decode needs >= 2 devices, have {ndev}"}

    requested = schedule or "auto"
    cfg = tiny(ndev)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    mesh = make_mesh((ndev,), ("x",))
    engine = CollectiveEngine.for_mesh(mesh, schedule=requested)

    B, S0 = ndev, 5
    # >= 3: the explicit step compiles twice (unsharded pages on the first
    # call, engine-sharded thereafter) before reaching steady state
    steps = 3 if quick else 4
    pcfg = PagedCacheConfig(page_size=PAGE, max_slots=B, max_seq=S0 + steps,
                            num_pages=B * (-(-(S0 + steps) // PAGE)))
    prompts = jax.random.randint(jax.random.key(1), (B, S0), 0,
                                 cfg.vocab_size).astype(jnp.int32)
    pages_g, alloc, first = _prefill_pages(model, pcfg, params, prompts, steps)
    pages_e = jax.tree.map(lambda a: a.copy(), pages_g)

    pd_g = make_paged_decode_step(model, None)
    pd_e = make_decode_step_explicit(model, mesh, engine=engine,
                                     schedule=schedule)
    tok = first.copy()
    logits_err = cache_err = 0.0
    t_g = []
    t_e = []
    for _ in range(steps):
        bt, ln = alloc.device_tables()
        t0 = time.perf_counter()
        lg, pages_g = jax.block_until_ready(
            pd_g(params, jnp.asarray(tok), pages_g, bt, ln))
        t_g.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        le, pages_e = jax.block_until_ready(
            pd_e(params, jnp.asarray(tok), pages_e, bt, ln))
        t_e.append(time.perf_counter() - t0)
        logits_err = max(logits_err, float(jnp.max(jnp.abs(lg - le))))
        cache_err = max(cache_err,
                        max(float(jnp.max(jnp.abs(x - y))) for x, y in
                            zip(jax.tree.leaves(pages_g),
                                jax.tree.leaves(pages_e))))
        for s in range(B):
            alloc.append(s)
        tok = np.asarray(jnp.argmax(lg[:, -1], -1), np.int32)[:, None]
    resolved, payloads = _resolved_decode(engine, cfg, B, ndev)
    return {
        "arch": ARCH, "devices": ndev, "slots": B, "steps": steps,
        "schedule": resolved[DECODE_QKV], "schedule_requested": requested,
        # steady-state per-token step time: the first call carries compile
        "t_gspmd_s": min(t_g), "t_explicit_s": min(t_e), "time": min(t_e),
        "max_logits_err": logits_err, "max_cache_err": cache_err,
        "resolved": resolved, **payloads,
    }


def _serve_workload(rng, cfg, n_requests, pmax):
    return [rng.integers(0, cfg.vocab_size,
                         size=(int(rng.integers(max(pmax // 2, 1),
                                                pmax + 1)),)).astype(np.int32)
            for _ in range(n_requests)]


def _run_engine(model, params, pcfg, prompts, max_new, **kw):
    eng = ServeEngine(model, params, pcfg, **kw)
    t0 = time.perf_counter()
    out, stats = eng.run(prompts, max_new_tokens=max_new, collect_stats=True)
    wall = time.perf_counter() - t0
    dec = [(s["decode_s"], s["decode_tokens"])
           for s in stats if s["decode_tokens"]]
    decode_tokens = sum(n for _, n in dec)
    # the first decode batch carries jit compile and the second a reshard
    # recompile (explicit mode): report the first separately and compute
    # throughput/percentiles over the steady-state samples
    steady = dec[2:] or dec[1:] or dec
    lat = sorted(t for t, _ in steady)
    new_tokens = sum(out[r].shape[0] - p.shape[0]
                     for r, p in enumerate(prompts))
    return {
        "requests": len(prompts), "new_tokens": new_tokens,
        "steps": len(stats), "wall_s": wall,
        "mixed_steps": sum(1 for s in stats
                           if s["prefills"] and s["decode_tokens"]),
        "decode_tokens": decode_tokens,
        "tok_per_s": sum(n for _, n in steady) / max(sum(lat), 1e-9),
        "first_decode_s": dec[0][0] if dec else 0.0,
        "p50_token_s": lat[len(lat) // 2],
        "p99_token_s": lat[min(int(len(lat) * 0.99), len(lat) - 1)],
    }


def _batch_sweep_section(quick: bool, schedule):
    """ServeEngine throughput/latency vs slot count (explicit decode)."""
    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": f"explicit serve needs >= 2 devices, have {ndev}"}

    requested = schedule or "auto"
    cfg = tiny(ndev)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    mesh = make_mesh((ndev,), ("x",))
    engine = CollectiveEngine.for_mesh(mesh, schedule=requested)

    pmax, max_new = 8, (4 if quick else 8)
    max_seq = pmax + max_new
    slot_counts = (ndev,) if quick else (ndev, 2 * ndev)
    rng = np.random.default_rng(0)
    sweep = {}
    for slots in slot_counts:
        pcfg = PagedCacheConfig(
            page_size=PAGE, max_slots=slots, max_seq=max_seq,
            num_pages=slots * (-(-max_seq // PAGE)))
        prompts = _serve_workload(rng, cfg, 2 * slots, pmax)
        row = _run_engine(model, params, pcfg, prompts, max_new,
                          mode="explicit", mesh=mesh, engine=engine,
                          schedule=schedule,
                          prefill_token_budget=2 * pmax)
        sweep[slots] = row

    # mode comparison at the smallest batch: GSPMD vs explicit, same work
    slots = slot_counts[0]
    pcfg = PagedCacheConfig(
        page_size=PAGE, max_slots=slots, max_seq=max_seq,
        num_pages=slots * (-(-max_seq // PAGE)))
    gspmd = _run_engine(model, params, pcfg,
                        _serve_workload(np.random.default_rng(0), cfg,
                                        2 * slots, pmax),
                        max_new, mode="gspmd", prefill_token_budget=2 * pmax)

    resolved, payloads = _resolved_decode(engine, cfg, slot_counts[0], ndev)
    return {
        "arch": ARCH, "devices": ndev, "max_new": max_new,
        "schedule": resolved[DECODE_QKV], "schedule_requested": requested,
        "time": sweep[slot_counts[0]]["p50_token_s"],
        "sweep": {str(k): v for k, v in sweep.items()},
        "gspmd": gspmd, "resolved": resolved, **payloads,
    }


def main(quick: bool = False, schedule=None):
    record = {}

    eq = _equivalence_section(quick, schedule)
    record["decode_equivalence"] = eq
    if "skipped" in eq:
        print(f"-- decode equivalence: {eq['skipped']} --")
    else:
        print("-- explicit-vs-GSPMD paged decode (engine-routed) --")
        print(table(
            [[eq["arch"], eq["slots"], f"{eq['t_gspmd_s']*1e3:.1f}ms",
              f"{eq['t_explicit_s']*1e3:.1f}ms",
              f"{eq['max_logits_err']:.2e}", f"{eq['max_cache_err']:.2e}"]],
            ["arch", "slots", "gspmd/tok", "explicit/tok", "max|dlogits|",
             "max|dcache|"]))
        print("   resolved: " + " ".join(
            f"{cs}={name}" for cs, name in sorted(eq["resolved"].items())))
    _gate_resolved(eq)

    sweep = _batch_sweep_section(quick, schedule)
    record["batch_sweep"] = sweep
    if "skipped" in sweep:
        print(f"\n-- batch sweep: {sweep['skipped']} --")
    else:
        print("\n-- continuous batching: tokens/sec + per-token latency "
              "vs batch size (explicit decode) --")
        rows = [[slots, r["requests"], r["mixed_steps"],
                 f"{r['tok_per_s']:.1f}", f"{r['p50_token_s']*1e3:.2f}ms",
                 f"{r['p99_token_s']*1e3:.2f}ms"]
                for slots, r in sweep["sweep"].items()]
        g = sweep["gspmd"]
        rows.append([f"{list(sweep['sweep'])[0]} (gspmd)", g["requests"],
                     g["mixed_steps"], f"{g['tok_per_s']:.1f}",
                     f"{g['p50_token_s']*1e3:.2f}ms",
                     f"{g['p99_token_s']*1e3:.2f}ms"])
        print(table(rows, ["slots", "reqs", "mixed", "tok/s", "p50/tok",
                           "p99/tok"]))
        print("   resolved: " + " ".join(
            f"{cs}={name}" for cs, name in sorted(sweep["resolved"].items())))
    _gate_resolved(sweep)

    save_result("serve_bench", record)
    return record


if __name__ == "__main__":
    main()
