"""Paper Fig. 12 + Eqs. 5/6 — PTRANS strong/weak scaling over the device
grid, both backends, with the block-time model overlay."""
from __future__ import annotations

from benchmarks.common import ensure_devices, save_result, table

ensure_devices()

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.core import models  # noqa: E402
from repro.core.ptrans import run_ptrans  # noqa: E402
from repro.launch.mesh import make_torus_mesh  # noqa: E402


def main(quick: bool = False, schedule=None, pipeline=None):
    n_dev = len(jax.devices())
    grids = [g for g in (1, 2, 3) if g * g <= n_dev]
    n_base = 256 if quick else 512
    b = 64
    reps = 2
    # pipeline = exchange chunk count (run.py --sweep-schedules S column);
    # None keeps the cost-model resolution. A pipeline sweep pass skips the
    # configurations the chunk count cannot affect (the 1x1 grid has no
    # exchange to chunk).
    nchunks = "auto" if pipeline in (None, "auto") else int(pipeline)
    if pipeline is not None:
        grids = [g for g in grids if g > 1]

    print("== PTRANS scaling (paper Fig. 12) ==")
    record = {}
    # HOST_STAGED forces the `staged` schedule, so an explicit other
    # schedule (e.g. a --sweep-schedules pass) would re-run byte-identical
    # host-staged configs — skip them in that case
    comms = ((CT.ICI_DIRECT,) if schedule not in (None, "auto", "staged")
             else (CT.ICI_DIRECT, CT.HOST_STAGED))
    for label, strong in (("strong", True), ("weak", False)):
        rows = []
        base_perf = {}
        for ct in comms:
            for g in grids:
                n = n_base if strong else n_base * g
                if n % (g * b):
                    continue
                mesh = make_torus_mesh(g)
                res = run_ptrans(mesh, ct, n=n, b=b, reps=reps,
                                 schedule=schedule or "auto",
                                 nchunks=nchunks)
                record[f"{label}/{ct.value}/g{g}"] = {
                    "n": n, "gflops": res.metric, "err": res.error,
                    "time": res.times["best"],
                    "nchunks": res.details["nchunks"],
                    "schedule": res.details["schedule"]}
                if g == grids[0]:
                    base_perf[ct.value] = res.metric
                speedup = res.metric / base_perf[ct.value]
                model_t = models.ptrans_block_time(
                    b, 4, staged=(ct is CT.HOST_STAGED))
                rows.append([label, ct.value, f"{g}x{g}", n,
                             f"{res.metric:.3f}", f"{speedup:.2f}x",
                             f"{res.error:.2e}", f"{model_t*1e6:.1f}us"])
        print(table(rows, ["scaling", "backend", "grid", "n", "GFLOP/s",
                           "speedup", "max_err", "model_t/blk(v5e)"]))
        print()
    save_result("ptrans_scaling", record)
    return record


if __name__ == "__main__":
    main()
