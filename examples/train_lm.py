"""End-to-end training driver (deliverable b): train a ~100M-param dense LM
for a few hundred steps with checkpointing, fault tolerance, and the
(data, model) mesh over the local placeholder devices.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch llama3-8b]

The config is the assigned arch's family scaled to ~100M params (what fits
a CPU run); on a real pod the same script runs the full config by passing
--full (see repro/launch/train.py for the production launcher).
"""
import argparse
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.configs import RunConfig, get_config, reduced  # noqa: E402
from repro.data import DataConfig, SyntheticLMDataset  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.train.loop import TrainLoopConfig, train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/hpccjax_train_lm")
    args = ap.parse_args()

    # ~100M params: d_model 512, 8 layers of the chosen family
    cfg = reduced(get_config(args.arch), layers=8, d_model=512, vocab=8192)
    n_params = cfg.param_count()
    print(f"arch family {cfg.family}, params ~{n_params/1e6:.1f}M")

    run = RunConfig(learning_rate=3e-4, warmup_steps=args.steps // 10,
                    checkpoint_dir=args.ckpt, checkpoint_every=50,
                    remat="none")
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch,
                      seq_len=args.seq)
    mesh = make_local_mesh()
    print("mesh:", dict(mesh.shape))

    hist = train_loop(cfg, run, data, TrainLoopConfig(steps=args.steps,
                                                      log_every=20),
                      mesh=mesh)
    floor = SyntheticLMDataset(data).entropy_floor()
    print(f"\nloss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"(dataset entropy floor ~{floor:.3f})")
    print("median step:",
          f"{sorted(hist['step_time'])[len(hist['step_time'])//2]*1e3:.0f} ms")
    print("straggler summary:", hist["straggler"])


if __name__ == "__main__":
    main()
