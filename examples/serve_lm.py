"""Batched serving example (deliverable b): prefill + streamed decode with a
KV cache, greedy and sampled, for any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch jamba-1.5-large-398b
"""
import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, list_archs, reduced  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.train.serve import generate  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_patches, cfg.vision_dim)), jnp.float32)
    if cfg.is_encoder_decoder:
        extras["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.audio_ctx, cfg.d_model)), jnp.float32)

    for temp, label in ((0.0, "greedy"), (args.temperature, "sampled")):
        t0 = time.perf_counter()
        out = jax.block_until_ready(
            generate(model, params, prompts, max_new_tokens=args.max_new,
                     temperature=temp, extras=extras,
                     key=jax.random.key(7)))
        dt = time.perf_counter() - t0
        toks = args.batch * args.max_new
        print(f"{label:8s}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s incl. compile)")
        print("  first row:", np.asarray(out[0, args.prompt_len:]).tolist())


if __name__ == "__main__":
    main()
