"""Run the full HPCC-JAX suite — every benchmark, both communication
backends — and print a paper-style summary table (§3 of the paper).

    PYTHONPATH=src python examples/hpcc_suite.py [--quick]
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.core.beff import run_beff  # noqa: E402
from repro.core.fft import run_fft  # noqa: E402
from repro.core.gemm import run_gemm  # noqa: E402
from repro.core.hpl import run_hpl  # noqa: E402
from repro.core.hpl_blocked import run_hpl_single  # noqa: E402
from repro.core.ptrans import run_ptrans  # noqa: E402
from repro.core.randomaccess import run_randomaccess  # noqa: E402
from repro.core.stream import run_stream  # noqa: E402
from repro.launch.mesh import make_ring_mesh, make_torus_mesh  # noqa: E402


def main():
    quick = "--quick" in sys.argv
    ring = make_ring_mesh()
    torus = make_torus_mesh(2)
    n = 256 if quick else 512

    rows = []
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        r = run_beff(ring, ct, max_log=8 if quick else 12, reps=1, rounds=2)
        rows.append(("b_eff", ct.value, f"{r.metric/1e6:.2f} MB/s", r.error))
        r = run_ptrans(torus, ct, n=n, b=64, reps=1)
        rows.append(("ptrans", ct.value, f"{r.metric:.3f} GFLOP/s", r.error))
        r = run_hpl(torus, ct, n=n, b=64,
                    schedule="native" if ct is CT.ICI_DIRECT else "staged",
                    reps=1)
        rows.append(("hpl", ct.value, f"{r.metric:.3f} GFLOP/s", r.error))

    r = run_hpl_single(n=n, b=64, reps=1)
    rows.append(("hpl_single", "-", f"{r.metric:.3f} GFLOP/s", r.error))
    r = run_stream(ring, elems_per_device=1 << (16 if quick else 20))
    rows.append(("stream", "-", f"{r.metric/1e9:.2f} GB/s", r.error))
    r = run_randomaccess(ring, table_log=14 if quick else 20)
    rows.append(("randomaccess", "-", f"{r.metric*1e3:.3f} MUPS", r.error))
    r = run_fft(ring, log_size=8 if quick else 12)
    rows.append(("fft", "-", f"{r.metric:.2f} GFLOP/s", r.error))
    r = run_gemm(ring, m=128 if quick else 256)
    rows.append(("gemm", "-", f"{r.metric:.2f} GFLOP/s", r.error))

    print(f"\n{'benchmark':14s} {'backend':12s} {'metric':>18s} {'error':>10s}")
    print("-" * 58)
    for name, backend, metric, err in rows:
        print(f"{name:14s} {backend:12s} {metric:>18s} {err:10.2e}")


if __name__ == "__main__":
    main()
