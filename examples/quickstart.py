"""Quickstart: the paper's three benchmarks + a tiny LM, in one script.

    PYTHONPATH=src python examples/quickstart.py

Runs on 8 placeholder CPU devices: b_eff over a ring, PTRANS + HPL over a
2x2 torus (both communication backends, like the paper's PCIe+MPI vs IEC),
then 20 training steps of a reduced llama-family model.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.comm.types import CommunicationType as CT  # noqa: E402
from repro.configs import RunConfig, get_config, reduced  # noqa: E402
from repro.core.beff import run_beff  # noqa: E402
from repro.core.hpl import run_hpl  # noqa: E402
from repro.core.ptrans import run_ptrans  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.launch.mesh import make_ring_mesh, make_torus_mesh  # noqa: E402
from repro.train.loop import TrainLoopConfig, train_loop  # noqa: E402


def main():
    print("== HPCC-JAX quickstart ==")
    ring = make_ring_mesh()
    torus = make_torus_mesh(2)

    print("\n-- b_eff (paper §2.1): ring over", ring.devices.size, "devices --")
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        res = run_beff(ring, ct, max_log=10, reps=1, rounds=2)
        print(f"  {ct.value:12s} b_eff = {res.metric/1e6:8.2f} MB/s "
              f"(errors={res.error})")

    print("\n-- PTRANS (paper §2.2): C = B + A^T on a 2x2 grid --")
    for ct in (CT.ICI_DIRECT, CT.HOST_STAGED):
        res = run_ptrans(torus, ct, n=256, b=64, reps=1)
        print(f"  {ct.value:12s} {res.metric:6.3f} GFLOP/s "
              f"(max err {res.error:.2e})")

    print("\n-- HPL (paper §2.3): LU on a 2x2 torus --")
    for ct, sched in ((CT.ICI_DIRECT, "native"), (CT.ICI_DIRECT, "chain"),
                      (CT.HOST_STAGED, "staged")):
        res = run_hpl(torus, ct, n=256, b=32, schedule=sched, reps=1)
        print(f"  {ct.value:12s}/{sched:6s} {res.metric:6.3f} GFLOP/s "
              f"(residual {res.error:.2e})")

    print("\n-- LM training (reduced llama3.2-3b, 20 steps) --")
    cfg = reduced(get_config("llama3.2-3b"))
    run = RunConfig(learning_rate=1e-3, warmup_steps=4)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=64)
    hist = train_loop(cfg, run, data, TrainLoopConfig(steps=20, log_every=5))
    print(f"  loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")
    print("\nquickstart done.")


if __name__ == "__main__":
    main()
