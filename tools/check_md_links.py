#!/usr/bin/env python
"""Offline markdown link checker for the repo's doc layer.

Verifies, without any network access:

* every relative link target (``[x](docs/ARCHITECTURE.md)``,
  ``[y](../README.md#anchor)``) exists on disk relative to the file
  containing the link;
* every anchor (``#section-name``, same-file or cross-file) matches a
  heading in the target file, using GitHub's slug rules (lowercase,
  punctuation stripped, spaces to dashes);
* ``http(s)://`` and ``mailto:`` links are skipped (no network in CI).

Run directly (``python tools/check_md_links.py [files...]``; defaults to
README.md, ROADMAP.md, and docs/*.md from the repo root) or through
``tests/test_docs.py``. Exits 1 listing every broken link.
"""
from __future__ import annotations

import glob
import os
import re
import sys
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — ignore images' leading ! by just not caring about it;
# the target existence check is identical
_LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slug: strip markup/punctuation, lowercase,
    spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # unwrap links
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = _CODE_FENCE_RE.sub("", f.read())
    return {github_slug(h) for h in _HEADING_RE.findall(text)}


def links_of(md_path: str) -> List[str]:
    with open(md_path, encoding="utf-8") as f:
        text = _CODE_FENCE_RE.sub("", f.read())
    return _LINK_RE.findall(text)


def check_file(md_path: str) -> List[Tuple[str, str]]:
    """Returns (link, problem) pairs for every broken link in ``md_path``."""
    problems = []
    base = os.path.dirname(os.path.abspath(md_path))
    for link in links_of(md_path):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = link.partition("#")
        target = (os.path.normpath(os.path.join(base, path_part))
                  if path_part else os.path.abspath(md_path))
        if not os.path.exists(target):
            problems.append((link, f"target does not exist: {target}"))
            continue
        if anchor and target.endswith(".md"):
            found = anchors_of(target)
            if anchor not in found:
                problems.append(
                    (link, f"anchor #{anchor} not among headings of "
                           f"{os.path.relpath(target, REPO)} "
                           f"(have: {sorted(found)})"))
    return problems


def default_files() -> List[str]:
    files = [os.path.join(REPO, "README.md"), os.path.join(REPO, "ROADMAP.md")]
    files += sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def main(argv: List[str]) -> int:
    files = argv or default_files()
    bad = 0
    for f in files:
        for link, problem in check_file(f):
            print(f"{os.path.relpath(f, REPO)}: [{link}] {problem}")
            bad += 1
    print(f"checked {len(files)} files: "
          f"{'OK' if not bad else f'{bad} broken link(s)'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
