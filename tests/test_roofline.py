"""Roofline HLO-parser unit tests against hand-written HLO snippets."""
from __future__ import annotations

import numpy as np
import pytest

from repro import roofline as rl


def test_shape_bytes():
    assert rl.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert rl.shape_bytes("bf16[10]") == 20
    assert rl.shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert rl.shape_bytes("pred[]") == 1
    assert rl.shape_bytes("token[]") == 0


def test_shape_dims():
    assert rl.shape_dims("f32[128,256]{1,0}") == [128, 256]
    assert rl.shape_dims("bf16[]") == []


SIMPLE = """
HloModule test

ENTRY %main (p0: f32[64,32], p1: f32[32,16]) -> f32[64,16] {
  %p0 = f32[64,32]{1,0} parameter(0)
  %p1 = f32[32,16]{1,0} parameter(1)
  ROOT %dot.1 = f32[64,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops_simple():
    stats = rl.analyze_hlo(SIMPLE)
    assert stats.flops == 2 * 64 * 16 * 32
    # traffic: result + both operands
    assert stats.hbm_bytes == (64 * 16 + 64 * 32 + 32 * 16) * 4


LOOPED = """
HloModule test

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %ip = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %d)
}

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%c0, %p)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_loop_multiplier():
    stats = rl.analyze_hlo(LOOPED)
    assert stats.flops == 10 * 2 * 8 * 8 * 8
    assert stats.unresolved_loops == 0


def test_while_loop_condition_fallback():
    """Without backend_config the trip count comes from the cond constant."""
    text = LOOPED.replace(', backend_config={"known_trip_count":{"n":"10"}}', "")
    stats = rl.analyze_hlo(text)
    assert stats.flops == 10 * 2 * 8 * 8 * 8


COLLECTIVES = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[4096]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[4096]{0} collective-permute(%ag), source_target_pairs={{0,1},{1,2}}
  ROOT %out = f32[1024]{0} slice(%cp), slice={[0:1024]}
}
"""


def test_collective_bytes_and_wire_factors():
    stats = rl.analyze_hlo(COLLECTIVES)
    b = stats.operand_bytes
    assert b["all-reduce"] == 1024 * 4
    assert b["all-gather"] == 1024 * 4       # operand (shard) size
    assert b["collective-permute"] == 4096 * 4
    # wire: AR 2(n-1)/n, AG (n-1)/n with n=4; permute 1x
    want = 1024 * 4 * 2 * 3 / 4 + 1024 * 4 * 3 / 4 + 4096 * 4
    np.testing.assert_allclose(stats.wire_bytes, want)
    assert stats.collective_count == 3


DUS_FUSION = """
HloModule test

%fused_dus (a: f32[64,64], u: f32[1,64], i: s32[]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %dus = f32[64,64]{1,0} dynamic-update-slice(%a, %u, %i, %z)
}

ENTRY %main (p: f32[64,64], u: f32[1,64], i: s32[]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[64,64]{1,0} fusion(%p, %u, %i), kind=kLoop, calls=%fused_dus
}
"""


def test_inplace_dus_fusion_counts_slice_only():
    stats = rl.analyze_hlo(DUS_FUSION)
    assert stats.hbm_bytes == 2 * 1 * 64 * 4  # read+write the slice, not 16KiB


def test_wire_factor_values():
    assert rl._wire_factor("all-reduce", 4) == pytest.approx(1.5)
    assert rl._wire_factor("all-gather", 8) == pytest.approx(7 / 8)
    assert rl._wire_factor("reduce-scatter", 2) == pytest.approx(0.5)
    assert rl._wire_factor("collective-permute", 2) == 1.0


def test_model_flops_for():
    from repro.configs import get_config
    cfg = get_config("llama3-8b")
    n = cfg.param_count(active_only=True)
    assert rl.model_flops_for(cfg, "train", 256, 4096) == 6.0 * n * 256 * 4096
    assert rl.model_flops_for(cfg, "decode", 128, 32768) == 2.0 * n * 128
