"""The assigned architecture table, verified literally (deliverable f)."""
from __future__ import annotations

import pytest

from repro.configs import SHAPES, cell_is_applicable, get_config, list_archs

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment
ASSIGNED = {
    "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
}

MOE = {
    "llama4-maverick-400b-a17b": (128, 1),
    "qwen3-moe-235b-a22b": (128, 8),
    "jamba-1.5-large-398b": (16, 2),
}


def test_all_archs_present():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    if arch in MOE:
        e, k = MOE[arch]
        assert cfg.num_experts == e
        assert cfg.num_experts_per_tok == k


def test_mamba_is_attention_free():
    cfg = get_config("mamba2-130m")
    assert cfg.is_attention_free
    assert cfg.ssm_state == 128
    assert all(k == "ssm" for k in cfg.layer_kinds())


def test_jamba_interleave():
    """Jamba: 1 attention layer per 8-block (1:7 mamba:attn interleave)."""
    cfg = get_config("jamba-1.5-large-398b")
    kinds = cfg.layer_kinds()
    assert kinds.count("attn") == cfg.num_layers // 8
    assert sum(cfg.moe_layer_mask()) == cfg.num_layers // 2


def test_whisper_is_enc_dec():
    cfg = get_config("whisper-base")
    assert cfg.is_encoder_decoder
    assert cfg.num_encoder_layers == 6
    assert cfg.audio_ctx > 0


def test_vision_cross_attn():
    cfg = get_config("llama-3.2-vision-90b")
    assert cfg.cross_attn_every > 0
    assert cfg.vision_dim > 0 and cfg.num_patches > 0
    assert sum(cfg.cross_attn_mask()) == cfg.num_layers // cfg.cross_attn_every


# param counts vs public numbers (names encode the sizes)
PARAM_BOUNDS = {
    "deepseek-7b": (6e9, 8e9),
    "llama3.2-3b": (3e9, 4.2e9),
    "llama3-8b": (7e9, 9e9),
    "qwen1.5-32b": (29e9, 36e9),
    "mamba2-130m": (1.1e8, 1.6e8),
    "llama4-maverick-400b-a17b": (3.4e11, 4.6e11),
    "qwen3-moe-235b-a22b": (2.0e11, 2.7e11),
    "jamba-1.5-large-398b": (3.3e11, 4.4e11),
    "llama-3.2-vision-90b": (7.4e10, 1.0e11),
}


@pytest.mark.parametrize("arch", sorted(PARAM_BOUNDS))
def test_param_count_in_band(arch):
    lo, hi = PARAM_BOUNDS[arch]
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n:.3g} not in [{lo:.3g}, {hi:.3g}]"


ACTIVE_BOUNDS = {
    "llama4-maverick-400b-a17b": (1.2e10, 2.2e10),   # a17b
    "qwen3-moe-235b-a22b": (1.7e10, 2.7e10),         # a22b
}


@pytest.mark.parametrize("arch", sorted(ACTIVE_BOUNDS))
def test_active_param_count(arch):
    lo, hi = ACTIVE_BOUNDS[arch]
    n = get_config(arch).param_count(active_only=True)
    assert lo <= n <= hi, f"{arch}: active {n:.3g} not in [{lo:.3g}, {hi:.3g}]"


def test_long_context_applicability():
    """long_500k runs only for SSM/hybrid (DESIGN.md §Arch-applicability)."""
    long = SHAPES["long_500k"]
    runnable = [a for a in list_archs()
                if cell_is_applicable(get_config(a), long)[0]]
    assert sorted(runnable) == ["jamba-1.5-large-398b", "mamba2-130m"]


def test_total_cells():
    """40 assigned cells: 32 runnable + 8 documented long_500k skips."""
    total = runnable = 0
    for a in list_archs():
        for s in SHAPES.values():
            total += 1
            if cell_is_applicable(get_config(a), s)[0]:
                runnable += 1
    assert total == 40
    assert runnable == 32
