"""Cost-model schedule-autotuning tests (single device).

The model must be a *pure function of static data* — the same (op, bytes,
topology) resolves to the same schedule in every process — and its rankings
must pin the paper's qualitative regimes: store-and-forward chains win the
latency-bound small-message end, ring schedules win the bandwidth-bound
large-message end, and the winner flips in between (paper Figs. 4-7).

Multi-device *output equivalence* of auto vs fixed schedules runs in
tests/dist/test_autotune.py on the simulated 8-device mesh.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.comm.autotune import (LOSSY_SCHEDULES, MAX_BUCKET_BYTES,
                                 MIN_BUCKET_BYTES, CostModel, TuningTable,
                                 axis_signature, derive_bucket_bytes)
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.comm.topology import AxisTopology, MeshTopology
from repro.comm.types import TPU_V5E

RING8 = (AxisTopology("x", 8, "ring"),)
RING4 = (AxisTopology("x", 4, "ring"),)
RING2 = (AxisTopology("x", 2, "ring"),)
TORUS22 = (AxisTopology("rows", 2, "torus_row"),
           AxisTopology("cols", 2, "torus_col"))

KiB = 1 << 10
MiB = 1 << 20


def analytic():
    """A table-free model: the persisted tuning.json must not leak into the
    ranking pins below."""
    return CostModel(hw=TPU_V5E, table=None)


# ---------------------------------------------------------------------------
# analytic-model structure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op,schedule", [
    ("bcast", "chain"), ("bcast", "native"), ("bcast", "ring2d"),
    ("allreduce", "chain"), ("allreduce", "native"), ("allreduce", "rs_ag"),
    ("allreduce", "ring2d"), ("allreduce", "staged"),
    ("grid_transpose", "direct"), ("grid_transpose", "ring2d"),
    ("ring_exchange", "direct"),
])
def test_cost_monotone_in_message_size(op, schedule):
    m = analytic()
    axes = TORUS22 if op == "grid_transpose" else RING8
    costs = [m.cost(op, schedule, s, axes)
             for s in (KiB, 64 * KiB, MiB, 64 * MiB)]
    assert all(a < b for a, b in zip(costs, costs[1:])), (op, schedule, costs)


@pytest.mark.parametrize("op,schedule", [
    ("bcast", "chain"), ("bcast", "native"), ("bcast", "ring2d"),
    ("allreduce", "chain"), ("allreduce", "native"), ("allreduce", "rs_ag"),
])
def test_cost_monotone_in_hop_count(op, schedule):
    m = analytic()
    for size in (KiB, MiB):
        c2 = m.cost(op, schedule, size, RING2)
        c4 = m.cost(op, schedule, size, RING4)
        c8 = m.cost(op, schedule, size, RING8)
        assert c2 < c4 < c8, (op, schedule, size, (c2, c4, c8))


def test_unpriced_schedule_is_infinite_and_never_chosen():
    m = analytic()
    assert m.cost("allreduce", "no_such_schedule", MiB, RING8) == float("inf")
    names = [n for n, _ in m.rank("allreduce", MiB, RING8)]
    assert "no_such_schedule" not in names


# ---------------------------------------------------------------------------
# regime pins (acceptance: >= 3 (op, size, topology) regimes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op,size,axes,winner", [
    # latency-bound: the store-and-forward chain beats native's dispatch
    # overhead (the paper's CSN-beats-MPI small-message regime)
    ("allreduce", KiB, RING8, "chain"),
    ("bcast", KiB, RING8, "chain"),
    # bandwidth-bound allreduce: XLA's bidirectional ring wins
    ("allreduce", 64 * MiB, RING8, "native"),
    # bandwidth-bound bcast: the two-phase scatter/all-gather ring halves
    # the wire vs native's garbage-gather, and chain's (n-1)x full payload
    ("bcast", 64 * MiB, RING8, "ring2d"),
    # transpose: the point-to-point partner exchange always beats the
    # stacked two-phase relay (paper Fig. 8's route costs (pg-1)(1+pg) S)
    ("grid_transpose", MiB, TORUS22, "direct"),
])
def test_regime_pins(op, size, axes, winner):
    m = analytic()
    ranked = m.rank(op, size, axes)
    assert ranked[0][0] == winner, (op, size, ranked)
    assert m.choose(op, size, axes) == winner


def test_regime_flips_with_message_size():
    """The winner must actually flip across the ladder (paper Figs. 4-7)."""
    m = analytic()
    small = m.choose("allreduce", KiB, RING8)
    large = m.choose("allreduce", 64 * MiB, RING8)
    assert small != large


def test_auto_never_selects_lossy():
    m = analytic()
    for size in (KiB, 64 * KiB, MiB, 64 * MiB):
        names = [n for n, _ in m.rank("allreduce", size, RING8)]
        assert not (set(names) & LOSSY_SCHEDULES)
        assert m.choose("allreduce", size, RING8) not in LOSSY_SCHEDULES


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

_PROBE = [("bcast", lg) for lg in range(0, 27, 3)] + \
         [("allreduce", lg) for lg in range(0, 27, 3)] + \
         [("ring_exchange", lg) for lg in (4, 16, 24)]

_PROBE_SRC = """
import json
from repro.comm.autotune import default_cost_model
from repro.comm.topology import AxisTopology
ring = (AxisTopology("x", 8, "ring"),)
m = default_cost_model()
probe = {probe!r}
print(json.dumps({{f"{{op}}:{{lg}}": m.choose(op, 1 << lg, ring)
                   for op, lg in probe}}))
"""


def _probe_choices():
    from repro.comm.autotune import default_cost_model
    m = default_cost_model(refresh=True)
    return {f"{op}:{lg}": m.choose(op, 1 << lg, RING8) for op, lg in _PROBE}


def test_auto_resolution_deterministic_across_processes():
    """auto must resolve identically in every process (SPMD ranks compile
    the same program): compare this process's choices against a fresh
    interpreter's."""
    here = _probe_choices()
    env = dict(os.environ)
    src_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src_dir, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c", _PROBE_SRC.format(probe=_PROBE)],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    there = json.loads(proc.stdout.strip().splitlines()[-1])
    assert here == there


def test_choose_is_cached_and_stable():
    m = analytic()
    first = m.choose("allreduce", MiB, RING8)
    assert m.choose("allreduce", MiB, RING8) == first
    assert analytic().choose("allreduce", MiB, RING8) == first


# ---------------------------------------------------------------------------
# tuning table round-trip
# ---------------------------------------------------------------------------


def _synthetic_table():
    t = TuningTable(meta={"devices": 8})
    sig = axis_signature(RING8)
    t.set("allreduce", sig, [(64 * KiB, "rs_ag"), (None, "ring2d")])
    t.set("bcast", sig, [(None, "native")])
    return t


def test_tuning_table_roundtrip(tmp_path):
    table = _synthetic_table()
    path = table.save(tmp_path / "tuning.json")
    loaded = TuningTable.load(path)
    assert loaded is not None
    assert loaded.to_json() == table.to_json()

    before = CostModel(table=table)
    after = CostModel(table=loaded)
    for size in (KiB, 64 * KiB, 65 * KiB, 64 * MiB):
        want = before.choose("allreduce", size, RING8)
        assert after.choose("allreduce", size, RING8) == want
    # the measured table overrides the analytic ranking where it has entries
    assert after.choose("allreduce", KiB, RING8) == "rs_ag"
    assert after.choose("allreduce", 64 * MiB, RING8) == "ring2d"
    assert after.choose("bcast", 64 * MiB, RING8) == "native"


def test_tuning_table_band_boundaries():
    t = _synthetic_table()
    sig = axis_signature(RING8)
    assert t.lookup("allreduce", sig, 64 * KiB) == "rs_ag"      # inclusive
    assert t.lookup("allreduce", sig, 64 * KiB + 1) == "ring2d"
    assert t.lookup("allreduce", "ring[4]", KiB) is None        # unknown sig
    assert t.lookup("grid_transpose", sig, KiB) is None         # unknown op


def test_stale_table_entry_falls_back_to_analytic():
    t = TuningTable()
    t.set("allreduce", axis_signature(RING8), [(None, "deleted_schedule")])
    m = CostModel(table=t)
    choice = m.choose("allreduce", 64 * MiB, RING8)
    assert choice in schedules_for("allreduce")
    assert choice == analytic().choose("allreduce", 64 * MiB, RING8)


def test_load_missing_table_returns_none(tmp_path):
    assert TuningTable.load(tmp_path / "nope.json") is None


def test_default_model_rejects_foreign_backend_table(tmp_path, monkeypatch):
    """A table measured on another backend (e.g. the CI CPU artifact landing
    on a TPU checkout) must not override the analytic model."""
    from repro.comm.autotune import default_cost_model
    import jax
    try:
        t = _synthetic_table()
        t.meta["backend"] = "definitely_not_" + jax.default_backend()
        monkeypatch.setenv("REPRO_TUNING_TABLE",
                           str(t.save(tmp_path / "foreign.json")))
        assert default_cost_model(refresh=True).table is None

        t.meta["backend"] = jax.default_backend()
        t.save(tmp_path / "foreign.json")
        assert default_cost_model(refresh=True).table is not None
    finally:
        monkeypatch.delenv("REPRO_TUNING_TABLE")
        default_cost_model(refresh=True)  # restore process-wide state


def test_winner_bounds_stay_aligned_with_measured_sizes():
    """Winners pair with the sizes that were actually measured: a failed
    intermediate ladder size must not shift the band boundaries."""
    from repro.comm.autotune import _winner_bounds
    # ladder (1K, 16K, 256K, 4M) with 16K failed -> measured (1K, 256K, 4M)
    bounds = _winner_bounds([1 << 10, 1 << 18, 1 << 22],
                            ["chain", "native", "ring2d"])
    assert bounds == [(int((2 ** 14)), "chain"),
                      (int((2 ** 20)), "native"),
                      (None, "ring2d")]
    # consecutive same winners merge into one band
    assert _winner_bounds([1, 4, 16], ["a", "a", "b"]) == [(8, "a"),
                                                           (None, "b")]
    assert _winner_bounds([1, 4], ["a", "a"]) == [(None, "a")]


# ---------------------------------------------------------------------------
# derived bucket size
# ---------------------------------------------------------------------------


def test_derive_bucket_bytes_bounds_and_monotonicity():
    b1 = derive_bucket_bytes((AxisTopology("x", 1, "ring"),))
    b2 = derive_bucket_bytes(RING2)
    b8 = derive_bucket_bytes(RING8)
    for b in (b1, b2, b8):
        assert MIN_BUCKET_BYTES <= b <= MAX_BUCKET_BYTES
        assert b & (b - 1) == 0, f"{b} is not a power of two"
    assert b2 <= b8  # more hops -> bigger buckets to amortize latency


def test_derive_bucket_bytes_latency_bandwidth_product():
    # depth x 2(n-1) hops x (alpha x beta), rounded up to a power of two:
    # 4 x 14 x (1e-6 s x 50e9 B/s) = 2.8 MB -> 4 MiB on the v5e numbers
    assert derive_bucket_bytes(RING8, TPU_V5E) == 4 * MiB


# ---------------------------------------------------------------------------
# engine integration (no devices needed: resolution is host-side)
# ---------------------------------------------------------------------------


def _engine8(**kw):
    topo = MeshTopology(axes=RING8)
    return CollectiveEngine(schedule="auto", topology=topo,
                            cost_model=analytic(), **kw)


def test_engine_auto_resolves_through_cost_model():
    eng = _engine8()
    assert eng.schedule_for("allreduce", nbytes=KiB, axis="x") == "chain"
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "native"
    assert eng.schedule_for("bcast", nbytes=64 * MiB, axis="x") == "ring2d"
    # the literal "auto" never escapes resolution
    for op in ("bcast", "allreduce", "ring_exchange"):
        for size in (KiB, MiB, 64 * MiB):
            name = eng.schedule_for(op, nbytes=size, axis="x")
            assert name != "auto" and name in schedules_for(op)


def test_engine_auto_without_payload_uses_static_defaults():
    eng = _engine8()
    assert eng.schedule_for("bcast") == "chain"
    assert eng.schedule_for("allreduce") == "native"
    assert eng.schedule_for("allreduce", nbytes=KiB, axis=None) == "native"


def test_engine_auto_unknown_axis_falls_back():
    eng = _engine8()
    assert eng.schedule_for("allreduce", nbytes=KiB, axis="bogus") == "native"


def test_engine_partial_name_falls_back_through_model():
    # rs_ag covers allreduce only: other ops resolve like auto, through the
    # model when payload context exists
    topo = MeshTopology(axes=RING8)
    eng = CollectiveEngine(schedule="rs_ag", topology=topo,
                           cost_model=analytic())
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "rs_ag"
    assert eng.schedule_for("bcast", nbytes=64 * MiB, axis="x") == "ring2d"
    assert eng.schedule_for("bcast", nbytes=KiB, axis="x") == "chain"


def test_engine_bucket_bytes_for():
    eng = _engine8()
    assert eng.bucket_bytes_for("x") == derive_bucket_bytes(RING8, TPU_V5E)
    from repro.comm.overlap import DEFAULT_BUCKET_BYTES
    assert CollectiveEngine().bucket_bytes_for("x") == DEFAULT_BUCKET_BYTES
    assert eng.bucket_bytes_for("bogus") == DEFAULT_BUCKET_BYTES


def test_engine_explicit_override_beats_model():
    eng = _engine8()
    assert eng.schedule_for("allreduce", "chain",
                            nbytes=64 * MiB, axis="x") == "chain"


def test_host_staged_still_forces_staged():
    from repro.comm.types import CommunicationType as CT
    topo = MeshTopology(axes=RING8)
    eng = CollectiveEngine(comm=CT.HOST_STAGED, schedule="auto",
                           topology=topo, cost_model=analytic())
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "staged"
