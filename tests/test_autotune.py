"""Cost-model schedule-autotuning tests (single device).

The model must be a *pure function of static data* — the same (op, bytes,
topology) resolves to the same schedule in every process — and its rankings
must pin the paper's qualitative regimes: store-and-forward chains win the
latency-bound small-message end, ring schedules win the bandwidth-bound
large-message end, and the winner flips in between (paper Figs. 4-7).

Multi-device *output equivalence* of auto vs fixed schedules runs in
tests/dist/test_autotune.py on the simulated 8-device mesh.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.comm.autotune import (LOSSY_SCHEDULES, MAX_BUCKET_BYTES,
                                 MAX_LOOKAHEAD_DEPTH, MAX_PIPELINE_CHUNKS,
                                 MIN_BUCKET_BYTES, CostModel, TuningTable,
                                 axis_signature, best_nchunks,
                                 choose_hpl_depth, derive_bucket_bytes,
                                 pipelined_cost, segments)
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.comm.topology import AxisTopology, MeshTopology
from repro.comm.types import TPU_V5E

RING8 = (AxisTopology("x", 8, "ring"),)
RING4 = (AxisTopology("x", 4, "ring"),)
RING2 = (AxisTopology("x", 2, "ring"),)
TORUS22 = (AxisTopology("rows", 2, "torus_row"),
           AxisTopology("cols", 2, "torus_col"))

KiB = 1 << 10
MiB = 1 << 20


def analytic():
    """A table-free model: the persisted tuning.json must not leak into the
    ranking pins below."""
    return CostModel(hw=TPU_V5E, table=None)


# ---------------------------------------------------------------------------
# analytic-model structure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op,schedule", [
    ("bcast", "chain"), ("bcast", "native"), ("bcast", "ring2d"),
    ("allreduce", "chain"), ("allreduce", "native"), ("allreduce", "rs_ag"),
    ("allreduce", "ring2d"), ("allreduce", "staged"),
    ("grid_transpose", "direct"), ("grid_transpose", "ring2d"),
    ("ring_exchange", "direct"),
])
def test_cost_monotone_in_message_size(op, schedule):
    m = analytic()
    axes = TORUS22 if op == "grid_transpose" else RING8
    costs = [m.cost(op, schedule, s, axes)
             for s in (KiB, 64 * KiB, MiB, 64 * MiB)]
    assert all(a < b for a, b in zip(costs, costs[1:])), (op, schedule, costs)


@pytest.mark.parametrize("op,schedule", [
    ("bcast", "chain"), ("bcast", "native"), ("bcast", "ring2d"),
    ("allreduce", "chain"), ("allreduce", "native"), ("allreduce", "rs_ag"),
])
def test_cost_monotone_in_hop_count(op, schedule):
    m = analytic()
    for size in (KiB, MiB):
        c2 = m.cost(op, schedule, size, RING2)
        c4 = m.cost(op, schedule, size, RING4)
        c8 = m.cost(op, schedule, size, RING8)
        assert c2 < c4 < c8, (op, schedule, size, (c2, c4, c8))


def test_unpriced_schedule_is_infinite_and_never_chosen():
    m = analytic()
    assert m.cost("allreduce", "no_such_schedule", MiB, RING8) == float("inf")
    names = [n for n, _ in m.rank("allreduce", MiB, RING8)]
    assert "no_such_schedule" not in names


# ---------------------------------------------------------------------------
# pipelined pricing (fill cost vs per-chunk latency)
# ---------------------------------------------------------------------------

_PIPE_CASES = [
    ("bcast", "chain", RING8), ("bcast", "native", RING8),
    ("bcast", "ring2d", RING8), ("allreduce", "rs_ag", RING8),
    ("allreduce", "staged", RING8),
    ("grid_transpose", "direct", TORUS22),
    ("grid_transpose", "ring2d", TORUS22),
]


@pytest.mark.parametrize("op,schedule,axes", _PIPE_CASES)
def test_pipelined_cost_with_one_chunk_is_monolithic(op, schedule, axes):
    m = analytic()
    for size in (KiB, MiB, 64 * MiB):
        assert pipelined_cost(op, schedule, size, axes, 1) == \
            pytest.approx(m.cost(op, schedule, size, axes), rel=1e-12)


def test_pipelined_cost_unpriced_is_infinite():
    assert pipelined_cost("allreduce", "no_such", MiB, RING8, 4) \
        == float("inf")
    assert best_nchunks("allreduce", "no_such", MiB, RING8) == \
        (1, float("inf"))


def test_segments_decomposition_matches_cost():
    from repro.comm.types import TPU_V5E
    from repro.roofline import alpha_beta_time
    m = analytic()
    segs = segments("grid_transpose", "ring2d", MiB, TORUS22)
    assert len(segs) == 2  # row phase + column relay phase
    total = sum(alpha_beta_time(h, w, TPU_V5E, staged=k == "staged")
                for h, w, k in segs if k != "sync")
    assert total == pytest.approx(
        m.cost("grid_transpose", "ring2d", MiB, TORUS22))


def test_best_nchunks_regimes():
    """Tiny payloads stay monolithic (fill cost dominates); large payloads
    chunk deeper; the chunk count never exceeds the ceiling and the chosen
    pipeline is never costlier than monolithic."""
    s_small, c_small = best_nchunks("grid_transpose", "direct", KiB, TORUS22)
    assert s_small == 1
    s_mid, c_mid = best_nchunks("grid_transpose", "direct", 256 * KiB,
                                TORUS22)
    s_big, c_big = best_nchunks("grid_transpose", "direct", 16 * MiB,
                                TORUS22)
    assert 1 < s_mid <= s_big <= MAX_PIPELINE_CHUNKS
    for (s, c), size in (((s_mid, c_mid), 256 * KiB),
                         ((s_big, c_big), 16 * MiB)):
        assert c <= pipelined_cost("grid_transpose", "direct", size,
                                   TORUS22, 1)
    # sync-heavy native schedules chunk reluctantly: every chunk re-pays the
    # dispatch surcharge
    s_native, _ = best_nchunks("bcast", "native", 256 * KiB, RING8)
    s_chain, _ = best_nchunks("bcast", "chain", 256 * KiB, RING8)
    assert s_native <= s_chain


def test_choose_hpl_depth_regimes():
    """Latency-bound small blocks on a torus go deep; compute-bound large
    local matrices stay at depth 1; the ceiling holds."""
    m = analytic()
    deep = choose_hpl_depth(b=64, m=1024, axes=TORUS22, model=m)
    shallow = choose_hpl_depth(b=256, m=65536, axes=TORUS22, model=m)
    assert deep == MAX_LOOKAHEAD_DEPTH
    assert shallow == 1
    for b, mm in ((32, 512), (128, 4096), (256, 1 << 17)):
        assert 1 <= choose_hpl_depth(b=b, m=mm, axes=TORUS22, model=m) \
            <= MAX_LOOKAHEAD_DEPTH


def test_choose_hpl_depth_prices_resolved_schedule():
    """A resolve hook naming the schedule the engine actually runs changes
    the depth: forcing the costly staged broadcasts on a config the analytic
    model calls compute-bound pushes t_comm up and the depth deeper — the
    HOST_STAGED / explicit-override case."""
    m = analytic()
    assert choose_hpl_depth(b=256, m=65536, axes=TORUS22, model=m) == 1
    forced = choose_hpl_depth(b=256, m=65536, axes=TORUS22, model=m,
                              resolve=lambda op, nbytes, ax, cs: "staged")
    assert forced > 1
    # an unpriceable schedule (no cost formula -> inf) clamps to the
    # ceiling instead of overflowing on ceil(inf)
    unpriced = choose_hpl_depth(b=256, m=65536, axes=TORUS22, model=m,
                                resolve=lambda op, nbytes, ax, cs: "custom")
    assert unpriced == MAX_LOOKAHEAD_DEPTH


# ---------------------------------------------------------------------------
# regime pins (acceptance: >= 3 (op, size, topology) regimes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op,size,axes,winner", [
    # latency-bound: the store-and-forward chain beats native's dispatch
    # overhead (the paper's CSN-beats-MPI small-message regime)
    ("allreduce", KiB, RING8, "chain"),
    ("bcast", KiB, RING8, "chain"),
    # bandwidth-bound allreduce: XLA's bidirectional ring wins
    ("allreduce", 64 * MiB, RING8, "native"),
    # bandwidth-bound bcast: the two-phase scatter/all-gather ring halves
    # the wire vs native's garbage-gather, and chain's (n-1)x full payload
    ("bcast", 64 * MiB, RING8, "ring2d"),
    # transpose: the point-to-point partner exchange always beats the
    # stacked two-phase relay (paper Fig. 8's route costs (pg-1)(1+pg) S)
    ("grid_transpose", MiB, TORUS22, "direct"),
])
def test_regime_pins(op, size, axes, winner):
    m = analytic()
    ranked = m.rank(op, size, axes)
    assert ranked[0][0] == winner, (op, size, ranked)
    assert m.choose(op, size, axes) == winner


def test_decode_regime_pins():
    """Serving decode payloads live in the latency band. On the 4-rank ring
    the store-and-forward chain beats native's per-tile dispatch overhead
    at every decode-ladder size and flips to native at training sizes; the
    8-rank ring pays (n-2) relay hops per tile, so native holds across the
    whole ladder — the decode callsites resolve per topology, not by size
    alone."""
    from repro.comm.autotune import DECODE_SIZES, DECODE_SIZES_QUICK
    m = analytic()
    for size in DECODE_SIZES:
        assert m.choose("all_to_all_tiles", size, RING4) == "chain", size
    assert m.choose("all_to_all_tiles", 64 * MiB, RING4) == "native"
    for size in DECODE_SIZES + (64 * MiB,):
        assert m.choose("all_to_all_tiles", size, RING8) == "native", size
    # the ladder itself must sit in the latency regime, below the training
    # ladder's bandwidth-bound sizes, and ascend (winner-band construction)
    for ladder in (DECODE_SIZES, DECODE_SIZES_QUICK):
        assert list(ladder) == sorted(ladder)
        assert ladder[-1] <= 64 * KiB


def test_regime_flips_with_message_size():
    """The winner must actually flip across the ladder (paper Figs. 4-7)."""
    m = analytic()
    small = m.choose("allreduce", KiB, RING8)
    large = m.choose("allreduce", 64 * MiB, RING8)
    assert small != large


def test_auto_never_selects_lossy():
    m = analytic()
    for size in (KiB, 64 * KiB, MiB, 64 * MiB):
        names = [n for n, _ in m.rank("allreduce", size, RING8)]
        assert not (set(names) & LOSSY_SCHEDULES)
        assert m.choose("allreduce", size, RING8) not in LOSSY_SCHEDULES


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

_PROBE = [("bcast", lg) for lg in range(0, 27, 3)] + \
         [("allreduce", lg) for lg in range(0, 27, 3)] + \
         [("ring_exchange", lg) for lg in (4, 16, 24)]

_PROBE_SRC = """
import json
from repro.comm.autotune import default_cost_model
from repro.comm.topology import AxisTopology
ring = (AxisTopology("x", 8, "ring"),)
m = default_cost_model()
probe = {probe!r}
print(json.dumps({{f"{{op}}:{{lg}}": m.choose(op, 1 << lg, ring)
                   for op, lg in probe}}))
"""


def _probe_choices():
    from repro.comm.autotune import default_cost_model
    m = default_cost_model(refresh=True)
    return {f"{op}:{lg}": m.choose(op, 1 << lg, RING8) for op, lg in _PROBE}


def test_auto_resolution_deterministic_across_processes():
    """auto must resolve identically in every process (SPMD ranks compile
    the same program): compare this process's choices against a fresh
    interpreter's."""
    here = _probe_choices()
    env = dict(os.environ)
    src_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src_dir, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c", _PROBE_SRC.format(probe=_PROBE)],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    there = json.loads(proc.stdout.strip().splitlines()[-1])
    assert here == there


def test_choose_is_cached_and_stable():
    m = analytic()
    first = m.choose("allreduce", MiB, RING8)
    assert m.choose("allreduce", MiB, RING8) == first
    assert analytic().choose("allreduce", MiB, RING8) == first


# ---------------------------------------------------------------------------
# tuning table round-trip
# ---------------------------------------------------------------------------


def _synthetic_table():
    t = TuningTable(meta={"devices": 8})
    sig = axis_signature(RING8)
    t.set("allreduce", sig, [(64 * KiB, "rs_ag"), (None, "ring2d")])
    t.set("bcast", sig, [(None, "native")])
    return t


def test_tuning_table_roundtrip(tmp_path):
    table = _synthetic_table()
    path = table.save(tmp_path / "tuning.json")
    loaded = TuningTable.load(path)
    assert loaded is not None
    assert loaded.to_json() == table.to_json()

    before = CostModel(table=table)
    after = CostModel(table=loaded)
    for size in (KiB, 64 * KiB, 65 * KiB, 64 * MiB):
        want = before.choose("allreduce", size, RING8)
        assert after.choose("allreduce", size, RING8) == want
    # the measured table overrides the analytic ranking where it has entries
    assert after.choose("allreduce", KiB, RING8) == "rs_ag"
    assert after.choose("allreduce", 64 * MiB, RING8) == "ring2d"
    assert after.choose("bcast", 64 * MiB, RING8) == "native"


def test_tuning_table_band_boundaries():
    t = _synthetic_table()
    sig = axis_signature(RING8)
    assert t.lookup("allreduce", sig, 64 * KiB) == "rs_ag"      # inclusive
    assert t.lookup("allreduce", sig, 64 * KiB + 1) == "ring2d"
    assert t.lookup("allreduce", "ring[4]", KiB) is None        # unknown sig
    assert t.lookup("grid_transpose", sig, KiB) is None         # unknown op


def test_tuning_table_callsite_keys():
    """op@callsite entries override the untagged op for the matching
    callsite only; unknown callsites and plain lookups fall through."""
    t = _synthetic_table()
    sig = axis_signature(RING8)
    t.set("bcast@hpl.panel", sig, [(None, "ring2d")])
    assert t.lookup("bcast", sig, KiB, callsite="hpl.panel") == "ring2d"
    assert t.lookup("bcast", sig, KiB) == "native"            # untagged
    assert t.lookup("bcast", sig, KiB, callsite="other") == "native"
    # tagged-only entry: a different callsite falls through to nothing
    t2 = TuningTable()
    t2.set("bcast@hpl.panel", sig, [(None, "ring2d")])
    assert t2.lookup("bcast", sig, KiB, callsite="hpl.panel") == "ring2d"
    assert t2.lookup("bcast", sig, KiB) is None

    m = CostModel(table=t)
    assert m.choose("bcast", KiB, RING8, callsite="hpl.panel") == "ring2d"
    assert m.choose("bcast", KiB, RING8) == "native"
    # callsite-tagged keys round-trip through json like any other op key
    loaded = TuningTable.from_json(t.to_json())
    assert loaded.lookup("bcast", sig, KiB, callsite="hpl.panel") == "ring2d"


def test_callsite_stale_entry_falls_back():
    t = TuningTable()
    t.set("bcast@hpl.panel", axis_signature(RING8),
          [(None, "deleted_schedule")])
    m = CostModel(table=t)
    choice = m.choose("bcast", KiB, RING8, callsite="hpl.panel")
    assert choice == analytic().choose("bcast", KiB, RING8)


def test_moe_and_dp_callsite_keys_round_trip():
    """The application-exchange tags — all_to_all_tiles@moe.dispatch /
    @moe.combine and allreduce@dp.grads — behave exactly like the HPL
    callsite keys: tagged lookup wins over untagged, unknown callsites fall
    through, and the keys survive the json round trip."""
    t = TuningTable()
    sig = axis_signature(RING8)
    t.set("all_to_all_tiles", sig, [(None, "native")])
    t.set("all_to_all_tiles@moe.dispatch", sig, [(64 * KiB, "chain"),
                                                 (None, "native")])
    t.set("all_to_all_tiles@moe.combine", sig, [(None, "chain")])
    t.set("allreduce@dp.grads", sig, [(None, "rs_ag")])

    assert t.lookup("all_to_all_tiles", sig, KiB,
                    callsite="moe.dispatch") == "chain"
    assert t.lookup("all_to_all_tiles", sig, 1 * MiB,
                    callsite="moe.dispatch") == "native"
    assert t.lookup("all_to_all_tiles", sig, KiB,
                    callsite="moe.combine") == "chain"
    assert t.lookup("all_to_all_tiles", sig, KiB) == "native"  # untagged
    assert t.lookup("all_to_all_tiles", sig, KiB,
                    callsite="other") == "native"  # falls through
    # dp.grads has no untagged allreduce entry: plain lookups miss entirely
    assert t.lookup("allreduce", sig, MiB, callsite="dp.grads") == "rs_ag"
    assert t.lookup("allreduce", sig, MiB) is None

    loaded = TuningTable.from_json(t.to_json())
    for cs, size, want in (("moe.dispatch", KiB, "chain"),
                           ("moe.combine", KiB, "chain")):
        assert loaded.lookup("all_to_all_tiles", sig, size,
                             callsite=cs) == want
    assert loaded.lookup("allreduce", sig, MiB,
                         callsite="dp.grads") == "rs_ag"

    m = CostModel(table=loaded)
    assert m.choose("all_to_all_tiles", KiB, RING8,
                    callsite="moe.dispatch") == "chain"
    assert m.choose("allreduce", MiB, RING8, callsite="dp.grads") == "rs_ag"
    assert m.choose("allreduce", MiB, RING8) \
        == analytic().choose("allreduce", MiB, RING8)


def test_moe_and_dp_callsite_stale_entries_fall_back():
    """Stale tagged winners (schedule since deleted, or lossy) are ignored
    exactly like the untagged stale path — resolution falls back to the
    analytic ranking instead of naming an unregistered schedule."""
    t = TuningTable()
    sig = axis_signature(RING8)
    t.set("all_to_all_tiles@moe.dispatch", sig, [(None, "deleted_schedule")])
    t.set("allreduce@dp.grads", sig, [(None, "int8_ef")])  # lossy: never auto
    m = CostModel(table=t)
    a2a = m.choose("all_to_all_tiles", KiB, RING8, callsite="moe.dispatch")
    assert a2a == analytic().choose("all_to_all_tiles", KiB, RING8)
    assert a2a in schedules_for("all_to_all_tiles")
    red = m.choose("allreduce", MiB, RING8, callsite="dp.grads")
    assert red == analytic().choose("allreduce", MiB, RING8)
    assert red not in LOSSY_SCHEDULES


def test_decode_callsite_keys_round_trip():
    """The serving tags — all_to_all_tiles@decode.qkv and its measured
    aliases @decode.out / @decode.moe — behave exactly like the moe.* keys:
    tagged lookup wins over untagged for exactly those callsites, the keys
    survive json, and the alias map covers every decode tag."""
    from repro.comm.autotune import PAIRED_ALIASES
    assert PAIRED_ALIASES["all_to_all_tiles@decode.qkv"] == (
        "all_to_all_tiles@decode.out", "all_to_all_tiles@decode.moe")

    t = TuningTable()
    sig = axis_signature(RING8)
    t.set("all_to_all_tiles", sig, [(None, "native")])
    keys = ("all_to_all_tiles@decode.qkv",) \
        + PAIRED_ALIASES["all_to_all_tiles@decode.qkv"]
    for key in keys:  # what autotune_mesh writes: the same bands per alias
        t.set(key, sig, [(16 * KiB, "chain"), (None, "native")])

    m = CostModel(table=TuningTable.from_json(t.to_json()))
    for cs in ("decode.qkv", "decode.out", "decode.moe"):
        assert m.choose("all_to_all_tiles", KiB, RING8, callsite=cs) \
            == "chain"
        assert m.choose("all_to_all_tiles", MiB, RING8, callsite=cs) \
            == "native"
    assert m.choose("all_to_all_tiles", KiB, RING8) == "native"  # untagged
    assert m.choose("all_to_all_tiles", KiB, RING8,
                    callsite="other") == "native"


def test_decode_callsite_stale_entry_falls_back():
    t = TuningTable()
    t.set("all_to_all_tiles@decode.qkv", axis_signature(RING8),
          [(None, "deleted_schedule")])
    m = CostModel(table=t)
    choice = m.choose("all_to_all_tiles", KiB, RING8, callsite="decode.qkv")
    assert choice == analytic().choose("all_to_all_tiles", KiB, RING8)
    assert choice in schedules_for("all_to_all_tiles")


def test_moe_callsite_backend_guard(tmp_path, monkeypatch):
    """A foreign-backend table carrying the MoE/DP callsite keys is rejected
    wholesale by default_cost_model — mirroring the bcast@hpl.panel
    stale-backend behavior."""
    import jax

    from repro.comm.autotune import default_cost_model
    try:
        t = TuningTable(meta={"backend": "definitely_not_"
                              + jax.default_backend()})
        sig = axis_signature(RING8)
        t.set("all_to_all_tiles@moe.dispatch", sig, [(None, "chain")])
        t.set("allreduce@dp.grads", sig, [(None, "rs_ag")])
        monkeypatch.setenv("REPRO_TUNING_TABLE",
                           str(t.save(tmp_path / "foreign.json")))
        m = default_cost_model(refresh=True)
        assert m.table is None
        assert m.choose("all_to_all_tiles", KiB, RING8,
                        callsite="moe.dispatch") \
            == analytic().choose("all_to_all_tiles", KiB, RING8)
    finally:
        monkeypatch.delenv("REPRO_TUNING_TABLE")
        default_cost_model(refresh=True)  # restore process-wide state


def test_stale_table_entry_falls_back_to_analytic():
    t = TuningTable()
    t.set("allreduce", axis_signature(RING8), [(None, "deleted_schedule")])
    m = CostModel(table=t)
    choice = m.choose("allreduce", 64 * MiB, RING8)
    assert choice in schedules_for("allreduce")
    assert choice == analytic().choose("allreduce", 64 * MiB, RING8)


def test_load_missing_table_returns_none(tmp_path):
    assert TuningTable.load(tmp_path / "nope.json") is None


def test_default_model_rejects_foreign_backend_table(tmp_path, monkeypatch):
    """A table measured on another backend (e.g. the CI CPU artifact landing
    on a TPU checkout) must not override the analytic model."""
    from repro.comm.autotune import default_cost_model
    import jax
    try:
        t = _synthetic_table()
        t.meta["backend"] = "definitely_not_" + jax.default_backend()
        monkeypatch.setenv("REPRO_TUNING_TABLE",
                           str(t.save(tmp_path / "foreign.json")))
        assert default_cost_model(refresh=True).table is None

        t.meta["backend"] = jax.default_backend()
        t.save(tmp_path / "foreign.json")
        assert default_cost_model(refresh=True).table is not None
    finally:
        monkeypatch.delenv("REPRO_TUNING_TABLE")
        default_cost_model(refresh=True)  # restore process-wide state


def test_winner_bounds_stay_aligned_with_measured_sizes():
    """Winners pair with the sizes that were actually measured: a failed
    intermediate ladder size must not shift the band boundaries."""
    from repro.comm.autotune import _winner_bounds
    # ladder (1K, 16K, 256K, 4M) with 16K failed -> measured (1K, 256K, 4M)
    bounds = _winner_bounds([1 << 10, 1 << 18, 1 << 22],
                            ["chain", "native", "ring2d"])
    assert bounds == [(int((2 ** 14)), "chain"),
                      (int((2 ** 20)), "native"),
                      (None, "ring2d")]
    # consecutive same winners merge into one band
    assert _winner_bounds([1, 4, 16], ["a", "a", "b"]) == [(8, "a"),
                                                           (None, "b")]
    assert _winner_bounds([1, 4], ["a", "a"]) == [(None, "a")]


# ---------------------------------------------------------------------------
# derived bucket size
# ---------------------------------------------------------------------------


def test_derive_bucket_bytes_bounds_and_monotonicity():
    b1 = derive_bucket_bytes((AxisTopology("x", 1, "ring"),))
    b2 = derive_bucket_bytes(RING2)
    b8 = derive_bucket_bytes(RING8)
    for b in (b1, b2, b8):
        assert MIN_BUCKET_BYTES <= b <= MAX_BUCKET_BYTES
        assert b & (b - 1) == 0, f"{b} is not a power of two"
    assert b2 <= b8  # more hops -> bigger buckets to amortize latency


def test_derive_bucket_bytes_latency_bandwidth_product():
    # depth x 2(n-1) hops x (alpha x beta), rounded up to a power of two:
    # 4 x 14 x (1e-6 s x 50e9 B/s) = 2.8 MB -> 4 MiB on the v5e numbers
    assert derive_bucket_bytes(RING8, TPU_V5E) == 4 * MiB


# ---------------------------------------------------------------------------
# engine integration (no devices needed: resolution is host-side)
# ---------------------------------------------------------------------------


def _engine8(**kw):
    topo = MeshTopology(axes=RING8)
    return CollectiveEngine(schedule="auto", topology=topo,
                            cost_model=analytic(), **kw)


def test_engine_auto_resolves_through_cost_model():
    eng = _engine8()
    assert eng.schedule_for("allreduce", nbytes=KiB, axis="x") == "chain"
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "native"
    assert eng.schedule_for("bcast", nbytes=64 * MiB, axis="x") == "ring2d"
    # the literal "auto" never escapes resolution
    for op in ("bcast", "allreduce", "ring_exchange"):
        for size in (KiB, MiB, 64 * MiB):
            name = eng.schedule_for(op, nbytes=size, axis="x")
            assert name != "auto" and name in schedules_for(op)


def test_engine_auto_without_payload_uses_static_defaults():
    eng = _engine8()
    assert eng.schedule_for("bcast") == "chain"
    assert eng.schedule_for("allreduce") == "native"
    assert eng.schedule_for("allreduce", nbytes=KiB, axis=None) == "native"


def test_engine_auto_unknown_axis_falls_back():
    eng = _engine8()
    assert eng.schedule_for("allreduce", nbytes=KiB, axis="bogus") == "native"


def test_engine_partial_name_falls_back_through_model():
    # rs_ag covers allreduce only: other ops resolve like auto, through the
    # model when payload context exists
    topo = MeshTopology(axes=RING8)
    eng = CollectiveEngine(schedule="rs_ag", topology=topo,
                           cost_model=analytic())
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "rs_ag"
    assert eng.schedule_for("bcast", nbytes=64 * MiB, axis="x") == "ring2d"
    assert eng.schedule_for("bcast", nbytes=KiB, axis="x") == "chain"


def test_engine_bucket_bytes_for():
    eng = _engine8()
    assert eng.bucket_bytes_for("x") == derive_bucket_bytes(RING8, TPU_V5E)
    from repro.comm.overlap import DEFAULT_BUCKET_BYTES
    assert CollectiveEngine().bucket_bytes_for("x") == DEFAULT_BUCKET_BYTES
    assert eng.bucket_bytes_for("bogus") == DEFAULT_BUCKET_BYTES


def test_engine_explicit_override_beats_model():
    eng = _engine8()
    assert eng.schedule_for("allreduce", "chain",
                            nbytes=64 * MiB, axis="x") == "chain"


def test_engine_callsite_resolution_and_pipeline_chunks():
    """The engine threads callsite tags into table lookups, and
    pipeline_chunks resolves the fill-cost chunk count (1 without
    payload/topology context)."""
    t = TuningTable()
    t.set("bcast@hpl.panel", axis_signature(RING8), [(None, "ring2d")])
    topo = MeshTopology(axes=RING8)
    eng = CollectiveEngine(schedule="auto", topology=topo,
                           cost_model=CostModel(table=t))
    assert eng.schedule_for("bcast", nbytes=KiB, axis="x",
                            callsite="hpl.panel") == "ring2d"
    assert eng.schedule_for("bcast", nbytes=KiB, axis="x") == "chain"

    eng2 = _engine8()
    assert eng2.pipeline_chunks("bcast", nbytes=64 * MiB, axis="x") > 1
    assert eng2.pipeline_chunks("bcast", nbytes=256, axis="x") == 1
    assert eng2.pipeline_chunks("bcast", nbytes=64 * MiB, axis="bogus") == 1
    assert eng2.pipeline_chunks("bcast") == 1
    assert CollectiveEngine().pipeline_chunks("bcast", nbytes=64 * MiB,
                                              axis="x") == 1


def test_host_staged_still_forces_staged():
    from repro.comm.types import CommunicationType as CT
    topo = MeshTopology(axes=RING8)
    eng = CollectiveEngine(comm=CT.HOST_STAGED, schedule="auto",
                           topology=topo, cost_model=analytic())
    assert eng.schedule_for("allreduce", nbytes=64 * MiB, axis="x") == "staged"
