"""Topology metadata unit tests: link identity (the size-2 ring dedupe)
and the grid factorization contract."""
from __future__ import annotations

import pytest

from repro.comm.topology import AxisTopology, grid_from_devices


# ---------------------------------------------------------------------------
# AxisTopology.links — physical wires, not hop names
# ---------------------------------------------------------------------------


def test_links_size2_ring_reports_one_wire():
    # hops 0 and 1 on a 2-rank ring are the same physical wire between
    # ranks 0 and 1; reporting both would let a health mask naming hop 1
    # miss routes recorded under hop 0 (and vice versa)
    ax = AxisTopology("x", 2, "ring")
    assert ax.links() == (("x", 0),)
    assert ax.n_links == 1


@pytest.mark.parametrize("size", [3, 4, 8])
def test_links_larger_rings_report_every_hop(size):
    ax = AxisTopology("x", size, "ring")
    assert ax.links() == tuple(("x", h) for h in range(size))
    assert ax.n_links == size


def test_links_staging_axis_has_none():
    ax = AxisTopology("pod", 4, "staging")
    assert ax.links() == ()
    assert ax.n_links == 0


def test_canonical_hop_collapses_only_on_size2():
    two = AxisTopology("x", 2, "ring")
    assert two.canonical_hop(0) == 0
    assert two.canonical_hop(1) == 0
    four = AxisTopology("x", 4, "ring")
    assert [four.canonical_hop(h) for h in range(4)] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# grid_from_devices — most-square factorization, square-or-raise contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,expect", [
    (1, (1, 1)),
    (7, (1, 7)),       # prime: degenerate 1 x n
    (8, (2, 4)),       # rectangular: most-square, P <= Q
    (12, (3, 4)),
    (16, (4, 4)),      # perfect square
])
def test_grid_from_devices_most_square(n, expect):
    p, q = grid_from_devices(n)
    assert (p, q) == expect
    assert p * q == n and p <= q


@pytest.mark.parametrize("n", [1, 4, 16, 64])
def test_grid_from_devices_square_flag_accepts_squares(n):
    p, q = grid_from_devices(n, square=True)
    assert p == q and p * p == n


@pytest.mark.parametrize("n", [2, 7, 8, 12])
def test_grid_from_devices_square_flag_raises_on_rectangles(n):
    # the circuit-switched PTRANS/HPL path (transpose_perm) needs P = Q;
    # silently returning 2 x 4 for 8 devices was the bug
    with pytest.raises(ValueError, match="square"):
        grid_from_devices(n, square=True)


def test_grid_from_devices_rejects_nonpositive():
    with pytest.raises(ValueError):
        grid_from_devices(0)
