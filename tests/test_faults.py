"""Unit tests for the resilience layer: deterministic fault injection
(repro.comm.faults), the narrow retune controller (repro.comm.retune),
mid-run engine invalidation, and the tuning-table merge they ride on."""
from __future__ import annotations

import pytest

from repro.comm.autotune import CostModel, TuningTable, route_links
from repro.comm.engine import CollectiveEngine
from repro.comm.faults import (FAULT_ACTIONS, FaultEvent, FaultInjector,
                               FaultSchedule, LinkFault, RankLostError,
                               active_injector, injected,
                               measured_extra_time)
from repro.comm.retune import RETUNE_TRIGGERS, RetuneController, Watched
from repro.comm.topology import AxisTopology, MeshTopology
from repro.comm.types import TPU_V5E

RING8 = (AxisTopology("x", 8, "ring"),)
NBYTES = 16384


def _engine():
    """Host-side engine over an 8-ring with an isolated analytic model —
    no live mesh needed for schedule resolution."""
    return CollectiveEngine(schedule="auto",
                            topology=MeshTopology(axes=RING8),
                            cost_model=CostModel(hw=TPU_V5E, table=None))


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------


def test_link_fault_rejects_speedups():
    with pytest.raises(ValueError):
        LinkFault("x", 0, alpha_scale=0.5)
    with pytest.raises(ValueError):
        LinkFault("x", 0, beta_scale=0.0)
    LinkFault("x", 0, alpha_scale=1.0, beta_scale=64.0)  # >= 1 is fine


def test_injector_degrade_heal_roundtrip():
    inj = FaultInjector(hw=TPU_V5E)
    assert not inj.active
    assert inj.hardware_view() is TPU_V5E  # clean view is the identity

    inj.degrade_link("x", 0, alpha_scale=2.0, beta_scale=8.0)
    assert inj.active
    a, b = inj.scales(("x",))
    assert (a, b) == (2.0, 8.0)
    assert inj.scales(("y",)) == (1.0, 1.0)  # other axes untouched
    hw = inj.hardware_view()
    assert hw.ici_latency == pytest.approx(TPU_V5E.ici_latency * 2.0)
    assert hw.ici_link_bw == pytest.approx(TPU_V5E.ici_link_bw / 8.0)

    inj.heal("x", 0)
    assert not inj.active
    assert inj.hardware_view() is TPU_V5E


def test_extra_time_charges_only_link_bound_schedules():
    inj = FaultInjector(hw=TPU_V5E)
    inj.degrade_link("x", 0, beta_scale=64.0)
    chain = inj.extra_time("bcast", "chain", NBYTES, RING8)
    staged = inj.extra_time("bcast", "staged", NBYTES, RING8)
    assert chain > 0.0
    assert staged == pytest.approx(0.0)  # staged routing avoids the link
    inj.heal()
    assert inj.extra_time("bcast", "chain", NBYTES, RING8) == 0.0


def test_host_delays_compose_and_clear():
    inj = FaultInjector(hw=TPU_V5E)
    inj.add_host_delay(None, 0.005)       # everywhere
    inj.add_host_delay("train.step", 0.010)
    assert inj.host_delay("train.step") == pytest.approx(0.015)
    assert inj.host_delay("serve.step") == pytest.approx(0.005)
    inj.clear_host_delay("train.step")
    assert inj.host_delay("train.step") == pytest.approx(0.005)
    inj.clear_host_delay(None)
    assert inj.host_delay("train.step") == 0.0


def test_injected_context_sets_and_restores():
    inj = FaultInjector(hw=TPU_V5E)
    inj.degrade_link("x", 0, beta_scale=4.0)
    assert active_injector() is None
    assert measured_extra_time("bcast", "chain", NBYTES, RING8) == 0.0
    with injected(inj):
        assert active_injector() is inj
        assert measured_extra_time("bcast", "chain", NBYTES, RING8) > 0.0
    assert active_injector() is None


# ---------------------------------------------------------------------------
# FaultSchedule
# ---------------------------------------------------------------------------


def test_fault_event_validates_action():
    for action in FAULT_ACTIONS:
        FaultEvent(0, action)
    with pytest.raises(ValueError):
        FaultEvent(0, "explode")


def test_degrade_window_rejects_empty():
    inj = FaultInjector(hw=TPU_V5E)
    with pytest.raises(ValueError, match="empty"):
        FaultSchedule.degrade_window(inj, 5, 5, beta_scale=2.0)


def test_schedule_applies_at_exact_steps():
    inj = FaultInjector(hw=TPU_V5E)
    sched = FaultSchedule.degrade_window(inj, 3, 6, axis="x",
                                         beta_scale=16.0,
                                         host_delay_s=0.01, callsite="c")
    assert sched.span == (3, 6)
    for step in range(8):
        sched.apply(step)
        if 3 <= step < 6:
            assert inj.active
            assert inj.host_delay("c") == pytest.approx(0.01)
        else:
            assert not inj.active
            assert inj.host_delay("c") == 0.0
    # re-applying a fired step is effect-idempotent: the same fault is
    # overwritten, not stacked
    sched.apply(3)
    sched.apply(3)
    assert inj.active and inj.scales(("x",)) == (1.0, 16.0)
    sched.apply(6)
    assert not inj.active and inj.host_delay("c") == 0.0


# ---------------------------------------------------------------------------
# Hard faults: down links, link-health masks, rank loss
# ---------------------------------------------------------------------------


def test_down_link_mask_and_heal():
    inj = FaultInjector(hw=TPU_V5E)
    assert inj.down_links() == frozenset()
    inj.down_link("x", 3)
    inj.degrade_link("y", 0, beta_scale=4.0)  # soft fault: not in the mask
    assert inj.active
    assert inj.down_links() == frozenset({("x", 3)})
    assert inj.down_links((RING8[0],)) == frozenset({("x", 3)})
    assert inj.down_links(("y",)) == frozenset()
    # a down link contributes no soft scaling — it is gone, not slow
    assert inj.scales(("x",)) == (1.0, 1.0)
    inj.heal("x", 3)
    assert inj.down_links() == frozenset()


def test_down_link_extra_time_is_infinite_on_crossing_routes():
    inj = FaultInjector(hw=TPU_V5E)
    inj.down_link("x", 3)
    # chain crosses every ring hop -> unusable
    assert inj.extra_time("bcast", "chain", NBYTES, RING8) == float("inf")
    # staged rides PCIe+MPI -> unaffected
    assert inj.extra_time("bcast", "staged", NBYTES, RING8) == 0.0
    # chain_rooted cuts at the down hop -> usable
    assert inj.extra_time("bcast", "chain_rooted", NBYTES,
                          RING8) != float("inf")


def test_health_mask_reroutes_resolution_on_same_engine():
    inj = FaultInjector(hw=TPU_V5E)
    engine = _engine()
    before = engine.schedule_for("bcast", nbytes=NBYTES, axis="x")
    inj.down_link("x", 3)
    engine.invalidate_resolutions(health=inj.down_links())
    during = engine.schedule_for("bcast", nbytes=NBYTES, axis="x")
    route = route_links("bcast", during, RING8,
                        health=frozenset({("x", 3)}))
    inj.heal()
    engine.invalidate_resolutions(health=inj.down_links())
    after = engine.schedule_for("bcast", nbytes=NBYTES, axis="x")
    assert before == "chain" and during == "chain_rooted" and after == before
    assert route is not None and ("x", 3) not in route


def test_health_mask_rejects_stale_measured_winner():
    """A tuning-table winner that crosses the cut must not survive the
    health mask — the analytic fallback reroutes instead."""
    t = TuningTable(hw="test")
    t.set("bcast", "ring[8]", [(None, "chain")])
    model = CostModel(hw=TPU_V5E, table=t, health=frozenset({("x", 2)}))
    assert model.choose("bcast", NBYTES, RING8) == "chain_rooted"


def test_doubly_broken_ring_falls_back_to_staged():
    """Two cuts: no rooted chain survives, so the host-staged route wins."""
    health = frozenset({("x", 1), ("x", 5)})
    model = CostModel(hw=TPU_V5E, table=None, health=health)
    winner = model.choose("bcast", NBYTES, RING8)
    route = route_links("bcast", winner, RING8, health=health)
    assert winner == "staged"
    assert route == frozenset()


def test_rank_loss_lifecycle():
    inj = FaultInjector(hw=TPU_V5E)
    assert inj.lost_ranks == frozenset()
    inj.fail_rank(3)
    inj.fail_rank(5)
    assert inj.active
    assert inj.lost_ranks == frozenset({3, 5})
    inj.restore_ranks()
    assert inj.lost_ranks == frozenset() and not inj.active
    err = RankLostError({5, 3}, 12)
    assert err.ranks == (3, 5) and err.step == 12
    assert isinstance(err, RuntimeError)


def test_fault_schedule_fail_rank_is_one_shot():
    """A resumed loop re-entering the step range must not re-lose the rank
    it just recovered from."""
    inj = FaultInjector(hw=TPU_V5E)
    sched = FaultSchedule.rank_loss(inj, 4, rank=7)
    sched.apply(4)
    assert inj.lost_ranks == frozenset({7})
    inj.restore_ranks()   # what train_loop_elastic does before resuming
    sched.apply(4)        # the resumed loop passes step 4 again
    assert inj.lost_ranks == frozenset()


def test_down_window_round_trip():
    inj = FaultInjector(hw=TPU_V5E)
    sched = FaultSchedule.down_window(inj, 3, 6, axis="x", hop=2)
    for step in range(8):
        sched.apply(step)
        if 3 <= step < 6:
            assert inj.down_links() == frozenset({("x", 2)})
        else:
            assert inj.down_links() == frozenset()


def test_fault_schedule_parse():
    inj = FaultInjector(hw=TPU_V5E)
    sched = FaultSchedule.parse(
        inj, "degrade@5-20:axis=x,hop=1,beta_scale=64;"
             "down@8-12:axis=x,hop=3;"
             "delay@5-9:seconds=0.05,callsite=train.step;"
             "fail_rank@12:rank=3")
    actions = sorted(e.action for e in sched.events)
    assert actions == ["clear_delay", "degrade", "delay", "down",
                       "fail_rank", "heal", "heal"]
    sched.apply(8)
    assert inj.down_links() == frozenset({("x", 3)})
    sched.apply(12)
    assert inj.down_links() == frozenset() and inj.lost_ranks == {3}
    with pytest.raises(ValueError):
        FaultSchedule.parse(inj, "explode@3")
    with pytest.raises(ValueError):
        FaultSchedule.parse(inj, "fail_rank@3-5:rank=1")  # no window form


# ---------------------------------------------------------------------------
# TuningTable.merge + invalidate_resolutions
# ---------------------------------------------------------------------------


def test_tuning_table_merge_overrides_per_signature():
    base = TuningTable(hw="a", meta={"k": 1, "keep": True})
    base.set("bcast", "ring[8]", [(None, "chain")])
    base.set("allreduce", "ring[8]", [(None, "rs_ag")])
    other = TuningTable(hw="b", meta={"k": 2})
    other.set("bcast", "ring[8]", [(4096, "native"), (None, "staged")])

    merged = base.merge(other)
    assert merged.entries["bcast"]["ring[8]"] == [(4096, "native"),
                                                  (None, "staged")]
    assert merged.entries["allreduce"]["ring[8]"] == [(None, "rs_ag")]
    assert merged.hw == "b" and merged.meta == {"k": 2, "keep": True}
    # the inputs are untouched
    assert base.entries["bcast"]["ring[8]"] == [(None, "chain")]


def test_invalidate_resolutions_swaps_without_rebuild():
    inj = FaultInjector(hw=TPU_V5E)
    engine = _engine()
    before = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                 callsite="hpl.panel")
    inj.degrade_link("x", 0, beta_scale=64.0)
    engine.invalidate_resolutions(hw=inj.hardware_view())
    during = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                 callsite="hpl.panel")
    inj.heal()
    engine.invalidate_resolutions(hw=inj.hardware_view())
    after = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                callsite="hpl.panel")
    assert before == "chain" and during == "staged" and after == before


def test_invalidate_resolutions_swaps_table():
    engine = _engine()
    t = TuningTable(hw="test")
    t.set("bcast", "ring[8]", [(None, "native")])
    engine.invalidate_resolutions(table=t)
    assert engine.schedule_for("bcast", nbytes=NBYTES, axis="x") == "native"


# ---------------------------------------------------------------------------
# RetuneController
# ---------------------------------------------------------------------------


def _controller(engine, inj, **kw):
    kw.setdefault("drift_factor", 1.75)
    kw.setdefault("recent", 2)
    kw.setdefault("min_baseline", 3)
    kw.setdefault("cooldown", 2)
    return RetuneController(engine, [Watched("hpl.panel", "bcast",
                                             NBYTES, "x")],
                            hw_probe=inj.hardware_view, **kw)


def test_controller_validation():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    with pytest.raises(ValueError, match="drift_factor"):
        RetuneController(engine, [Watched("c", "bcast", 1, "x")],
                         drift_factor=1.0)
    with pytest.raises(ValueError, match="at least one"):
        RetuneController(engine, [])
    ctrl = _controller(engine, inj)
    with pytest.raises(ValueError, match="trigger"):
        ctrl.retune(0, trigger="panic")
    assert RETUNE_TRIGGERS == ("drift", "straggler", "forced")


def test_controller_detects_degrade_and_heal():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    ctrl = _controller(engine, inj)

    events = []
    for step in range(6):  # nominal: baseline arms, nothing fires
        assert ctrl.observe(step, 1.0) is None

    inj.degrade_link("x", 0, beta_scale=64.0)
    for step in range(6, 12):
        ev = ctrl.observe(step, 16.0)
        if ev:
            events.append(ev)
    assert len(events) == 1
    assert events[0].trigger == "drift"
    assert events[0].changed == {"hpl.panel": ("chain", "staged")}

    # cooldown re-arms a fresh baseline at the degraded speed, then the
    # heal shows up as a *downward* drift — the detector is two-sided
    inj.heal()
    for step in range(12, 24):
        ev = ctrl.observe(step, 1.0)
        if ev:
            events.append(ev)
    assert len(events) == 2
    assert events[1].changed == {"hpl.panel": ("staged", "chain")}


def test_controller_straggler_trigger_and_cooldown():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    ctrl = _controller(engine, inj, cooldown=5)
    inj.degrade_link("x", 0, beta_scale=64.0)
    ev = ctrl.on_straggler(7)
    assert ev is not None and ev.trigger == "straggler"
    assert ev.changed == {"hpl.panel": ("chain", "staged")}
    assert ctrl.on_straggler(8) is None       # cooling down
    assert ctrl.observe(9, 100.0) is None     # observations too
    assert len(ctrl.events) == 1


def test_controller_callsite_stream_narrows_hot_set():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    watched = [Watched("hpl.panel", "bcast", NBYTES, "x"),
               Watched("dp.grads", "allreduce", NBYTES, "x")]
    ctrl = RetuneController(engine, watched, drift_factor=1.75, recent=2,
                            min_baseline=3, cooldown=2,
                            hw_probe=inj.hardware_view)
    inj.degrade_link("x", 0, beta_scale=64.0)
    ev = None
    for step in range(10):
        got = ctrl.observe(step, 16.0 if step >= 5 else 1.0,
                           callsite="hpl.panel")
        ev = ev or got
    assert ev is not None
    assert ev.hot == ("hpl.panel",)  # only the drifted stream retunes
