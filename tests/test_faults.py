"""Unit tests for the resilience layer: deterministic fault injection
(repro.comm.faults), the narrow retune controller (repro.comm.retune),
mid-run engine invalidation, and the tuning-table merge they ride on."""
from __future__ import annotations

import pytest

from repro.comm.autotune import CostModel, TuningTable
from repro.comm.engine import CollectiveEngine
from repro.comm.faults import (FAULT_ACTIONS, FaultEvent, FaultInjector,
                               FaultSchedule, LinkFault, active_injector,
                               injected, measured_extra_time)
from repro.comm.retune import RETUNE_TRIGGERS, RetuneController, Watched
from repro.comm.topology import AxisTopology, MeshTopology
from repro.comm.types import TPU_V5E

RING8 = (AxisTopology("x", 8, "ring"),)
NBYTES = 16384


def _engine():
    """Host-side engine over an 8-ring with an isolated analytic model —
    no live mesh needed for schedule resolution."""
    return CollectiveEngine(schedule="auto",
                            topology=MeshTopology(axes=RING8),
                            cost_model=CostModel(hw=TPU_V5E, table=None))


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------


def test_link_fault_rejects_speedups():
    with pytest.raises(ValueError):
        LinkFault("x", 0, alpha_scale=0.5)
    with pytest.raises(ValueError):
        LinkFault("x", 0, beta_scale=0.0)
    LinkFault("x", 0, alpha_scale=1.0, beta_scale=64.0)  # >= 1 is fine


def test_injector_degrade_heal_roundtrip():
    inj = FaultInjector(hw=TPU_V5E)
    assert not inj.active
    assert inj.hardware_view() is TPU_V5E  # clean view is the identity

    inj.degrade_link("x", 0, alpha_scale=2.0, beta_scale=8.0)
    assert inj.active
    a, b = inj.scales(("x",))
    assert (a, b) == (2.0, 8.0)
    assert inj.scales(("y",)) == (1.0, 1.0)  # other axes untouched
    hw = inj.hardware_view()
    assert hw.ici_latency == pytest.approx(TPU_V5E.ici_latency * 2.0)
    assert hw.ici_link_bw == pytest.approx(TPU_V5E.ici_link_bw / 8.0)

    inj.heal("x", 0)
    assert not inj.active
    assert inj.hardware_view() is TPU_V5E


def test_extra_time_charges_only_link_bound_schedules():
    inj = FaultInjector(hw=TPU_V5E)
    inj.degrade_link("x", 0, beta_scale=64.0)
    chain = inj.extra_time("bcast", "chain", NBYTES, RING8)
    staged = inj.extra_time("bcast", "staged", NBYTES, RING8)
    assert chain > 0.0
    assert staged == pytest.approx(0.0)  # staged routing avoids the link
    inj.heal()
    assert inj.extra_time("bcast", "chain", NBYTES, RING8) == 0.0


def test_host_delays_compose_and_clear():
    inj = FaultInjector(hw=TPU_V5E)
    inj.add_host_delay(None, 0.005)       # everywhere
    inj.add_host_delay("train.step", 0.010)
    assert inj.host_delay("train.step") == pytest.approx(0.015)
    assert inj.host_delay("serve.step") == pytest.approx(0.005)
    inj.clear_host_delay("train.step")
    assert inj.host_delay("train.step") == pytest.approx(0.005)
    inj.clear_host_delay(None)
    assert inj.host_delay("train.step") == 0.0


def test_injected_context_sets_and_restores():
    inj = FaultInjector(hw=TPU_V5E)
    inj.degrade_link("x", 0, beta_scale=4.0)
    assert active_injector() is None
    assert measured_extra_time("bcast", "chain", NBYTES, RING8) == 0.0
    with injected(inj):
        assert active_injector() is inj
        assert measured_extra_time("bcast", "chain", NBYTES, RING8) > 0.0
    assert active_injector() is None


# ---------------------------------------------------------------------------
# FaultSchedule
# ---------------------------------------------------------------------------


def test_fault_event_validates_action():
    for action in FAULT_ACTIONS:
        FaultEvent(0, action)
    with pytest.raises(ValueError):
        FaultEvent(0, "explode")


def test_degrade_window_rejects_empty():
    inj = FaultInjector(hw=TPU_V5E)
    with pytest.raises(ValueError, match="empty"):
        FaultSchedule.degrade_window(inj, 5, 5, beta_scale=2.0)


def test_schedule_applies_at_exact_steps():
    inj = FaultInjector(hw=TPU_V5E)
    sched = FaultSchedule.degrade_window(inj, 3, 6, axis="x",
                                         beta_scale=16.0,
                                         host_delay_s=0.01, callsite="c")
    assert sched.span == (3, 6)
    for step in range(8):
        sched.apply(step)
        if 3 <= step < 6:
            assert inj.active
            assert inj.host_delay("c") == pytest.approx(0.01)
        else:
            assert not inj.active
            assert inj.host_delay("c") == 0.0
    # re-applying a fired step is effect-idempotent: the same fault is
    # overwritten, not stacked
    sched.apply(3)
    sched.apply(3)
    assert inj.active and inj.scales(("x",)) == (1.0, 16.0)
    sched.apply(6)
    assert not inj.active and inj.host_delay("c") == 0.0


# ---------------------------------------------------------------------------
# TuningTable.merge + invalidate_resolutions
# ---------------------------------------------------------------------------


def test_tuning_table_merge_overrides_per_signature():
    base = TuningTable(hw="a", meta={"k": 1, "keep": True})
    base.set("bcast", "ring[8]", [(None, "chain")])
    base.set("allreduce", "ring[8]", [(None, "rs_ag")])
    other = TuningTable(hw="b", meta={"k": 2})
    other.set("bcast", "ring[8]", [(4096, "native"), (None, "staged")])

    merged = base.merge(other)
    assert merged.entries["bcast"]["ring[8]"] == [(4096, "native"),
                                                  (None, "staged")]
    assert merged.entries["allreduce"]["ring[8]"] == [(None, "rs_ag")]
    assert merged.hw == "b" and merged.meta == {"k": 2, "keep": True}
    # the inputs are untouched
    assert base.entries["bcast"]["ring[8]"] == [(None, "chain")]


def test_invalidate_resolutions_swaps_without_rebuild():
    inj = FaultInjector(hw=TPU_V5E)
    engine = _engine()
    before = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                 callsite="hpl.panel")
    inj.degrade_link("x", 0, beta_scale=64.0)
    engine.invalidate_resolutions(hw=inj.hardware_view())
    during = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                 callsite="hpl.panel")
    inj.heal()
    engine.invalidate_resolutions(hw=inj.hardware_view())
    after = engine.schedule_for("bcast", nbytes=NBYTES, axis="x",
                                callsite="hpl.panel")
    assert before == "chain" and during == "staged" and after == before


def test_invalidate_resolutions_swaps_table():
    engine = _engine()
    t = TuningTable(hw="test")
    t.set("bcast", "ring[8]", [(None, "native")])
    engine.invalidate_resolutions(table=t)
    assert engine.schedule_for("bcast", nbytes=NBYTES, axis="x") == "native"


# ---------------------------------------------------------------------------
# RetuneController
# ---------------------------------------------------------------------------


def _controller(engine, inj, **kw):
    kw.setdefault("drift_factor", 1.75)
    kw.setdefault("recent", 2)
    kw.setdefault("min_baseline", 3)
    kw.setdefault("cooldown", 2)
    return RetuneController(engine, [Watched("hpl.panel", "bcast",
                                             NBYTES, "x")],
                            hw_probe=inj.hardware_view, **kw)


def test_controller_validation():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    with pytest.raises(ValueError, match="drift_factor"):
        RetuneController(engine, [Watched("c", "bcast", 1, "x")],
                         drift_factor=1.0)
    with pytest.raises(ValueError, match="at least one"):
        RetuneController(engine, [])
    ctrl = _controller(engine, inj)
    with pytest.raises(ValueError, match="trigger"):
        ctrl.retune(0, trigger="panic")
    assert RETUNE_TRIGGERS == ("drift", "straggler", "forced")


def test_controller_detects_degrade_and_heal():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    ctrl = _controller(engine, inj)

    events = []
    for step in range(6):  # nominal: baseline arms, nothing fires
        assert ctrl.observe(step, 1.0) is None

    inj.degrade_link("x", 0, beta_scale=64.0)
    for step in range(6, 12):
        ev = ctrl.observe(step, 16.0)
        if ev:
            events.append(ev)
    assert len(events) == 1
    assert events[0].trigger == "drift"
    assert events[0].changed == {"hpl.panel": ("chain", "staged")}

    # cooldown re-arms a fresh baseline at the degraded speed, then the
    # heal shows up as a *downward* drift — the detector is two-sided
    inj.heal()
    for step in range(12, 24):
        ev = ctrl.observe(step, 1.0)
        if ev:
            events.append(ev)
    assert len(events) == 2
    assert events[1].changed == {"hpl.panel": ("staged", "chain")}


def test_controller_straggler_trigger_and_cooldown():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    ctrl = _controller(engine, inj, cooldown=5)
    inj.degrade_link("x", 0, beta_scale=64.0)
    ev = ctrl.on_straggler(7)
    assert ev is not None and ev.trigger == "straggler"
    assert ev.changed == {"hpl.panel": ("chain", "staged")}
    assert ctrl.on_straggler(8) is None       # cooling down
    assert ctrl.observe(9, 100.0) is None     # observations too
    assert len(ctrl.events) == 1


def test_controller_callsite_stream_narrows_hot_set():
    engine = _engine()
    inj = FaultInjector(hw=TPU_V5E)
    watched = [Watched("hpl.panel", "bcast", NBYTES, "x"),
               Watched("dp.grads", "allreduce", NBYTES, "x")]
    ctrl = RetuneController(engine, watched, drift_factor=1.75, recent=2,
                            min_baseline=3, cooldown=2,
                            hw_probe=inj.hardware_view)
    inj.degrade_link("x", 0, beta_scale=64.0)
    ev = None
    for step in range(10):
        got = ctrl.observe(step, 16.0 if step >= 5 else 1.0,
                           callsite="hpl.panel")
        ev = ev or got
    assert ev is not None
    assert ev.hot == ("hpl.panel",)  # only the drifted stream retunes
