"""Single-device tests for the overlap subsystem: bucket packing, the
engine's bucketed allreduce_tree, and schedule registration. Multi-device
equivalence runs in tests/dist/test_overlap.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.engine import CollectiveEngine, schedules_for
from repro.comm.overlap import bucketed_psum_tree, pack_buckets, tree_bytes
from repro.compat import make_mesh, shard_map


def _leaves(*sizes):
    return [jnp.zeros((s,), jnp.float32) for s in sizes]


def test_pack_buckets_greedy_boundaries():
    # 3 x 40B leaves, 100B cap: [0, 1] fills 80B, 2 overflows into a new one
    assert pack_buckets(_leaves(10, 10, 10), 100) == [[0, 1], [2]]
    # a leaf larger than the cap gets its own bucket and closes the previous
    assert pack_buckets(_leaves(10, 100, 10), 100) == [[0], [1], [2]]
    # cap of one byte: every leaf alone
    assert pack_buckets(_leaves(2, 2, 2), 1) == [[0], [1], [2]]
    # everything fits
    assert pack_buckets(_leaves(2, 2, 2), 1 << 30) == [[0, 1, 2]]


def test_pack_buckets_zero_byte_leaves():
    # 0-byte leaves never force a bucket boundary
    assert pack_buckets(_leaves(0, 10, 0, 10), 100) == [[0, 1, 2, 3]]
    assert pack_buckets([], 100) == []


def test_tree_bytes():
    assert tree_bytes({"a": jnp.zeros((3,), jnp.float32),
                       "b": jnp.zeros((2,), jnp.int8)}) == 14


def test_overlap_schedules_registered():
    assert "int8_ef" in schedules_for("allreduce")
    assert "ring2d" in schedules_for("grid_transpose")


def test_allreduce_tree_single_device_identity():
    """On a 1-rank axis every schedule must return the tree unchanged."""
    mesh = make_mesh((1,), ("x",))
    tree = {"w": jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4)),
            "b": jnp.asarray(np.arange(3, dtype=np.float32)),
            "empty": jnp.zeros((0,), jnp.float32)}
    for schedule in ("native", "chain", "rs_ag", "ring2d"):
        eng = CollectiveEngine.for_mesh(mesh, schedule=schedule)
        fn = jax.jit(shard_map(
            lambda t, e=eng: e.allreduce_tree(t, "x", bucket_bytes=16),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False))
        out = fn(tree)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(tree[k]), err_msg=k)


def test_allreduce_tree_validates_axis():
    mesh = make_mesh((1,), ("x",))
    eng = CollectiveEngine.for_mesh(mesh)
    with pytest.raises(KeyError):
        eng.allreduce_tree({"a": jnp.zeros(3)}, "bogus")


def test_bucketed_psum_tree_single_device():
    mesh = make_mesh((1,), ("x",))
    tree = {"a": jnp.ones((5,), jnp.float32), "b": jnp.ones((2, 2))}
    with pytest.warns(DeprecationWarning, match="allreduce_tree"):
        fn = jax.jit(shard_map(lambda t: bucketed_psum_tree(t, "x", 8),
                               mesh=mesh, in_specs=(P(),), out_specs=P(),
                               check_vma=False))
        out = fn(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


def test_bucketed_psum_tree_is_deprecated_shim():
    """Single code path: the legacy wrapper must warn and forward to the
    engine op rather than carry its own reduction."""
    mesh = make_mesh((1,), ("x",))
    tree = {"a": jnp.ones((3,), jnp.float32)}
    with pytest.warns(DeprecationWarning):
        fn = jax.jit(shard_map(lambda t: bucketed_psum_tree(t, "x"),
                               mesh=mesh, in_specs=(P(),), out_specs=P(),
                               check_vma=False))
        out = fn(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_hpl_lookahead_single_cell_mesh():
    """lookahead on the trivial 1x1 torus matches eager bitwise."""
    from repro.core.hpl import generate_system, make_factorize
    mesh = make_mesh((1, 1), ("rows", "cols"))
    n, b = 64, 32
    a, _, _ = generate_system(n)
    a_sh = jnp.asarray(a)[None]
    eager = make_factorize(mesh, pg=1, nb=n // b, b=b)(a_sh)
    look = make_factorize(mesh, pg=1, nb=n // b, b=b, lookahead=True)(a_sh)
    np.testing.assert_array_equal(np.asarray(look), np.asarray(eager))
