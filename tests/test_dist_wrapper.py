"""Launches the distributed suite (tests/dist) in a fresh interpreter with 8
placeholder CPU devices — the assignment forbids setting the device-count
flag globally, so the main pytest process keeps 1 device."""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_distributed_suite():
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        REPRO_DIST_TESTS="1",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.environ.get("PYTHONPATH", "")]),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", os.path.join(REPO, "tests", "dist"),
         "-q", "--no-header", "-p", "no:cacheprovider"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=3000)
    tail = proc.stdout[-4000:] + "\n" + proc.stderr[-2000:]
    assert proc.returncode == 0, f"distributed suite failed:\n{tail}"
    print(proc.stdout.strip().splitlines()[-1])
