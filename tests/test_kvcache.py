"""Paged KV cache: specs, the host-side page allocator, gather/commit
round-trips, scheduler admission, and the continuous-batching engine
against the whole-batch ``generate`` reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.kvcache import (OutOfPagesError, PagedCacheConfig,
                                  PageAllocator, attn_cache_spec,
                                  commit_prefill, gather_pages,
                                  paged_attn_cache_spec, ssm_cache_spec)
from repro.serve import SERVE_MODES, Request, Scheduler, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    from repro.models.model import build_model
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# -- dense specs -----------------------------------------------------------


def test_dense_cache_specs():
    cfg = reduced(get_config("llama3.2-3b"))
    spec = attn_cache_spec(cfg, 3, 16, jnp.bfloat16)
    assert spec["k"].shape == (3, 16, cfg.num_kv_heads, cfg.head_dim)
    assert spec["v"].dtype == jnp.bfloat16

    mcfg = reduced(get_config("mamba2-130m"))
    sspec = ssm_cache_spec(mcfg, 2, jnp.float32)
    assert sspec["conv_x"].shape[0] == 2
    assert sspec["conv_x"].shape[1] == mcfg.ssm_conv - 1
    assert sspec["state"].dtype == jnp.float32  # SSD state stays f32


def test_paged_spec_shapes():
    cfg = reduced(get_config("llama3.2-3b"))
    pcfg = PagedCacheConfig(page_size=4, num_pages=10, max_slots=2,
                            max_seq=13)
    spec = paged_attn_cache_spec(cfg, pcfg, jnp.bfloat16)
    assert spec["k_pages"].shape == (10, 4, cfg.num_kv_heads, cfg.head_dim)
    assert spec["v_pages"].dtype == jnp.bfloat16
    assert pcfg.pages_per_slot == 4  # ceil(13 / 4)


def test_paged_config_validation():
    with pytest.raises(ValueError):
        PagedCacheConfig(page_size=0, num_pages=8, max_slots=2, max_seq=8)
    with pytest.raises(ValueError):
        PagedCacheConfig(page_size=4, num_pages=8, max_slots=-1, max_seq=8)


# -- allocator -------------------------------------------------------------


def _pcfg(**kw):
    base = dict(page_size=4, num_pages=8, max_slots=3, max_seq=16)
    base.update(kw)
    return PagedCacheConfig(**base)


def test_allocate_append_release_roundtrip():
    alloc = PageAllocator(_pcfg())
    s = alloc.allocate(10)  # 3 pages
    assert alloc.free_page_count == 5
    row = alloc.block_table[s]
    assert (row[:3] < 8).all() and (row[3:] == 8).all()  # sentinel tail
    alloc.commit(s, 6)
    assert alloc.seq_lens[s] == 6
    for _ in range(4):
        alloc.append(s)
    assert alloc.seq_lens[s] == 10
    # reserved capacity is 3 pages = 12 tokens: 2 more appends fit, not 3
    alloc.append(s, 2)
    with pytest.raises(OutOfPagesError):
        alloc.append(s)
    alloc.release(s)
    assert alloc.free_page_count == 8 and alloc.free_slot_count == 3
    assert (alloc.block_table[s] == 8).all()
    assert alloc.seq_lens[s] == 0


def test_allocator_exhaustion_and_recycle():
    alloc = PageAllocator(_pcfg())  # 8 pages
    a = alloc.allocate(16)  # 4 pages
    b = alloc.allocate(16)  # 4 pages -> pool empty
    assert not alloc.can_allocate(4)
    with pytest.raises(OutOfPagesError):
        alloc.allocate(4)
    alloc.release(a)
    assert alloc.can_allocate(16)
    c = alloc.allocate(16)
    assert c != b  # a's recycled pages back the new slot
    assert alloc.free_page_count == 0
    alloc.release(b), alloc.release(c)
    # all three slots busy -> no slot even though pages are free
    s = [alloc.allocate(4) for _ in range(3)]
    assert not alloc.can_allocate(4)
    with pytest.raises(OutOfPagesError):
        alloc.allocate(4)
    for x in s:
        alloc.release(x)


def test_allocate_validates_max_seq():
    alloc = PageAllocator(_pcfg())
    with pytest.raises(ValueError):
        alloc.allocate(17)  # > max_seq
    with pytest.raises(ValueError):
        alloc.allocate(0)
    s = alloc.allocate(4)
    with pytest.raises(ValueError):
        alloc.commit(s, 5)  # past the single reserved page


# -- gather / commit -------------------------------------------------------


def test_gather_pages_roundtrip():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((8, 4, 2, 3)), jnp.float32)
    bt = jnp.asarray([[5, 1, 8, 8], [0, 8, 8, 8]], jnp.int32)
    g = gather_pages(pages, bt)
    assert g.shape == (2, 16, 2, 3)
    np.testing.assert_array_equal(np.asarray(g[0, :4]), np.asarray(pages[5]))
    np.testing.assert_array_equal(np.asarray(g[0, 4:8]), np.asarray(pages[1]))
    np.testing.assert_array_equal(np.asarray(g[1, :4]), np.asarray(pages[0]))


def test_commit_prefill_roundtrip(setup):
    cfg, model, params = setup
    pcfg = _pcfg(max_seq=12)
    alloc = PageAllocator(pcfg)
    slot = alloc.allocate(9)
    from repro.models import transformer as T
    pages = T.init_paged_cache(cfg, pcfg, jnp.float32)

    rng = np.random.default_rng(1)
    S0, Spad = 6, 8  # prefill padded past the true length
    dense = model.init_cache(1, Spad, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, Spad)), jnp.int32)
    from repro.train.serve import make_prefill_step
    _, dense = make_prefill_step(model, None)(params, {"tokens": toks}, dense)

    out = commit_prefill(pages["layers"], dense["layers"],
                         jnp.asarray(alloc.block_table[slot]), S0,
                         page_size=pcfg.page_size)
    for name, stacked in out.items():
        g = gather_pages(stacked["k_pages"][0],
                         jnp.asarray(alloc.block_table[slot])[None])
        ref = np.asarray(dense["layers"][name]["k"][0, 0])
        np.testing.assert_allclose(np.asarray(g[0, :S0]), ref[:S0])
        # pad positions (>= S0) dropped on the sentinel, pages stay zero
        np.testing.assert_array_equal(np.asarray(g[0, S0:]), 0.0)


# -- scheduler -------------------------------------------------------------


def test_scheduler_budget_and_admission():
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=32,
                                           max_slots=4, max_seq=24))
    sched = Scheduler(alloc, prefill_token_budget=10)
    for rid, plen in enumerate((6, 6, 6)):
        sched.submit(Request(rid=rid,
                             prompt=np.zeros((plen,), np.int32),
                             max_new_tokens=4))
    first = sched.admit()
    # 6 + 6 > 10: the second admission waits for the next step
    assert [r.rid for r in first] == [0, 1] or [r.rid for r in first] == [0]
    assert sum(r.prompt_len for r in first) <= 10 + first[-1].prompt_len
    second = sched.admit()
    assert {r.rid for r in first} | {r.rid for r in second} >= {0, 1}


def test_scheduler_oversized_head_admitted_alone():
    """A prompt longer than the budget must not starve at the head."""
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=32,
                                           max_slots=4, max_seq=24))
    sched = Scheduler(alloc, prefill_token_budget=4)
    sched.submit(Request(rid=0, prompt=np.zeros((12,), np.int32),
                         max_new_tokens=4))
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0]


def test_scheduler_rejects_over_max_seq():
    alloc = PageAllocator(_pcfg())
    sched = Scheduler(alloc)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.zeros((15,), np.int32),
                             max_new_tokens=4))  # 19 > max_seq=16


def test_scheduler_slot_recycling():
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=8,
                                           max_slots=1, max_seq=16))
    sched = Scheduler(alloc)
    for rid in range(2):
        sched.submit(Request(rid=rid, prompt=np.zeros((4,), np.int32),
                             max_new_tokens=4))
    (a,) = sched.admit()
    assert sched.admit() == []  # single slot busy
    sched.finish(a, "max_new")
    assert a.done and a.finish_reason == "max_new" and a.slot is None
    (b,) = sched.admit()
    assert b.rid == 1 and b.slot == 0  # recycled


# -- engine ----------------------------------------------------------------


def test_serve_engine_matches_generate(setup):
    """Continuous batching (shared pool, slot churn, mixed steps) must be
    token-exact against the whole-batch dense reference."""
    from repro.train.serve import generate
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (5, 9, 3, 12)]
    max_new = 5

    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_seq=32)
    eng = ServeEngine(model, params, pcfg, prefill_token_budget=12)
    out, stats = eng.run(prompts, max_new_tokens=max_new, collect_stats=True)

    assert max(s["active"] for s in stats) <= 2  # never beyond the slots
    for rid, prompt in enumerate(prompts):
        ref = generate(model, params, jnp.asarray(prompt[None]),
                       max_new_tokens=max_new)
        np.testing.assert_array_equal(np.asarray(ref[0]), out[rid])


def test_serve_engine_eos_recycles_early(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_seq=16)
    free = ServeEngine(model, params, pcfg).run([prompt], max_new_tokens=6)
    eos = int(free[0][7])  # the 2nd generated token

    eng = ServeEngine(model, params, pcfg, eos_id=eos)
    rid = eng.submit(prompt, max_new_tokens=6)
    out = eng.run()
    assert out[rid].shape[0] < prompt.shape[0] + 6  # stopped at EOS
    assert out[rid][-1] == eos
    assert eng.alloc.free_slot_count == 1  # slot recycled


def test_serve_engine_rejects_impossible_request(setup):
    cfg, model, params = setup
    pcfg = PagedCacheConfig(page_size=4, num_pages=2, max_slots=1,
                            max_seq=16)  # pool of 8 tokens
    eng = ServeEngine(model, params, pcfg)
    # a request whose worst-case reservation exceeds the whole pool is
    # refused at submit() — it could never be admitted, even idle
    with pytest.raises(OutOfPagesError, match="never be admitted"):
        eng.submit(np.zeros((8,), np.int32), max_new_tokens=4)  # needs 12
    assert not eng.scheduler.has_work


def test_serve_engine_idle_pool_raise_via_scheduler_bypass(setup):
    # the step()-time guard still fires for requests that skip the
    # engine's submit() validation (direct scheduler use)
    cfg, model, params = setup
    pcfg = PagedCacheConfig(page_size=4, num_pages=2, max_slots=1,
                            max_seq=16)
    eng = ServeEngine(model, params, pcfg)
    eng.scheduler.submit(Request(rid=0, prompt=np.zeros((8,), np.int32),
                                 max_new_tokens=4))
    with pytest.raises(OutOfPagesError, match="pool is idle yet too small"):
        eng.run()


def test_serve_engine_mode_validation(setup):
    cfg, model, params = setup
    pcfg = _pcfg()
    with pytest.raises(ValueError, match="unknown serve mode"):
        ServeEngine(model, params, pcfg, mode="speculative")
    with pytest.raises(ValueError, match="requires a mesh"):
        ServeEngine(model, params, pcfg, mode="explicit")
    assert SERVE_MODES == ("gspmd", "explicit")


# ---------------------------------------------------------------------------
# graceful degradation: preemption, deadlines, bounded retry
# ---------------------------------------------------------------------------


def test_bucket_clamps_to_max_context():
    from repro.serve.engine import _bucket
    assert _bucket(5) == 8
    assert _bucket(12, hi=16) == 16
    assert _bucket(12, hi=20) == 16   # pow2 still below the cap
    assert _bucket(17, hi=20) == 20   # top bucket is exactly the cap
    with pytest.raises(ValueError, match="max context"):
        _bucket(21, hi=20)


def test_serve_preemption_zero_lost_tokens(setup):
    """Under page exhaustion the engine evicts the youngest active, re-queues
    it with prompt+generated intact, and the resumed stream is token-exact
    vs a pool that never had to preempt."""
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    pa = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)

    big = ServeEngine(model, params,
                      PagedCacheConfig(page_size=4, num_pages=16,
                                       max_slots=2, max_seq=16))
    big.submit(pa, max_new_tokens=8)
    big.submit(pb, max_new_tokens=4)
    ref = big.run()

    # 4 pages: A (4+8 -> 3 pages) and B (4+4 -> 2 pages) cannot coexist
    small = ServeEngine(model, params,
                        PagedCacheConfig(page_size=4, num_pages=4,
                                         max_slots=2, max_seq=16),
                        preempt=True)
    small.submit(pa, max_new_tokens=8)
    small.submit(pb, max_new_tokens=4)
    out, stats = small.run(collect_stats=True)

    assert small.scheduler.preempted_total >= 1
    assert sum(s["preempted"] for s in stats) == small.scheduler.preempted_total
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


def test_serve_preemption_bounded_per_request(setup):
    """No request is evicted past max_preemptions — the livelock guard."""
    cfg, model, params = setup
    rng = np.random.default_rng(8)
    eng = ServeEngine(model, params,
                      PagedCacheConfig(page_size=4, num_pages=4,
                                       max_slots=2, max_seq=16),
                      preempt=True)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, size=(4,))
                       .astype(np.int32), max_new_tokens=8)
            for _ in range(3)]
    out = eng.run()
    assert eng.scheduler.max_preemptions == 1
    assert set(out) == set(rids)
    for rid in rids:
        assert out[rid].shape[0] == 4 + 8  # nobody lost tokens


def test_serve_deadline_timeout_waiting_and_active(setup):
    import time as _time
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)

    # expires while waiting: pool busy is not even required — the deadline
    # check runs before admission
    eng = ServeEngine(model, params, _pcfg())
    eng.submit(prompt, max_new_tokens=4, deadline_s=1e-9)
    req = eng.scheduler.waiting[0]
    _time.sleep(0.01)
    stats = eng.step()
    assert req.done and req.finish_reason == "timeout"
    assert stats["timeouts"] == 1 and req.generated == []

    # expires mid-decode: partial generation is kept, slot recycles
    eng2 = ServeEngine(model, params,
                       PagedCacheConfig(page_size=4, num_pages=32,
                                        max_slots=3, max_seq=128))
    eng2.submit(prompt, max_new_tokens=64, deadline_s=0.05)
    req2 = eng2.scheduler.waiting[0]
    eng2.step()  # admit + prefill + first decode
    assert req2.slot is not None
    _time.sleep(0.06)
    eng2.step()
    assert req2.done and req2.finish_reason == "timeout"
    assert 0 < len(req2.generated) < 64
    assert req2.slot is None and eng2.alloc.free_slot_count == 3


def test_serve_bounded_retry_rejects_head(setup):
    """A head that cannot be admitted within admission_retries attempts is
    finished with reason 'rejected' instead of blocking forever."""
    cfg, model, params = setup
    rng = np.random.default_rng(10)
    pa = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, size=(4,)).astype(np.int32)
    eng = ServeEngine(model, params,
                      PagedCacheConfig(page_size=4, num_pages=8,
                                       max_slots=1, max_seq=16),
                      admission_retries=2)
    ra = eng.submit(pa, max_new_tokens=12)  # holds the only slot 12 steps
    rb = eng.submit(pb, max_new_tokens=4)
    reqb = eng.scheduler.waiting[1]  # [0] is A, admitted on the first step
    out, stats = eng.run(collect_stats=True)
    assert reqb.finish_reason == "rejected"
    assert sum(s["rejected"] for s in stats) == 1
    assert out[ra].shape[0] == 4 + 12    # the active stream was untouched
    np.testing.assert_array_equal(out[rb], pb)  # rejected: prompt only
