"""Paged KV cache: specs, the host-side page allocator, gather/commit
round-trips, scheduler admission, and the continuous-batching engine
against the whole-batch ``generate`` reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.kvcache import (OutOfPagesError, PagedCacheConfig,
                                  PageAllocator, attn_cache_spec,
                                  commit_prefill, gather_pages,
                                  paged_attn_cache_spec, ssm_cache_spec)
from repro.serve import SERVE_MODES, Request, Scheduler, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    from repro.models.model import build_model
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# -- dense specs -----------------------------------------------------------


def test_dense_cache_specs():
    cfg = reduced(get_config("llama3.2-3b"))
    spec = attn_cache_spec(cfg, 3, 16, jnp.bfloat16)
    assert spec["k"].shape == (3, 16, cfg.num_kv_heads, cfg.head_dim)
    assert spec["v"].dtype == jnp.bfloat16

    mcfg = reduced(get_config("mamba2-130m"))
    sspec = ssm_cache_spec(mcfg, 2, jnp.float32)
    assert sspec["conv_x"].shape[0] == 2
    assert sspec["conv_x"].shape[1] == mcfg.ssm_conv - 1
    assert sspec["state"].dtype == jnp.float32  # SSD state stays f32


def test_paged_spec_shapes():
    cfg = reduced(get_config("llama3.2-3b"))
    pcfg = PagedCacheConfig(page_size=4, num_pages=10, max_slots=2,
                            max_seq=13)
    spec = paged_attn_cache_spec(cfg, pcfg, jnp.bfloat16)
    assert spec["k_pages"].shape == (10, 4, cfg.num_kv_heads, cfg.head_dim)
    assert spec["v_pages"].dtype == jnp.bfloat16
    assert pcfg.pages_per_slot == 4  # ceil(13 / 4)


def test_paged_config_validation():
    with pytest.raises(ValueError):
        PagedCacheConfig(page_size=0, num_pages=8, max_slots=2, max_seq=8)
    with pytest.raises(ValueError):
        PagedCacheConfig(page_size=4, num_pages=8, max_slots=-1, max_seq=8)


# -- allocator -------------------------------------------------------------


def _pcfg(**kw):
    base = dict(page_size=4, num_pages=8, max_slots=3, max_seq=16)
    base.update(kw)
    return PagedCacheConfig(**base)


def test_allocate_append_release_roundtrip():
    alloc = PageAllocator(_pcfg())
    s = alloc.allocate(10)  # 3 pages
    assert alloc.free_page_count == 5
    row = alloc.block_table[s]
    assert (row[:3] < 8).all() and (row[3:] == 8).all()  # sentinel tail
    alloc.commit(s, 6)
    assert alloc.seq_lens[s] == 6
    for _ in range(4):
        alloc.append(s)
    assert alloc.seq_lens[s] == 10
    # reserved capacity is 3 pages = 12 tokens: 2 more appends fit, not 3
    alloc.append(s, 2)
    with pytest.raises(OutOfPagesError):
        alloc.append(s)
    alloc.release(s)
    assert alloc.free_page_count == 8 and alloc.free_slot_count == 3
    assert (alloc.block_table[s] == 8).all()
    assert alloc.seq_lens[s] == 0


def test_allocator_exhaustion_and_recycle():
    alloc = PageAllocator(_pcfg())  # 8 pages
    a = alloc.allocate(16)  # 4 pages
    b = alloc.allocate(16)  # 4 pages -> pool empty
    assert not alloc.can_allocate(4)
    with pytest.raises(OutOfPagesError):
        alloc.allocate(4)
    alloc.release(a)
    assert alloc.can_allocate(16)
    c = alloc.allocate(16)
    assert c != b  # a's recycled pages back the new slot
    assert alloc.free_page_count == 0
    alloc.release(b), alloc.release(c)
    # all three slots busy -> no slot even though pages are free
    s = [alloc.allocate(4) for _ in range(3)]
    assert not alloc.can_allocate(4)
    with pytest.raises(OutOfPagesError):
        alloc.allocate(4)
    for x in s:
        alloc.release(x)


def test_allocate_validates_max_seq():
    alloc = PageAllocator(_pcfg())
    with pytest.raises(ValueError):
        alloc.allocate(17)  # > max_seq
    with pytest.raises(ValueError):
        alloc.allocate(0)
    s = alloc.allocate(4)
    with pytest.raises(ValueError):
        alloc.commit(s, 5)  # past the single reserved page


# -- gather / commit -------------------------------------------------------


def test_gather_pages_roundtrip():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((8, 4, 2, 3)), jnp.float32)
    bt = jnp.asarray([[5, 1, 8, 8], [0, 8, 8, 8]], jnp.int32)
    g = gather_pages(pages, bt)
    assert g.shape == (2, 16, 2, 3)
    np.testing.assert_array_equal(np.asarray(g[0, :4]), np.asarray(pages[5]))
    np.testing.assert_array_equal(np.asarray(g[0, 4:8]), np.asarray(pages[1]))
    np.testing.assert_array_equal(np.asarray(g[1, :4]), np.asarray(pages[0]))


def test_commit_prefill_roundtrip(setup):
    cfg, model, params = setup
    pcfg = _pcfg(max_seq=12)
    alloc = PageAllocator(pcfg)
    slot = alloc.allocate(9)
    from repro.models import transformer as T
    pages = T.init_paged_cache(cfg, pcfg, jnp.float32)

    rng = np.random.default_rng(1)
    S0, Spad = 6, 8  # prefill padded past the true length
    dense = model.init_cache(1, Spad, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, Spad)), jnp.int32)
    from repro.train.serve import make_prefill_step
    _, dense = make_prefill_step(model, None)(params, {"tokens": toks}, dense)

    out = commit_prefill(pages["layers"], dense["layers"],
                         jnp.asarray(alloc.block_table[slot]), S0,
                         page_size=pcfg.page_size)
    for name, stacked in out.items():
        g = gather_pages(stacked["k_pages"][0],
                         jnp.asarray(alloc.block_table[slot])[None])
        ref = np.asarray(dense["layers"][name]["k"][0, 0])
        np.testing.assert_allclose(np.asarray(g[0, :S0]), ref[:S0])
        # pad positions (>= S0) dropped on the sentinel, pages stay zero
        np.testing.assert_array_equal(np.asarray(g[0, S0:]), 0.0)


# -- scheduler -------------------------------------------------------------


def test_scheduler_budget_and_admission():
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=32,
                                           max_slots=4, max_seq=24))
    sched = Scheduler(alloc, prefill_token_budget=10)
    for rid, plen in enumerate((6, 6, 6)):
        sched.submit(Request(rid=rid,
                             prompt=np.zeros((plen,), np.int32),
                             max_new_tokens=4))
    first = sched.admit()
    # 6 + 6 > 10: the second admission waits for the next step
    assert [r.rid for r in first] == [0, 1] or [r.rid for r in first] == [0]
    assert sum(r.prompt_len for r in first) <= 10 + first[-1].prompt_len
    second = sched.admit()
    assert {r.rid for r in first} | {r.rid for r in second} >= {0, 1}


def test_scheduler_oversized_head_admitted_alone():
    """A prompt longer than the budget must not starve at the head."""
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=32,
                                           max_slots=4, max_seq=24))
    sched = Scheduler(alloc, prefill_token_budget=4)
    sched.submit(Request(rid=0, prompt=np.zeros((12,), np.int32),
                         max_new_tokens=4))
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0]


def test_scheduler_rejects_over_max_seq():
    alloc = PageAllocator(_pcfg())
    sched = Scheduler(alloc)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.zeros((15,), np.int32),
                             max_new_tokens=4))  # 19 > max_seq=16


def test_scheduler_slot_recycling():
    alloc = PageAllocator(PagedCacheConfig(page_size=4, num_pages=8,
                                           max_slots=1, max_seq=16))
    sched = Scheduler(alloc)
    for rid in range(2):
        sched.submit(Request(rid=rid, prompt=np.zeros((4,), np.int32),
                             max_new_tokens=4))
    (a,) = sched.admit()
    assert sched.admit() == []  # single slot busy
    sched.finish(a, "max_new")
    assert a.done and a.finish_reason == "max_new" and a.slot is None
    (b,) = sched.admit()
    assert b.rid == 1 and b.slot == 0  # recycled


# -- engine ----------------------------------------------------------------


def test_serve_engine_matches_generate(setup):
    """Continuous batching (shared pool, slot churn, mixed steps) must be
    token-exact against the whole-batch dense reference."""
    from repro.train.serve import generate
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (5, 9, 3, 12)]
    max_new = 5

    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_seq=32)
    eng = ServeEngine(model, params, pcfg, prefill_token_budget=12)
    out, stats = eng.run(prompts, max_new_tokens=max_new, collect_stats=True)

    assert max(s["active"] for s in stats) <= 2  # never beyond the slots
    for rid, prompt in enumerate(prompts):
        ref = generate(model, params, jnp.asarray(prompt[None]),
                       max_new_tokens=max_new)
        np.testing.assert_array_equal(np.asarray(ref[0]), out[rid])


def test_serve_engine_eos_recycles_early(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=1,
                            max_seq=16)
    free = ServeEngine(model, params, pcfg).run([prompt], max_new_tokens=6)
    eos = int(free[0][7])  # the 2nd generated token

    eng = ServeEngine(model, params, pcfg, eos_id=eos)
    rid = eng.submit(prompt, max_new_tokens=6)
    out = eng.run()
    assert out[rid].shape[0] < prompt.shape[0] + 6  # stopped at EOS
    assert out[rid][-1] == eos
    assert eng.alloc.free_slot_count == 1  # slot recycled


def test_serve_engine_rejects_impossible_request(setup):
    cfg, model, params = setup
    pcfg = PagedCacheConfig(page_size=4, num_pages=2, max_slots=1,
                            max_seq=16)  # pool of 8 tokens
    eng = ServeEngine(model, params, pcfg)
    eng.submit(np.zeros((8,), np.int32), max_new_tokens=4)  # needs 12
    with pytest.raises(OutOfPagesError):
        eng.run()


def test_serve_engine_mode_validation(setup):
    cfg, model, params = setup
    pcfg = _pcfg()
    with pytest.raises(ValueError, match="unknown serve mode"):
        ServeEngine(model, params, pcfg, mode="speculative")
    with pytest.raises(ValueError, match="requires a mesh"):
        ServeEngine(model, params, pcfg, mode="explicit")
    assert SERVE_MODES == ("gspmd", "explicit")
