"""Dry-run integration: one real (arch x shape x mesh) cell lowered and
compiled on the 512-placeholder-device production mesh in a subprocess
(keeps this process at 1 device per the assignment)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_compiles(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(REPO, "src"), os.environ.get("PYTHONPATH", "")]))
    out = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-base",
         "--shape", "decode_32k", "--mesh", "multi", "--force", "--out", out],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec_path = os.path.join(out, "whisper-base__decode_32k__multi.json")
    with open(rec_path) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["flops_per_device"] > 0
    assert rec["memory_analysis"]["temp_size_in_bytes"] < 16 * 2**30  # fits HBM


def test_dryrun_results_complete():
    """The committed sweep must cover all 80 (cell x mesh) slots: 64 ok +
    16 documented skips, zero failures."""
    d = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        import pytest
        pytest.skip("full sweep results not present")
    statuses = {}
    for fn in os.listdir(d):
        with open(os.path.join(d, fn)) as f:
            statuses[fn] = json.load(f)["status"]
    assert sum(s == "ok" for s in statuses.values()) == 64
    assert sum(s == "skipped" for s in statuses.values()) == 16
    assert not any(s == "failed" for s in statuses.values())
