"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced same-family config — forward + one train step on CPU, asserting
output shapes and no NaNs — plus decode/prefill cache consistency and the
MoE dispatch vs. its dense oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, list_archs, reduced
from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.models.model import build_model, next_token_loss
from repro.train.step import init_train_state, make_train_step

ARCHS = list_archs()


def _batch(cfg: ModelConfig, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.vision_dim)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.audio_ctx, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, _, _ = model.apply(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = next_token_loss(logits, batch["tokens"])
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    run = RunConfig(learning_rate=1e-2, warmup_steps=1)
    step = make_train_step(model, run, mesh, donate=False)
    state = init_train_state(model, jax.random.key(0))
    batch = _batch(cfg)
    state1, m1 = step(state, batch)
    state2, m2 = step(state1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # same batch, post-warmup update: loss must decrease
    assert float(m2["loss"]) < float(m1["loss"]) + 1e-6, arch
    assert int(state2.step) == 2
    for leaf in jax.tree.leaves(state2.params):
        assert not bool(jnp.any(jnp.isnan(leaf)))


# families with capacity-based MoE dispatch: prefill (many tokens compete
# for expert capacity) legitimately differs from decode (single token), so
# the tolerance is loose for them.
DECODE_TOL = {"moe": 5e-2, "hybrid": 5e-2}


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S, MAX = 2, 8, 32
    batch = _batch(cfg, B=B, S=S)

    cache = model.init_cache(B, MAX, jnp.float32)
    logits_p, cache, _ = model.apply(params, batch, cache=cache)

    dec = {"tokens": batch["tokens"][:, -1:]}
    if "patch_embeds" in batch:
        dec["patch_embeds"] = batch["patch_embeds"]
    logits_d, cache, _ = model.apply(params, dec, cache=cache)

    full = dict(batch)
    full["tokens"] = jnp.concatenate(
        [batch["tokens"], batch["tokens"][:, -1:]], axis=1)
    logits_f, _, _ = model.apply(params, full)

    tol = DECODE_TOL.get(cfg.family, 1e-4)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_f[:, -1]), atol=tol, rtol=tol)
    # prefill logits must match the no-cache forward exactly-ish
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_f[:, :S]), atol=tol, rtol=tol)


def test_moe_matches_dense_oracle():
    """With capacity >> need, scatter dispatch equals the dense expert loop."""
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})
    key = jax.random.key(1)
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model), jnp.float32)
    got = MOE.apply_moe(p, cfg, x)
    want = MOE.reference_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_moe_capacity_drops_are_bounded():
    cfg = reduced(get_config("llama4-maverick-400b-a17b"))
    p = MOE.init_moe(jax.random.key(1), cfg)
    x = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model), jnp.float32)
    aux = {}
    MOE.apply_moe(p, cfg, x, aux=aux)
    assert float(aux["moe_dropped"]) <= 0.6  # top-1 of 4 experts, cap 1.25
    np.testing.assert_allclose(float(jnp.sum(aux["moe_frac_tokens"])), 1.0,
                               atol=1e-5)


def test_microbatch_grads_match():
    """Gradient accumulation over microbatches == full-batch gradients."""
    cfg = reduced(get_config("llama3.2-3b"))
    model = build_model(cfg)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    batch = _batch(cfg, B=4, S=16)
    state = init_train_state(model, jax.random.key(0))
    outs = {}
    for nm in (1, 2, 4):
        run = RunConfig(learning_rate=1e-2, warmup_steps=0, microbatches=nm)
        step = make_train_step(model, run, mesh, donate=False)
        st, m = step(state, batch)
        outs[nm] = (float(m["loss"]), jax.tree.leaves(st.params)[0])
    np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-5)
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=1e-5)
    # atol admits the reduction-order jitter of the multi-device CPU runtime
    # (CI runs the suite under 8 placeholder devices; threading differs)
    np.testing.assert_allclose(np.asarray(outs[1][1]), np.asarray(outs[4][1]),
                               atol=5e-5)


@pytest.mark.parametrize("remat", ["none", "full", "dots"])
def test_remat_policies_same_loss(remat):
    cfg = reduced(get_config("llama3-8b"))
    model = build_model(cfg)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    run = RunConfig(learning_rate=1e-2, warmup_steps=1, remat=remat)
    step = make_train_step(model, run, mesh, donate=False)
    state = init_train_state(model, jax.random.key(0))
    _, m = step(state, _batch(cfg))
    # remat must not change numerics
    assert abs(float(m["loss"]) - 6.25) < 0.5
