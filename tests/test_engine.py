"""Collective-engine registry and resolution tests (single device).

Multi-device schedule *equivalence* runs in tests/dist/test_schedules.py on
the simulated 8-device mesh (launched by tests/test_dist_wrapper.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.engine import (OPS, CollectiveEngine, UnknownScheduleError,
                               known_schedules, register_schedule,
                               schedules_for)
from repro.comm.topology import AxisTopology, MeshTopology
from repro.comm.types import CommunicationType as CT
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P


def test_registry_has_core_schedules():
    assert {"chain", "native", "staged", "ring2d"} <= set(schedules_for("bcast"))
    assert {"chain", "native", "staged", "rs_ag", "ring2d", "int8_ef"} <= set(
        schedules_for("allreduce"))
    assert {"chain", "native", "staged"} <= set(
        schedules_for("all_to_all_tiles"))
    assert {"direct", "staged"} <= set(schedules_for("ring_exchange"))
    assert {"direct", "staged", "ring2d"} <= set(
        schedules_for("grid_transpose"))
    assert "auto" in known_schedules()


def test_unknown_schedule_rejected_with_clear_error():
    with pytest.raises(UnknownScheduleError) as exc:
        CollectiveEngine(schedule="fastest")
    msg = str(exc.value)
    assert "fastest" in msg and "chain" in msg  # names the options


def test_unknown_per_call_override_rejected():
    eng = CollectiveEngine()
    with pytest.raises(UnknownScheduleError) as exc:
        eng.schedule_for("bcast", "direct")  # registered, but not for bcast
    assert "bcast" in str(exc.value)


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        CollectiveEngine().schedule_for("gather")
    with pytest.raises(ValueError):
        register_schedule("gather", "x")


def test_host_staged_forces_staged_everywhere():
    eng = CollectiveEngine(comm=CT.HOST_STAGED, schedule="chain")
    assert all(eng.schedule_for(op) == "staged" for op in OPS)


def test_auto_defaults_and_partial_name_fallback():
    eng = CollectiveEngine()  # auto
    assert eng.schedule_for("bcast") == "chain"
    assert eng.schedule_for("allreduce") == "native"
    assert eng.schedule_for("all_to_all_tiles") == "native"
    # 'rs_ag' exists only for allreduce: other ops fall back to their default
    eng = CollectiveEngine(schedule="rs_ag")
    assert eng.schedule_for("allreduce") == "rs_ag"
    assert eng.schedule_for("bcast") == "chain"
    assert eng.schedule_for("ring_exchange") == "direct"


def test_custom_schedule_registration():
    @register_schedule("allreduce", "double_native")
    def _ar(engine, x, axis):
        from jax import lax
        return lax.psum(x, axis) * 0 + lax.psum(x, axis)

    assert "double_native" in schedules_for("allreduce")
    eng = CollectiveEngine(schedule="double_native")
    assert eng.schedule_for("allreduce") == "double_native"


def test_topology_metadata_and_validation():
    mesh = make_mesh((1, 1), ("rows", "cols"))
    topo = MeshTopology.from_mesh(mesh)
    assert topo.axis("rows").kind == "torus_row"
    assert topo.axis("cols").kind == "torus_col"
    assert topo.size(("rows", "cols")) == 1
    assert isinstance(topo.axis("rows"), AxisTopology)
    with pytest.raises(KeyError):
        topo.axis("nonexistent")
    eng = CollectiveEngine.for_mesh(mesh)
    with pytest.raises(KeyError):
        eng.bcast(jnp.zeros(4), "bogus_axis", 0)
    desc = eng.describe()
    assert desc["topology"] == {"rows": "torus_row[1]", "cols": "torus_col[1]"}
    assert desc["resolved"]["bcast"] == "chain"


@pytest.mark.parametrize("schedule", ["chain", "native", "staged", "ring2d",
                                      "rs_ag"])
def test_single_rank_ops_are_identity(schedule):
    """Every schedule degenerates to identity on a 1-rank axis."""
    mesh = make_mesh((1,), ("x",))
    eng = CollectiveEngine.for_mesh(mesh, schedule=schedule)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 4, 128)),
                    jnp.float32)

    def body(v):
        out = eng.allreduce(v[0], "x")
        out = eng.bcast(out, "x", 0)
        out = eng.all_to_all_tiles(out, "x", split_axis=0, concat_axis=0)
        return out[None]

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x", None, None),),
                           out_specs=P("x", None, None), check_vma=False))
    np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))


def test_pipelined_rejects_unsupported_ops():
    eng = CollectiveEngine()
    with pytest.raises(ValueError) as exc:
        eng.pipelined("ring_exchange", jnp.zeros((4, 4)), "x", nchunks=2)
    assert "single-payload" in str(exc.value)
    with pytest.raises(ValueError):
        eng.pipelined("nonsense", jnp.zeros((4, 4)), "x", nchunks=2)
    # missing per-op operands fail fast with a named error, not a KeyError
    with pytest.raises(ValueError, match="src"):
        eng.pipelined("bcast", jnp.zeros((4, 4)), "x", nchunks=2)
    with pytest.raises(ValueError, match="pg"):
        eng.pipelined("grid_transpose", jnp.zeros((4, 4)),
                      ("rows", "cols"), nchunks=2)
    with pytest.raises(ValueError, match="tile_split_axis"):
        eng.pipelined("all_to_all_tiles", jnp.zeros((4, 4, 4)), "x",
                      nchunks=2, split_axis=2)
    with pytest.raises(ValueError, match="tile_concat_axis"):
        eng.pipelined("all_to_all_tiles", jnp.zeros((4, 4, 4)), "x",
                      nchunks=2, split_axis=2, tile_split_axis=0)
    # strips along a tile axis would change the tile boundaries the
    # exchange moves — rejected before any slicing happens
    for bad in (0, 1):
        with pytest.raises(ValueError, match="tile axis"):
            eng.pipelined("all_to_all_tiles", jnp.zeros((4, 4, 4)), "x",
                          nchunks=2, split_axis=bad, tile_split_axis=0,
                          tile_concat_axis=1)


@pytest.mark.parametrize("nchunks", [1, 2, 3, 64, "auto"])
def test_pipelined_a2a_single_rank_identity(nchunks):
    """The pipelined all_to_all_tiles on a 1-rank axis reproduces the input
    exactly for every chunk count (strips along the capacity-style axis,
    tile axes untouched)."""
    mesh = make_mesh((1,), ("x",))
    eng = CollectiveEngine.for_mesh(mesh)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 2, 6, 4)),
                    jnp.float32)

    def body(v):
        return eng.pipelined("all_to_all_tiles", v[0], "x", nchunks=nchunks,
                             split_axis=2, tile_split_axis=1,
                             tile_concat_axis=0)[None]

    fn = jax.jit(shard_map(body, mesh=mesh,
                           in_specs=(P("x", None, None, None),),
                           out_specs=P("x", None, None, None),
                           check_vma=False))
    np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))


@pytest.mark.parametrize("nchunks", [1, 2, 3, 64, "auto"])
def test_pipelined_single_rank_identity(nchunks):
    """Chunked ops on a 1-rank axis reproduce the input exactly for every
    chunk count (including nchunks > rows, clamped to one row per strip)."""
    mesh = make_mesh((1,), ("x",))
    eng = CollectiveEngine.for_mesh(mesh)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 12, 8)),
                    jnp.float32)

    def body(v):
        out = eng.pipelined("allreduce", v[0], "x", nchunks=nchunks)
        out = eng.pipelined("bcast", out, "x", src=0, nchunks=nchunks,
                            split_axis=1)
        return out[None]

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x", None, None),),
                           out_specs=P("x", None, None), check_vma=False))
    np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))


def test_pipelined_consume_and_concat_axis():
    """consume runs per strip with its static start offset; outputs
    concatenate along concat_axis."""
    mesh = make_mesh((1,), ("x",))
    eng = CollectiveEngine.for_mesh(mesh)
    x = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    starts = []

    def body(v):
        def consume(strip, start):
            starts.append(start)
            return strip.T  # (4, rows) -> concat along axis 1
        return eng.pipelined("bcast", v[0], "x", src=0, nchunks=3,
                             concat_axis=1, consume=consume)[None]

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x", None, None),),
                           out_specs=P("x", None, None), check_vma=False))
    out = np.asarray(fn(x[None]))[0]
    assert starts == [0, 2, 4]  # three equal strips of the 6 rows
    np.testing.assert_array_equal(out, np.asarray(x).T)


def test_fused_ring_step_matches_plain_add():
    from repro.kernels.ring import fused_chunk_add
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(fused_chunk_add(a, b)),
                                  np.asarray(a + b))
    # ragged chunk falls back to the jnp add, same semantics
    a2, b2 = a.reshape(-1)[:100], b.reshape(-1)[:100]
    np.testing.assert_array_equal(np.asarray(fused_chunk_add(a2, b2)),
                                  np.asarray(a2 + b2))
