"""Hypothesis property tests on system invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import compression
from repro.comm.autotune import CostModel, route_links
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.comm.topology import AxisTopology
from repro.comm.types import TPU_V5E
from repro.compat import make_mesh, shard_map
from repro.core import models
from repro.core.ptrans import distribute_cyclic, undistribute_cyclic
from repro.data import DataConfig, SyntheticLMDataset
from repro.kernels.gemm import fit_block
from repro.models.model import next_token_loss
from repro.roofline import _wire_factor, shape_bytes

SETTINGS = settings(max_examples=25, deadline=None)
# collective property tests jit-compile per drawn shape: keep the example
# count small and the shape pools discrete so the compile cache saturates
A2A_SETTINGS = settings(max_examples=10, deadline=None)


# --- PQ block-cyclic distribution is a bijection ---------------------------


@SETTINGS
@given(pg=st.sampled_from([1, 2, 4]),
       lb=st.integers(1, 3),
       b=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_distribute_undistribute_roundtrip(pg, lb, b, seed):
    n = pg * lb * b
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, n)).astype(np.float32)
    shards = distribute_cyclic(mat, pg, b)
    assert shards.shape == (pg * pg, lb * b, lb * b)
    back = undistribute_cyclic(shards, pg, b)
    np.testing.assert_array_equal(back, mat)


@SETTINGS
@given(pg=st.sampled_from([2, 4]), b=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**31 - 1))
def test_distribution_preserves_multiset(pg, b, seed):
    n = pg * 2 * b
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((n, n)).astype(np.float32)
    shards = distribute_cyclic(mat, pg, b)
    np.testing.assert_allclose(np.sort(shards.ravel()), np.sort(mat.ravel()))


# --- int8 error-feedback quantization ---------------------------------------


@SETTINGS
@given(size=st.integers(1, 2000), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**31 - 1))
def test_quantize_error_bound(size, scale, seed):
    """|x - deq(q(x))| <= max|block| / 127 / 2 per element (half-step)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(size).astype(np.float32) * scale)
    q, s = compression.quantize(x)
    back = compression.dequantize(q, s, x.shape, x.size)
    blocks = np.asarray(jnp.pad(x, (0, (-x.size) % compression.BLOCK))
                        ).reshape(-1, compression.BLOCK)
    bound = np.abs(blocks).max(axis=1) / 127.0 * 0.5 + 1e-7
    err = np.abs(np.asarray(back) - np.asarray(x)).reshape(-1)
    per_block_err = np.pad(err, (0, (-err.size) % compression.BLOCK)
                           ).reshape(-1, compression.BLOCK)
    assert (per_block_err.max(axis=1) <= bound + 1e-6).all()


# --- fit_block always returns a divisor -------------------------------------


@SETTINGS
@given(size=st.integers(1, 4096), pref=st.integers(1, 512))
def test_fit_block_divides(size, pref):
    b = fit_block(size, pref)
    assert 1 <= b <= max(pref, 1)
    assert size % b == 0


# --- loss properties ---------------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_nonnegative_and_uniform_bound(seed):
    """CE >= 0; for logits ~ 0 the loss is ~= log(V)."""
    rng = np.random.default_rng(seed)
    B, S, V = 2, 8, 64
    tokens = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    zero_logits = jnp.zeros((B, S, V))
    loss = float(next_token_loss(zero_logits, tokens, z_loss=0.0))
    np.testing.assert_allclose(loss, np.log(V), rtol=1e-5)
    logits = jnp.asarray(rng.standard_normal((B, S, V)), jnp.float32)
    assert float(next_token_loss(logits, tokens, z_loss=0.0)) > 0


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_perfect_prediction_goes_small(seed):
    rng = np.random.default_rng(seed)
    B, S, V = 2, 8, 64
    tokens = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    logits = 100.0 * jax.nn.one_hot(tokens[:, 1:], V)
    logits = jnp.pad(logits, ((0, 0), (0, 1), (0, 0)))  # align: pos t -> t+1
    logits = jnp.roll(logits, 1, axis=1) * 0 + jnp.concatenate(
        [100.0 * jax.nn.one_hot(tokens[:, 1:], V),
         jnp.zeros((B, 1, V))], axis=1)
    assert float(next_token_loss(logits, tokens, z_loss=0.0)) < 1e-3


# --- paper model functions ----------------------------------------------------


@SETTINGS
@given(bws=st.lists(st.floats(1e3, 1e12), min_size=1, max_size=21))
def test_effective_bandwidth_is_mean(bws):
    d = {2 ** i: bw for i, bw in enumerate(bws)}
    assert models.effective_bandwidth(d) == sum(bws) / len(bws)
    assert min(bws) - 1e-6 <= models.effective_bandwidth(d) <= max(bws) + 1e-6


@SETTINGS
@given(L=st.integers(1, 1 << 20))
def test_beff_models_monotone_bounded(L):
    """Bandwidth grows with message size and never exceeds the link peak."""
    csn = models.beff_csn_model_520n(L)
    assert csn <= 2 * 64 * 156.25e6 + 1e-6  # 2 channels x 32 B x f
    ici = models.beff_ici_model(L)
    assert ici <= 2 * 50e9
    if L >= 2:
        assert models.beff_ici_model(L) >= models.beff_ici_model(L // 2) - 1e-6


def test_beff_csn_model_matches_paper_eq4():
    """Paper Eq. 4 at L=64B: b = 2*64 / (6.4ns + 520ns)."""
    got = models.beff_csn_model_520n(64)
    want = 2 * 64 / (6.4e-9 + 520e-9)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_hpl_flops_rule():
    assert models.hpl_flops(1000) == 2e9 / 3


@SETTINGS
@given(n=st.integers(2, 64))
def test_wire_factor_bounds(n):
    for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
        f = _wire_factor(op, n)
        assert 0 < f < 2
    assert _wire_factor("all-reduce", n) == 2 * (n - 1) / n


# --- data pipeline: shard independence of worker count -----------------------


@SETTINGS
@given(step=st.integers(0, 1000), seed=st.integers(0, 100))
def test_data_pure_function_of_step_shard(step, seed):
    cfg = DataConfig(vocab_size=128, global_batch=4, seq_len=16, seed=seed)
    a = SyntheticLMDataset(cfg).batch(step, 1, 2)["tokens"]
    b = SyntheticLMDataset(cfg).batch(step, 1, 2)["tokens"]
    np.testing.assert_array_equal(a, b)


# --- all_to_all_tiles: schedule equivalence + pipelined == monolithic --------
#
# Runs over a ring of however many devices this process sees (1 locally; the
# CI tier-1 job sets the 8-device XLA flag, so the schedules exchange for
# real there). The 8-device-only MoE layer equivalence lives in
# tests/dist/test_moe.py; these randomized-shape/dtype properties cover
# every all_to_all_tiles callsite shape the engine can see.

_NDEV = len(jax.devices())
_A2A_MESH = make_mesh((_NDEV,), ("x",))
_A2A_DTYPES = ["float32", "int32", "bfloat16", "float16"]


def _a2a_run(schedule, x, split_axis, concat_axis):
    eng = CollectiveEngine.for_mesh(_A2A_MESH, schedule=schedule)

    def body(v):
        return eng.all_to_all_tiles(v[0], "x", split_axis=split_axis,
                                    concat_axis=concat_axis)[None]

    spec = P("x", *([None] * (x.ndim - 1)))
    fn = jax.jit(shard_map(body, mesh=_A2A_MESH, in_specs=(spec,),
                           out_specs=spec, check_vma=False))
    return np.asarray(fn(x).astype(jnp.float32))


def _a2a_reference(g, split_axis, concat_axis):
    """Rank j receives split j of every source rank, ordered by source."""
    n = g.shape[0]
    return np.stack([
        np.concatenate([np.split(g[i], n, axis=split_axis)[j]
                        for i in range(n)], axis=concat_axis)
        for j in range(n)])


@A2A_SETTINGS
@given(tiles=st.sampled_from([1, 2]), rows=st.sampled_from([0, 1, 3]),
       d=st.sampled_from([1, 4]), dtype=st.sampled_from(_A2A_DTYPES),
       concat=st.sampled_from([0, 1]), seed=st.integers(0, 2**31 - 1))
def test_a2a_schedule_equivalence_randomized(tiles, rows, d, dtype, concat,
                                             seed):
    """Every registered all_to_all_tiles schedule moves identical bytes for
    random shapes (including 0-row payloads) and dtypes — small-integer
    values, so every dtype carries them exactly."""
    rng = np.random.default_rng(seed)
    g = rng.integers(-8, 8, (_NDEV, _NDEV * tiles, rows, d))
    x = jnp.asarray(g).astype(dtype)
    want = _a2a_reference(np.asarray(g, np.float32), 0, concat)
    for schedule in sorted(schedules_for("all_to_all_tiles")):
        got = _a2a_run(schedule, x, split_axis=0, concat_axis=concat)
        np.testing.assert_array_equal(got.reshape(want.shape), want,
                                      err_msg=f"{schedule}/{dtype}")


@A2A_SETTINGS
@given(nchunks=st.sampled_from([1, 2, 3, 7, 64, "auto"]),
       rows=st.sampled_from([0, 1, 5, 8]),
       dtype=st.sampled_from(_A2A_DTYPES),
       schedule=st.sampled_from(sorted(schedules_for("all_to_all_tiles"))),
       seed=st.integers(0, 2**31 - 1))
def test_pipelined_a2a_matches_monolithic_randomized(nchunks, rows, dtype,
                                                     schedule, seed):
    """engine.pipelined('all_to_all_tiles', ...) is bit-identical to the
    monolithic exchange for every chunk count (non-divisible strip counts,
    nchunks > rows clamped to one row per strip, 0-row strip axes) — chunk
    boundaries only partition the payload along an axis the exchange leaves
    alone."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.integers(-8, 8, (_NDEV, _NDEV * 2, rows, 3))
                    ).astype(dtype)
    eng = CollectiveEngine.for_mesh(_A2A_MESH, schedule=schedule)
    spec = P("x", None, None, None)

    def run(pipelined):
        def body(v):
            loc = v[0]
            if pipelined:
                out = eng.pipelined("all_to_all_tiles", loc, "x",
                                    nchunks=nchunks, split_axis=1,
                                    tile_split_axis=0, tile_concat_axis=0)
            else:
                out = eng.all_to_all_tiles(loc, "x", split_axis=0,
                                           concat_axis=0)
            return out[None]
        fn = jax.jit(shard_map(body, mesh=_A2A_MESH, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        return np.asarray(fn(g).astype(jnp.float32))

    np.testing.assert_array_equal(run(True), run(False),
                                  err_msg=f"{schedule}/{dtype}/{nchunks}")


# (pipelined-a2a argument validation lives in
# tests/test_engine.py::test_pipelined_rejects_unsupported_ops)


# --- link-health masks: no resolution crosses a down link --------------------
#
# For every (op, topology kind, break position): with one link marked
# hard-down, the cost model's winner must have a *known* route whose link
# set excludes the cut and a finite cost — i.e. schedule resolution
# provably never routes through a dead wire, whichever hop died.


def _break_cases():
    cases = []
    for n in (4, 8):
        cases.append(("ring", (AxisTopology("x", n, "ring"),)))
        cases.append(("torus", (AxisTopology("rows", n, "ring"),
                                AxisTopology("cols", n, "ring"))))
    return cases


_BREAK_CASES = _break_cases()


@SETTINGS
@given(case=st.sampled_from(range(len(_BREAK_CASES))),
       op=st.sampled_from(["bcast", "allreduce"]),
       hop_seed=st.integers(0, 63),
       nbytes=st.sampled_from([256, 16384, 1 << 20]))
def test_no_resolution_crosses_a_down_link(case, op, hop_seed, nbytes):
    import math
    kind, axes = _BREAK_CASES[case]
    ax = axes[hop_seed % len(axes)]          # which axis breaks
    hop = (hop_seed // len(axes)) % ax.size  # where on it
    health = frozenset({(ax.name, hop)})
    model = CostModel(hw=TPU_V5E, table=None, health=health)
    winner = model.choose(op, nbytes, axes)
    assert winner in schedules_for(op), (kind, winner)
    route = route_links(op, winner, axes, health=health)
    assert route is not None, \
        f"{kind}: winner {winner!r} has no priceable route"
    assert not (route & health), \
        f"{kind}: {op}/{winner} routes through down link {(ax.name, hop)}"
    assert math.isfinite(model.cost(op, winner, nbytes, axes)), \
        f"{kind}: winner {winner!r} priced infinite yet chosen"


@SETTINGS
@given(hop=st.integers(0, 7), nbytes=st.sampled_from([256, 16384]))
def test_down_link_never_prices_crossing_schedule_finite(hop, nbytes):
    """The converse: any schedule whose route intersects the cut (or is
    unknown under a health mask) must be priced infinite."""
    import math
    axes = (AxisTopology("x", 8, "ring"),)
    health = frozenset({("x", hop)})
    model = CostModel(hw=TPU_V5E, table=None, health=health)
    for op in ("bcast", "allreduce"):
        for name in schedules_for(op):
            route = route_links(op, name, axes, health=health)
            cost = model.cost(op, name, nbytes, axes)
            if route is None or route & health:
                assert not math.isfinite(cost), (op, name, hop)


@SETTINGS
@given(hop=st.integers(0, 1), op=st.sampled_from(["bcast", "allreduce"]),
       nbytes=st.sampled_from([256, 16384]))
def test_size2_ring_down_wire_excludes_both_hop_ids(hop, op, nbytes):
    """A size-2 ring has ONE physical wire; hops 0 and 1 are two names for
    it. Downing either hop id must price every ICI schedule that touches
    the axis infinite (the rooted chain cannot route around the only wire),
    so resolution falls through to the link-free ``staged``."""
    import math
    axes = (AxisTopology("x", 2, "ring"),)
    assert axes[0].links() == (("x", 0),)       # dedupe: one link reported
    assert axes[0].canonical_hop(hop) == 0
    health = frozenset({("x", hop)})
    model = CostModel(hw=TPU_V5E, table=None, health=health)
    for name in schedules_for(op):
        route = route_links(op, name, axes, health=health)
        if route:  # any route touching the axis touches the one wire
            assert route == frozenset({("x", 0)}), (name, route)
            assert not math.isfinite(model.cost(op, name, nbytes, axes)), \
                f"{op}/{name} priced finite across the downed size-2 wire"
    assert model.choose(op, nbytes, axes) == "staged"


# --- HLO shape parser --------------------------------------------------------


@SETTINGS
@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
       dt=st.sampled_from(["f32", "bf16", "s8", "u32", "f64"]))
def test_shape_bytes_product(dims, dt):
    sizes = {"f32": 4, "bf16": 2, "s8": 1, "u32": 4, "f64": 8}
    text = f"{dt}[{','.join(map(str, dims))}]"
    want = sizes[dt] * int(np.prod(dims)) if dims else sizes[dt]
    assert shape_bytes(text) == want
