"""Overlap-subsystem equivalence suite on the simulated 8-device mesh.

Acceptance properties (ISSUE 2 + ISSUE 4):
* depth-d lookahead HPL (d in {1, 2, 3}) is *bit-identical* to eager HPL
  under every registered bcast schedule, including the nb == pg edge (the
  overlap restructuring must not change a single ulp);
* chunked (pipelined) grid_transpose is bit-identical to the monolithic
  exchange under every registered schedule, including nchunks > strips;
* ``CollectiveEngine.allreduce_tree`` matches leaf-wise ``lax.psum`` for
  every allreduce schedule and odd bucket boundaries (inputs are small
  integers in f32/int32 so every summation order is exact; the ``int8_ef``
  schedule gets inputs its block quantizer represents exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.engine import CollectiveEngine, schedules_for
from repro.compat import make_mesh, shard_map

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

BCAST_SCHEDULES = sorted(schedules_for("bcast"))
ALLREDUCE_EXACT = sorted(s for s in schedules_for("allreduce")
                         if s != "int8_ef")


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


@pytest.fixture(scope="module")
def torus():
    return make_mesh((2, 2), ("rows", "cols"))


# ---------------------------------------------------------------------------
# lookahead HPL == eager HPL, bitwise
# ---------------------------------------------------------------------------


def _int_system(n, seed=3):
    rng = np.random.default_rng(seed)
    a = rng.integers(-8, 8, (n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n  # diagonally dominant (HPL-AI rule)
    return a


@pytest.mark.parametrize("schedule", BCAST_SCHEDULES)
def test_hpl_lookahead_bit_identical(torus, schedule):
    """Depth-d lookahead (d in {1, 2, 3}) == eager, bitwise, per schedule."""
    from repro.core.hpl import make_factorize
    from repro.core.ptrans import distribute_cyclic
    n, b, pg = 128, 32, 2
    a = _int_system(n)
    spec = NamedSharding(torus, P(("rows", "cols"), None, None))
    a_sh = jax.device_put(distribute_cyclic(a, pg, b), spec)
    eager = np.asarray(
        make_factorize(torus, pg=pg, nb=n // b, b=b, schedule=schedule)(a_sh))
    for depth in (1, 2, 3):
        look = make_factorize(torus, pg=pg, nb=n // b, b=b,
                              schedule=schedule, lookahead=depth)
        np.testing.assert_array_equal(np.asarray(look(a_sh)), eager,
                                      strict=True,
                                      err_msg=f"{schedule}/d={depth}")


@pytest.mark.parametrize("depth", [True, 2, 3])
def test_hpl_lookahead_single_block_column(torus, depth):
    """nb == pg edge: the lookahead carry wraps with only one local block
    (depth > nb clamps to nb panel sets in flight)."""
    from repro.core.hpl import make_factorize
    from repro.core.ptrans import distribute_cyclic
    n, b, pg = 64, 32, 2
    a = _int_system(n, seed=11)
    spec = NamedSharding(torus, P(("rows", "cols"), None, None))
    a_sh = jax.device_put(distribute_cyclic(a, pg, b), spec)
    eager = make_factorize(torus, pg=pg, nb=n // b, b=b, schedule="chain")
    look = make_factorize(torus, pg=pg, nb=n // b, b=b, schedule="chain",
                          lookahead=depth)
    np.testing.assert_array_equal(np.asarray(look(a_sh)),
                                  np.asarray(eager(a_sh)))


def test_run_hpl_lookahead_converges(torus):
    from repro.comm.types import CommunicationType as CT
    from repro.core.hpl import run_hpl
    res = run_hpl(torus, CT.ICI_DIRECT, n=128, b=32, schedule="ring2d",
                  reps=1, lookahead=True)
    assert res.error < 1.0
    assert res.details["lookahead"] is True
    assert res.details["lookahead_depth"] == 1
    # both bcast payloads carry resolved provenance, never the literal auto
    assert res.details["schedule_block"] == "ring2d"
    assert res.details["schedule_panel"] == "ring2d"


def test_run_hpl_auto_depth_and_schedule(torus):
    """schedule="auto" + lookahead="auto": the cost model resolves both the
    per-callsite bcast schedules and the pipeline depth, and the run still
    converges."""
    from repro.comm.engine import schedules_for
    from repro.comm.types import CommunicationType as CT
    from repro.core.hpl import run_hpl
    res = run_hpl(torus, CT.ICI_DIRECT, n=128, b=32, schedule="auto",
                  reps=1, lookahead="auto")
    assert res.error < 1.0
    assert 1 <= res.details["lookahead_depth"] <= 3
    for key in ("schedule", "schedule_block", "schedule_panel"):
        assert res.details[key] in schedules_for("bcast"), key


# ---------------------------------------------------------------------------
# chunked (pipelined) grid_transpose == monolithic, bitwise
# ---------------------------------------------------------------------------


GRID_SCHEDULES = sorted(schedules_for("grid_transpose"))


@pytest.mark.parametrize("schedule", GRID_SCHEDULES)
def test_pipelined_grid_transpose_bit_identical(torus, schedule):
    """Strip-chunked exchange == monolithic for every chunk count,
    including nchunks > strips (clamped to one row per strip)."""
    from jax import lax
    x = np.random.default_rng(9).integers(-8, 8, (4, 16, 16)) \
        .astype(np.float32)
    spec = P(("rows", "cols"), None, None)
    eng = CollectiveEngine.for_mesh(torus, schedule=schedule)

    def run(nchunks):
        def body(v):
            if nchunks is None:
                return eng.grid_transpose(v[0], ("rows", "cols"), 2)[None]
            return eng.pipelined("grid_transpose", v[0], ("rows", "cols"),
                                 pg=2, nchunks=nchunks)[None]
        fn = jax.jit(shard_map(body, mesh=torus, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    mono = run(None)
    for nchunks in (1, 2, 4, 7, 64):  # 64 > the 16 strips available
        np.testing.assert_array_equal(run(nchunks), mono,
                                      err_msg=f"{schedule}/S={nchunks}")

    # with a consume hook the pipeline reproduces the strip-wise PTRANS
    bm = np.random.default_rng(10).integers(-8, 8, (4, 16, 16)) \
        .astype(np.float32)

    def body_pipe(va, vb):
        b_loc = vb[0]

        def consume(strip, start):
            return strip.T + lax.slice_in_dim(b_loc, start,
                                              start + strip.shape[0], axis=1)
        out = eng.pipelined("grid_transpose", va[0], ("rows", "cols"), pg=2,
                            nchunks=4, concat_axis=1, consume=consume)
        return out[None]

    fn = jax.jit(shard_map(body_pipe, mesh=torus, in_specs=(spec, spec),
                           out_specs=spec, check_vma=False))
    got = np.asarray(fn(jnp.asarray(x), jnp.asarray(bm)))
    want = np.stack([bm[i] + mono[i].T for i in range(4)])
    np.testing.assert_array_equal(got, want)


def test_run_ptrans_pipelined_matches_monolithic(torus):
    """run_ptrans with any chunk count (incl. auto) produces the exact
    transpose and records the resolved (schedule, nchunks)."""
    from repro.comm.engine import schedules_for as _sf
    from repro.core.ptrans import run_ptrans
    for nchunks in (1, 2, "auto"):
        res = run_ptrans(torus, n=128, b=32, reps=1, nchunks=nchunks)
        assert res.error == 0.0, nchunks
        assert res.details["schedule"] in _sf("grid_transpose")
        assert res.details["nchunks"] >= 1
        assert res.details["nchunks_requested"] == nchunks


# ---------------------------------------------------------------------------
# allreduce_tree == leaf-wise psum
# ---------------------------------------------------------------------------


def _grad_tree(seed=0):
    """Odd-shaped pytree: mixed dtypes, a 0-byte leaf, a scalar-ish leaf,
    and one giant leaf dwarfing the bucket size."""
    rng = np.random.default_rng(seed)
    return {
        "w": rng.integers(-8, 8, (NDEV, 7, 33)).astype(np.float32),
        "giant": rng.integers(-8, 8, (NDEV, 4096)).astype(np.float32),
        "bias": rng.integers(-8, 8, (NDEV, 5)).astype(np.float32),
        "ints": rng.integers(-8, 8, (NDEV, 11)).astype(np.int32),
        "empty": np.zeros((NDEV, 0), np.float32),
        "one": rng.integers(-8, 8, (NDEV, 1)).astype(np.float32),
    }


def _reduce_tree(mesh, eng, tree, bucket_bytes):
    def body(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = eng.allreduce_tree(loc, "x", bucket_bytes=bucket_bytes)
        return jax.tree.map(lambda v: v[None], out)

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x"),),
                           out_specs=P("x"), check_vma=False))
    return fn(jax.tree.map(jnp.asarray, tree))


@pytest.mark.parametrize("schedule", ALLREDUCE_EXACT)
@pytest.mark.parametrize("bucket_bytes", [1, 64, 1 << 30])
def test_allreduce_tree_matches_leafwise_psum(ring, schedule, bucket_bytes):
    tree = _grad_tree()
    eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
    out = _reduce_tree(ring, eng, tree, bucket_bytes)
    for key, x in tree.items():
        want = np.broadcast_to(x.sum(0, dtype=x.dtype), out[key].shape)
        np.testing.assert_array_equal(np.asarray(out[key]), want,
                                      err_msg=f"{schedule}/{bucket_bytes}/"
                                              f"{key}")


def test_allreduce_tree_int8_ef_exact_on_representable_inputs(ring):
    # int8_ef quantizes per ring chunk on every hop (the wire payload is
    # int8 + per-block scales hop by hop, never a whole fp32 bucket), so
    # "representable" means every hop's chunk must round-trip the block
    # quantizer exactly. Identical integer rows with a 127-max in every
    # 256-elem block of every 512-elem ring chunk give that: the partial
    # sum after k hops is k*v with block max k*127, so the scale is exactly
    # k and round((k*v)/k) == v on every requantization.
    rng = np.random.default_rng(1)
    row = rng.integers(-100, 100, (NDEV * 512,)).astype(np.float32)
    row[::256] = 127
    x = np.broadcast_to(row, (NDEV, NDEV * 512)).copy()
    tree = {"g": x}
    eng = CollectiveEngine.for_mesh(ring, schedule="int8_ef")
    out = _reduce_tree(ring, eng, tree, 1 << 30)
    np.testing.assert_array_equal(
        np.asarray(out["g"]), np.broadcast_to(x.sum(0), out["g"].shape))


def test_allreduce_int8_ef_close_on_general_inputs(ring):
    # per-hop requantization of partial sums is lossy in general, but the
    # residual chunk carried alongside the payload means each hop leaks only
    # the residual's own requantization — O(1/127^2) of the chunk magnitude
    # per hop, vs O(1/127) for the residual-free wire (ROADMAP in-ring
    # error-feedback item). Assert the tightened bound.
    rng = np.random.default_rng(6)
    x = rng.integers(-100, 100, (NDEV, 4096)).astype(np.float32)
    eng = CollectiveEngine.for_mesh(ring, schedule="int8_ef")
    out = _reduce_tree(ring, eng, {"g": x}, 1 << 30)
    want = np.broadcast_to(x.sum(0), out["g"].shape)
    err = np.max(np.abs(np.asarray(out["g"]) - want))
    assert err <= 2.0 / 127.0 ** 2 * NDEV * np.max(np.abs(x)), err


def test_bucketed_psum_tree_legacy_wrapper(ring):
    """The deprecated shim must warn exactly once at trace time and reduce
    to the same values as the engine op it forwards to."""
    import pytest

    from repro.comm.overlap import bucketed_psum_tree
    tree = _grad_tree(seed=2)
    eng = CollectiveEngine.for_mesh(ring, schedule="native")

    def run(reduce_fn):
        def body(t):
            loc = jax.tree.map(lambda v: v[0], t)
            out = reduce_fn(loc)
            return jax.tree.map(lambda v: v[None], out)

        fn = jax.jit(shard_map(body, mesh=ring, in_specs=(P("x"),),
                               out_specs=P("x"), check_vma=False))
        return fn(jax.tree.map(jnp.asarray, tree))

    want = run(lambda loc: eng.allreduce_tree(loc, "x", bucket_bytes=256))
    with pytest.warns(DeprecationWarning, match="allreduce_tree") as rec:
        out = run(lambda loc: bucketed_psum_tree(loc, "x", bucket_bytes=256))
    assert sum(issubclass(w.category, DeprecationWarning)
               and "bucketed_psum_tree" in str(w.message) for w in rec) == 1
    for key, x in tree.items():
        np.testing.assert_array_equal(
            np.asarray(out[key]),
            np.broadcast_to(x.sum(0, dtype=x.dtype), out[key].shape))
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(want[key]))


def test_compressed_psum_engine_routing(ring):
    """Error-feedback compression composed with the rs_ag ring reduces to
    the same values as its lax.psum transport on exactly-representable
    inputs, and carries identical error state."""
    from repro.comm.compression import compressed_psum
    rng = np.random.default_rng(4)
    x = rng.integers(-100, 100, (NDEV, 512)).astype(np.float32)
    x[:, 0] = 127
    x[:, 256] = 127
    eng = CollectiveEngine.for_mesh(ring, schedule="rs_ag")

    def body(v, use_engine):
        err = jnp.zeros_like(v[0])
        red, ne = compressed_psum(v[0], "x", err,
                                  engine=eng if use_engine else None)
        return red[None], ne[None]

    spec = P("x", None)
    outs = {}
    for use_engine in (False, True):
        fn = jax.jit(shard_map(lambda v, u=use_engine: body(v, u), mesh=ring,
                               in_specs=(spec,), out_specs=(spec, spec),
                               check_vma=False))
        outs[use_engine] = [np.asarray(o) for o in fn(jnp.asarray(x))]
    np.testing.assert_array_equal(outs[True][0], outs[False][0])
    np.testing.assert_array_equal(outs[True][1], outs[False][1])
    np.testing.assert_array_equal(outs[True][0],
                                  np.broadcast_to(x.sum(0), (NDEV, 512)))


def test_dp_train_step_explicit_compressed_engine(ring):
    """The int8_ef error-feedback DP step runs end-to-end through the engine
    transport and produces a finite loss."""
    from repro.configs import RunConfig, get_config, reduced
    from repro.models.model import build_model
    from repro.train.step import init_train_state, make_dp_train_step_explicit
    cfg = reduced(get_config("llama3.2-3b"), layers=1, d_model=32)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (NDEV, 16)), jnp.int32)}
    run = RunConfig(learning_rate=1e-3, warmup_steps=1,
                    grad_compression="int8_ef")
    state = init_train_state(model, jax.random.key(0), compression_on=True)
    step = make_dp_train_step_explicit(model, run, ring,
                                       schedule_kind="rs_ag")
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    err_norm = sum(float(jnp.sum(jnp.abs(e)))
                   for e in jax.tree.leaves(new_state.error))
    assert np.isfinite(err_norm)
