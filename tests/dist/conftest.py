"""Distributed suite — runs only in the 8-device subprocess launched by
tests/test_dist_wrapper.py (REPRO_DIST_TESTS=1 + XLA_FLAGS device-count 8).
Collected-but-skipped in the main single-device pytest process."""
from __future__ import annotations

import os

import pytest


DIST_TEST_TIMEOUT_S = 300


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_DIST_TESTS") == "1":
        # per-test wall-clock cap (pytest-timeout, when installed): a
        # route-exclusion regression that deadlocks a collective must fail
        # the suite, not hang it. Guarded so a container without the
        # plugin still runs the tests.
        if config.pluginmanager.hasplugin("timeout"):
            timeout = pytest.mark.timeout(DIST_TEST_TIMEOUT_S)
            for item in items:
                item.add_marker(timeout)
        return
    skip = pytest.mark.skip(
        reason="distributed suite runs via tests/test_dist_wrapper.py "
               "(needs REPRO_DIST_TESTS=1 and the 8-device XLA flag)")
    for item in items:
        item.add_marker(skip)
