"""Autotuning equivalence suite on the simulated 8-device mesh.

Acceptance properties (ISSUE 3):
* ``schedule="auto"`` is *bit-equivalent* to every fixed exact schedule for
  bcast, allreduce, and grid_transpose — the cost model only ever changes
  which wire route runs, never the numbers (inputs are small integers in
  float32, so every summation order is exact);
* the measured mode microbenchmarks the live mesh and its tuning table
  round-trips through save -> load -> identical picks;
* the explicit DP train step runs end-to-end with ``schedule_kind="auto"``
  and the derived bucket size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.autotune import CostModel, TuningTable, autotune_mesh
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.compat import make_mesh, shard_map

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

ALLREDUCE_EXACT = sorted(s for s in schedules_for("allreduce")
                         if s != "int8_ef")


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


@pytest.fixture(scope="module")
def torus():
    return make_mesh((2, 2), ("rows", "cols"))


def _ints(shape, seed=0):
    return np.random.default_rng(seed).integers(-8, 8, shape).astype(np.float32)


def _auto_engine(mesh):
    # analytic model: the committed tuning table must not decide which
    # fixed schedule auto agrees with — any exact pick must be bit-equal
    return CollectiveEngine.for_mesh(mesh, schedule="auto",
                                     cost_model=CostModel(table=None))


# ---------------------------------------------------------------------------
# auto == every fixed exact schedule, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("elems", [128, 1 << 16])  # latency + bandwidth regimes
def test_auto_allreduce_bit_equal_to_fixed(ring, elems):
    x = _ints((NDEV, elems), seed=1)
    spec = P("x", None)

    def run(eng):
        fn = jax.jit(shard_map(lambda v: eng.allreduce(v[0], "x")[None],
                               mesh=ring, in_specs=(spec,), out_specs=spec,
                               check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    auto = run(_auto_engine(ring))
    np.testing.assert_array_equal(
        auto, np.broadcast_to(x.sum(0), auto.shape))
    for schedule in ALLREDUCE_EXACT:
        fixed = run(CollectiveEngine.for_mesh(ring, schedule=schedule))
        np.testing.assert_array_equal(auto, fixed, err_msg=schedule)


@pytest.mark.parametrize("elems", [96, 1 << 16])
def test_auto_bcast_bit_equal_to_fixed(ring, elems):
    x = _ints((NDEV, elems), seed=2)
    spec = P("x", None)

    def run(eng):
        fn = jax.jit(shard_map(lambda v: eng.bcast(v[0], "x", 3)[None],
                               mesh=ring, in_specs=(spec,), out_specs=spec,
                               check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    auto = run(_auto_engine(ring))
    np.testing.assert_array_equal(auto, np.broadcast_to(x[3], auto.shape))
    for schedule in sorted(schedules_for("bcast")):
        fixed = run(CollectiveEngine.for_mesh(ring, schedule=schedule))
        np.testing.assert_array_equal(auto, fixed, err_msg=schedule)


def test_int8_ef_in_ring_error_feedback_bound(ring):
    """Lossy-bound pin for the residual-carrying int8_ef wire: with the
    per-hop requantization residual travelling alongside the payload, the
    end-to-end error is O(hops/127^2) of the input magnitude — ~1/127 of
    the residual-free wire's O(hops/127) bound — and the cost model prices
    the doubled int8 payload accordingly (INT8_WIRE_RATIO ~ 0.5)."""
    from repro.comm.autotune import INT8_WIRE_RATIO
    assert 0.5 <= INT8_WIRE_RATIO < 0.52  # 2 x (1/4 + 1/256) of f32 bytes
    rng = np.random.default_rng(12)
    x = rng.uniform(-50.0, 50.0, (NDEV, 2048)).astype(np.float32)
    eng = CollectiveEngine.for_mesh(ring, schedule="int8_ef")
    spec = P("x", None)
    fn = jax.jit(shard_map(lambda v: eng.allreduce(v[0], "x")[None],
                           mesh=ring, in_specs=(spec,), out_specs=spec,
                           check_vma=False))
    out = np.asarray(fn(jnp.asarray(x)))
    err = np.max(np.abs(out - x.sum(0, dtype=np.float64)))
    assert err <= 2.0 / 127.0 ** 2 * NDEV * np.max(np.abs(x)), err


def test_auto_pipelined_grid_transpose_bit_equal(torus):
    """engine.pipelined with nchunks="auto" (cost-model chunk count, per-
    callsite tag) == the monolithic exchange, bitwise."""
    x = _ints((4, 16, 16), seed=7)
    spec = P(("rows", "cols"), None, None)
    eng = _auto_engine(torus)

    def run(pipelined):
        def body(v):
            if pipelined:
                return eng.pipelined("grid_transpose", v[0],
                                     ("rows", "cols"), pg=2, nchunks="auto",
                                     callsite="ptrans.exchange")[None]
            return eng.grid_transpose(v[0], ("rows", "cols"), 2)[None]
        fn = jax.jit(shard_map(body, mesh=torus, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    np.testing.assert_array_equal(run(True), run(False))


def test_auto_grid_transpose_bit_equal_to_fixed(torus):
    x = _ints((4, 16, 16), seed=3)
    spec = P(("rows", "cols"), None, None)

    def run(eng):
        fn = jax.jit(shard_map(
            lambda v: eng.grid_transpose(v[0], ("rows", "cols"), 2)[None],
            mesh=torus, in_specs=(spec,), out_specs=spec, check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    auto = run(_auto_engine(torus))
    want = x.reshape(2, 2, 16, 16).transpose(1, 0, 2, 3).reshape(4, 16, 16)
    np.testing.assert_array_equal(auto, want)
    for schedule in sorted(schedules_for("grid_transpose")):
        fixed = run(CollectiveEngine.for_mesh(torus, schedule=schedule))
        np.testing.assert_array_equal(auto, fixed, err_msg=schedule)


def test_auto_allreduce_tree_with_derived_bucket(ring):
    """bucket_bytes=None: the engine derives the size from the topology and
    the reduction still matches leaf-wise sums exactly."""
    rng = np.random.default_rng(5)
    tree = {"w": rng.integers(-8, 8, (NDEV, 7, 33)).astype(np.float32),
            "b": rng.integers(-8, 8, (NDEV, 5)).astype(np.float32)}
    eng = _auto_engine(ring)
    assert eng.bucket_bytes_for("x") == 4 << 20  # v5e ring-of-8 derivation

    def body(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = eng.allreduce_tree(loc, "x")  # derived bucket size
        return jax.tree.map(lambda v: v[None], out)

    fn = jax.jit(shard_map(body, mesh=ring, in_specs=(P("x"),),
                           out_specs=P("x"), check_vma=False))
    out = fn(jax.tree.map(jnp.asarray, tree))
    for k, x in tree.items():
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.broadcast_to(x.sum(0), out[k].shape),
            err_msg=k)


# ---------------------------------------------------------------------------
# measured mode on the live mesh
# ---------------------------------------------------------------------------


def test_measured_callsite_entry_round_trip(tmp_path):
    """The paired-bcast callsite pattern measures under its tagged key and
    a model with that table resolves the matching callsite through it,
    while untagged lookups fall back to the analytic ranking."""
    from repro.comm.topology import AxisTopology
    table, record = autotune_mesh(ops=("bcast@hpl.panel",),
                                  sizes=(1024,), reps=1, verbose=False)
    sig = "torus_row[2]"
    assert sig in table.entries.get("bcast@hpl.panel", {})
    rows = table.entries["bcast@hpl.panel"][sig]
    for _, name in rows:
        assert name in schedules_for("bcast")
    assert record
    # the HPL pattern is row/column-symmetric: the winner must also land
    # under the column-axis signature so the l_panel bcast matches it
    assert table.entries["bcast@hpl.panel"].get("torus_col[2]") == rows

    loaded = TuningTable.load(table.save(tmp_path / "tuning.json"))
    axes = (AxisTopology("rows", 2, "torus_row"),)
    m = CostModel(table=loaded)
    assert m.choose("bcast", 1024, axes, callsite="hpl.panel") == rows[0][1]
    col_axes = (AxisTopology("cols", 2, "torus_col"),)
    assert m.choose("bcast", 1024, col_axes, callsite="hpl.panel") \
        == rows[0][1]
    # no callsite -> no tagged entry consulted -> analytic pick
    assert m.choose("bcast", 1024, axes) \
        == CostModel(table=None).choose("bcast", 1024, axes)


def test_measured_moe_callsite_entry_round_trip(tmp_path):
    """The paired MoE dispatch+combine pattern measures on the ring under
    its tagged key — and, because the pattern is direction-symmetric, the
    winner also lands under the @moe.combine alias. A model with that table
    resolves both callsites through it; untagged lookups fall back to the
    analytic ranking."""
    from repro.comm.topology import AxisTopology
    table, record = autotune_mesh(ops=("all_to_all_tiles@moe.dispatch",),
                                  sizes=(1024,), reps=1, verbose=False)
    sig = f"ring[{NDEV}]"
    assert sig in table.entries.get("all_to_all_tiles@moe.dispatch", {})
    rows = table.entries["all_to_all_tiles@moe.dispatch"][sig]
    for _, name in rows:
        assert name in schedules_for("all_to_all_tiles")
    assert record
    # the combine alias carries the same measured bands
    assert table.entries["all_to_all_tiles@moe.combine"][sig] == rows

    loaded = TuningTable.load(table.save(tmp_path / "tuning.json"))
    axes = (AxisTopology("x", NDEV, "ring"),)
    m = CostModel(table=loaded)
    for cs in ("moe.dispatch", "moe.combine"):
        assert m.choose("all_to_all_tiles", 1024, axes,
                        callsite=cs) == rows[0][1]
    # no callsite -> no tagged entry consulted -> analytic pick
    assert m.choose("all_to_all_tiles", 1024, axes) \
        == CostModel(table=None).choose("all_to_all_tiles", 1024, axes)


def test_measured_decode_callsite_entry_round_trip(tmp_path):
    """The serving burst pattern measures under @decode.qkv on its own
    decode-sized ladder (not the training ladder), and the winner lands
    under the @decode.out / @decode.moe aliases; a model with that table
    resolves all three callsites through it."""
    from repro.comm.autotune import DECODE_SIZES_QUICK
    from repro.comm.topology import AxisTopology
    table, record = autotune_mesh(ops=("all_to_all_tiles@decode.qkv",),
                                  quick=True, verbose=False)
    sig = f"ring[{NDEV}]"
    assert sig in table.entries.get("all_to_all_tiles@decode.qkv", {})
    rows = table.entries["all_to_all_tiles@decode.qkv"][sig]
    for _, name in rows:
        assert name in schedules_for("all_to_all_tiles")
    # measured at the decode ladder sizes, not the default training sizes
    assert {int(k.rsplit("/", 1)[1]) for k in record} \
        == set(DECODE_SIZES_QUICK)
    for alias in ("all_to_all_tiles@decode.out",
                  "all_to_all_tiles@decode.moe"):
        assert table.entries[alias][sig] == rows

    loaded = TuningTable.load(table.save(tmp_path / "tuning.json"))
    axes = (AxisTopology("x", NDEV, "ring"),)
    m = CostModel(table=loaded)
    want = m.choose("all_to_all_tiles", 1024, axes, callsite="decode.qkv")
    assert want in schedules_for("all_to_all_tiles")
    for cs in ("decode.out", "decode.moe"):
        assert m.choose("all_to_all_tiles", 1024, axes, callsite=cs) == want
    # no callsite -> no tagged entry consulted -> analytic pick
    assert m.choose("all_to_all_tiles", 1024, axes) \
        == CostModel(table=None).choose("all_to_all_tiles", 1024, axes)


def test_dp_grads_callsite_threads_through_allreduce_tree(ring):
    """allreduce_tree(callsite="dp.grads") consults the tagged table entry
    for its buckets — forcing a distinguishable schedule via the tag changes
    nothing numerically (exact integer payloads) but resolves through it."""
    from repro.comm.autotune import axis_signature
    from repro.comm.topology import AxisTopology, MeshTopology
    axes = (AxisTopology("x", NDEV, "ring"),)
    t = TuningTable()
    t.set("allreduce@dp.grads", axis_signature(axes), [(None, "chain")])
    eng = CollectiveEngine(schedule="auto",
                           topology=MeshTopology.from_mesh(ring),
                           cost_model=CostModel(table=t))
    assert eng.schedule_for("allreduce", nbytes=1 << 20, axis="x",
                            callsite="dp.grads") == "chain"
    assert eng.schedule_for("allreduce", nbytes=1 << 20, axis="x") \
        == CostModel(table=None).choose("allreduce", 1 << 20, axes)

    tree = {"w": np.arange(NDEV * 6, dtype=np.float32).reshape(NDEV, 6),
            "b": np.ones((NDEV, 3), np.float32)}

    def body(tr):
        loc = jax.tree.map(lambda v: v[0], tr)
        out = eng.allreduce_tree(loc, "x", callsite="dp.grads")
        return jax.tree.map(lambda v: v[None], out)

    fn = jax.jit(shard_map(body, mesh=ring, in_specs=(P("x"),),
                           out_specs=P("x"), check_vma=False))
    out = fn(jax.tree.map(jnp.asarray, tree))
    for k, x in tree.items():
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.broadcast_to(x.sum(0), out[k].shape),
            err_msg=k)


def test_measured_autotune_round_trip(tmp_path):
    table, record = autotune_mesh(ops=("allreduce",), sizes=(1024, 1 << 16),
                                  reps=1, verbose=False)
    sig = "ring[8]"
    assert sig in table.entries.get("allreduce", {})
    for _, name in table.entries["allreduce"][sig]:
        assert name in schedules_for("allreduce")
    assert record  # raw timings captured for the bench artifact

    loaded = TuningTable.load(table.save(tmp_path / "tuning.json"))
    m_live, m_disk = CostModel(table=table), CostModel(table=loaded)
    from repro.comm.topology import AxisTopology
    axes = (AxisTopology("x", NDEV, "ring"),)
    for size in (512, 1024, 1 << 16, 1 << 24):
        assert m_live.choose("allreduce", size, axes) \
            == m_disk.choose("allreduce", size, axes)


# ---------------------------------------------------------------------------
# end-to-end: explicit DP train step under auto
# ---------------------------------------------------------------------------


def test_dp_train_step_auto_schedule(ring):
    """schedule_kind="auto" + derived bucket size runs end-to-end and lands
    on the same loss as the fixed native reduction."""
    from repro.configs import RunConfig, get_config, reduced
    from repro.models.model import build_model
    from repro.train.step import init_train_state, make_dp_train_step_explicit
    cfg = reduced(get_config("llama3.2-3b"), layers=1, d_model=32)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (NDEV, 16)), jnp.int32)}
    losses = {}
    for kind in ("auto", "native"):
        run = RunConfig(learning_rate=1e-3, warmup_steps=1)
        state = init_train_state(model, jax.random.key(0))
        step = make_dp_train_step_explicit(model, run, ring,
                                           schedule_kind=kind)
        _, metrics = step(state, batch)
        losses[kind] = float(metrics["loss"])
        assert np.isfinite(losses[kind]), kind
    np.testing.assert_allclose(losses["auto"], losses["native"], rtol=1e-5)
