"""Degraded- and dead-link resilience end-to-end on the 8-device mesh
(ISSUES 8 + 9).

Acceptance:
* degrade a link mid-run -> the RetuneController detects the drift ->
  a narrow retune re-prices and ``invalidate_resolutions`` swaps the
  resolved schedule **on the same engine object** (no rebuild) -> the
  bcast keeps returning bit-identical results through both flips;
* an ``InjectedFailure`` crash under ``step_mode="explicit_tp"`` resumes
  from the last checkpoint and lands on the uninterrupted run's loss;
* sever a ring hop -> the health mask reroutes bcast and allreduce onto
  the rooted chain, bit-identical to the healthy ring, for every break
  position;
* lose a rank mid-run -> ``train_loop_elastic`` resumes on the largest
  divisible survivor mesh from the resharded checkpoint, bitwise equal
  to a control run restored from the same snapshot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.autotune import CostModel, _seg_time, route_links, segments
from repro.comm.callsites import HPL_PANEL
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.comm.faults import FaultInjector, FaultSchedule
from repro.comm.retune import RetuneController, Watched
from repro.comm.types import TPU_V5E
from repro.compat import make_mesh, shard_map
from repro.configs import RunConfig
from repro.configs.qwen3_moe_235b_a22b import tiny
from repro.data import DataConfig
from repro.train.loop import InjectedFailure, TrainLoopConfig, train_loop

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

NBYTES = 16384
FAULT_AT, HEAL_AT, STEPS = 8, 20, 30


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


def _modeled_step(inj, axes, bcast_schedule):
    """Analytic step time on the injector's current link numbers: the
    watched panel bcast at its *current* resolution plus a fixed rs_ag
    allreduce canary that always rides the ring — the canary is what lets
    the controller see the heal after the bcast has retuned onto the
    link-avoiding staged route."""
    hw = inj.hardware_view()
    total = 0.0
    for op, schedule in (("bcast", bcast_schedule), ("allreduce", "rs_ag")):
        total += sum(_seg_time(s, hw)
                     for s in segments(op, schedule, NBYTES, axes, hw))
    return total


def test_degrade_retune_heal_bit_identical(ring):
    eng = CollectiveEngine.for_mesh(
        ring, cost_model=CostModel(hw=TPU_V5E, table=None))
    axes = eng.topology.axes
    inj = FaultInjector(hw=TPU_V5E)
    fault = FaultSchedule.degrade_window(inj, FAULT_AT, HEAL_AT, axis="x",
                                         beta_scale=64.0)
    ctrl = RetuneController(eng, [Watched(HPL_PANEL, "bcast", NBYTES, "x")],
                            drift_factor=1.75, recent=2, min_baseline=3,
                            cooldown=2, hw_probe=inj.hardware_view)

    x = np.arange(NDEV * (NBYTES // 4), dtype=np.int32).reshape(NDEV, -1)

    def run_bcast():
        # rebuilt per phase from the SAME engine: the swap must land
        # through re-tracing alone, never through a new engine
        fn = jax.jit(shard_map(
            lambda v: eng.bcast(v[0], "x", 0, callsite=HPL_PANEL)[None],
            mesh=ring, in_specs=(P("x", None),), out_specs=P("x", None),
            check_vma=False))
        return np.asarray(fn(jnp.asarray(x)))

    outputs, resolved = {}, {}
    for step in range(STEPS):
        fault.apply(step)
        now = ctrl.resolutions()[HPL_PANEL]
        ctrl.observe(step, _modeled_step(inj, axes, now))
        if step == FAULT_AT - 1:
            resolved["before"], outputs["before"] = now, run_bcast()
        elif step == HEAL_AT - 1:
            resolved["during"], outputs["during"] = now, run_bcast()
        elif step == STEPS - 1:
            resolved["after"], outputs["after"] = now, run_bcast()

    # the resolution provably flipped away and back, on one engine object
    assert ctrl.engine is eng
    assert resolved["during"] != resolved["before"]
    assert resolved["after"] == resolved["before"]
    assert {resolved["before"], resolved["during"]} <= \
        set(schedules_for("bcast"))

    flips = [e for e in ctrl.events if e.changed]
    assert len(flips) >= 2
    assert flips[0].changed == {
        HPL_PANEL: (resolved["before"], resolved["during"])}
    # detection is prompt on both edges (two-sided drift)
    assert 0 <= flips[0].step - FAULT_AT <= 6
    assert 0 <= flips[1].step - HEAL_AT <= 6

    # exact routes: every phase is bit-identical and correct
    want = np.broadcast_to(x[0], x.shape)
    for phase, out in outputs.items():
        np.testing.assert_array_equal(out, want, err_msg=phase)


def test_injected_failure_resume_explicit_tp(ring, tmp_path):
    cfg = tiny(NDEV, layers=2)
    data = DataConfig(cfg.vocab_size, NDEV, 16)

    def _run(ckdir, **kw):
        run = RunConfig(checkpoint_dir=str(ckdir), checkpoint_every=2,
                        learning_rate=1e-3, warmup_steps=1)
        return train_loop(cfg, run, data,
                          TrainLoopConfig(steps=5, step_mode="explicit_tp",
                                          **kw),
                          mesh=ring)

    with pytest.raises(InjectedFailure):
        _run(tmp_path / "ck", fail_at_step=4)
    resumed = _run(tmp_path / "ck")
    assert resumed["step"][0] == 2  # restarted from the step-2 checkpoint

    clean = _run(tmp_path / "fresh")
    assert clean["step"] == list(range(5))
    np.testing.assert_allclose(resumed["loss"][-1], clean["loss"][-1],
                               rtol=1e-6)


@pytest.mark.parametrize("hop", [0, 3, NDEV - 1])
def test_rerouted_ring_bit_identical_to_healthy(ring, hop):
    """With hop severed, both collectives re-resolve onto the rooted chain
    and return exactly the healthy ring's bytes — for breaks at the
    wraparound, mid-ring, and the default cut position."""
    eng = CollectiveEngine.for_mesh(
        ring, cost_model=CostModel(hw=TPU_V5E, table=None))
    inj = FaultInjector(hw=TPU_V5E)
    x = np.arange(NDEV * (NBYTES // 4), dtype=np.int32).reshape(NDEV, -1)

    def run():
        fn = jax.jit(shard_map(
            lambda v: (eng.bcast(v[0], "x", 2)[None],
                       eng.allreduce(v, "x")),
            mesh=ring, in_specs=(P("x", None),),
            out_specs=(P("x", None), P("x", None)), check_vma=False))
        b, a = fn(jnp.asarray(x))
        return np.asarray(b), np.asarray(a)

    healthy = run()
    inj.down_link("x", hop)
    eng.invalidate_resolutions(health=inj.down_links())
    for op in ("bcast", "allreduce"):
        resolved = eng.schedule_for(op, nbytes=NBYTES, axis="x")
        assert resolved == "chain_rooted", (op, hop, resolved)
        route = route_links(op, resolved, eng.topology.axes,
                            health=inj.down_links())
        assert route is not None and ("x", hop) not in route
    rerouted = run()
    np.testing.assert_array_equal(rerouted[0], healthy[0],
                                  err_msg=f"bcast hop={hop}")
    np.testing.assert_array_equal(rerouted[1], healthy[1],
                                  err_msg=f"allreduce hop={hop}")
    np.testing.assert_array_equal(healthy[0], np.broadcast_to(x[2], x.shape))
    np.testing.assert_array_equal(healthy[1],
                                  np.broadcast_to(x.sum(axis=0), x.shape))


def test_rank_loss_elastic_resume_bitwise(ring, tmp_path):
    """Rank 7 dies at step 3: the loop resumes on the 4-survivor mesh from
    the resharded checkpoint, bitwise equal to a control restored from the
    identical snapshot on the identical mesh."""
    from repro.train.loop import train_loop_elastic

    cfg = tiny(NDEV, layers=2)
    data = DataConfig(cfg.vocab_size, NDEV, 16)

    def _lcfg(**kw):
        return TrainLoopConfig(steps=5, step_mode="explicit_tp", **kw)

    def _rcfg(ckdir):
        return RunConfig(checkpoint_dir=str(ckdir), checkpoint_every=2,
                         learning_rate=1e-3, warmup_steps=1)

    inj = FaultInjector(hw=TPU_V5E)
    fault = FaultSchedule.rank_loss(inj, 3, rank=NDEV - 1)
    hist, rec = train_loop_elastic(
        cfg, _rcfg(tmp_path / "ck"), data, _lcfg(fault_schedule=fault),
        mesh=ring, snapshot_dir=str(tmp_path / "snap"))

    assert rec is not None
    assert rec["lost_ranks"] == [NDEV - 1] and rec["fail_step"] == 3
    assert rec["new_size"] == 4 and rec["old_size"] == NDEV
    assert rec["resume_step"] <= rec["fail_step"]
    assert hist["step"][-1] == 4  # the resumed run finished all 5 steps

    devices = list(np.asarray(ring.devices).flat)
    ctrl_mesh = make_mesh((4,), ("x",),
                          devices=np.array(devices[:4]))
    ctrl = train_loop(cfg, _rcfg(tmp_path / "snap"), data, _lcfg(),
                      mesh=ctrl_mesh)
    i = hist["step"].index(rec["resume_step"])
    assert hist["loss"][i:] == ctrl["loss"]  # bitwise, not approx
