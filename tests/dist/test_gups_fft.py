"""Distributed GUPS + pencil FFT equivalence on the 8-device mesh.

The engine-routed RandomAccess must restore exactly under the inverse
update sequence, and agree with a numpy oracle that applies *every*
generated update, for every registered ``all_to_all_tiles`` schedule and
chunk count. The pencil FFT localizes full signals before transforming, so
its output is **bitwise** ``jnp.fft.fft`` per schedule x chunking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.callsites import FFT_TRANSPOSE, RA_UPDATES
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.compat import make_mesh
from repro.core import fft as FFT
from repro.core import randomaccess as RA

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

A2A_SCHEDULES = sorted(schedules_for("all_to_all_tiles"))
NCHUNKS = [1, 2, "auto"]

TABLE_LOG = 12
UPR = 64  # updates per rng stream


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


def _ra_fixtures(ring):
    table, seeds = RA._make_table_and_seeds(ring, table_log=TABLE_LOG,
                                            rngs_per_device=2)
    return table, seeds


def _np_apply_all_updates(table: np.ndarray, seeds: np.ndarray,
                          sign: int) -> np.ndarray:
    """Oracle: every generated update applied to its global address with
    int32 wraparound — what the routed path must compute."""
    out = table.astype(np.int64)
    mask = (1 << TABLE_LOG) - 1
    for s in seeds.reshape(-1):
        x = int(s) & 0xFFFFFFFF
        for _ in range(UPR):
            x = ((x << 1) & 0xFFFFFFFF) ^ (int(RA.POLY) if x >> 31 else 0)
            upd = np.int64(np.int32(np.uint32(x))) * sign
            out[x & mask] += upd
    # int32 wraparound semantics
    return out.astype(np.int64).astype(np.int32)


@pytest.mark.parametrize("nchunks", NCHUNKS)
@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
def test_routed_gups_restores_exactly(ring, schedule, nchunks):
    res = RA.run_randomaccess_dist(ring, table_log=TABLE_LOG,
                                   rngs_per_device=2, updates_per_rng=UPR,
                                   reps=1, schedule=schedule,
                                   nchunks=nchunks)
    assert res.error == 0.0, (schedule, nchunks, res.error)
    assert res.details["schedule"] == schedule
    assert res.details["schedule"] != "auto"


@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
def test_routed_gups_matches_global_oracle(ring, schedule):
    table, seeds = _ra_fixtures(ring)
    step = RA.make_routed_step(
        ring, CollectiveEngine.for_mesh(ring, schedule=schedule),
        updates_per_rng=UPR, table_log=TABLE_LOG, sign=+1)
    got = np.asarray(step(table, seeds))
    want = _np_apply_all_updates(np.asarray(table), np.asarray(seeds), +1)
    np.testing.assert_array_equal(got, want)


def test_routed_gups_schedules_agree_bitwise(ring):
    table, seeds = _ra_fixtures(ring)
    outs = {}
    for schedule in A2A_SCHEDULES:
        step = RA.make_routed_step(
            ring, CollectiveEngine.for_mesh(ring, schedule=schedule),
            updates_per_rng=UPR, table_log=TABLE_LOG, sign=+1)
        outs[schedule] = np.asarray(step(table, seeds))
    base = outs[A2A_SCHEDULES[0]]
    for schedule, out in outs.items():
        np.testing.assert_array_equal(out, base, err_msg=schedule)


def _fft_input(batch, n):
    rng = np.random.default_rng(11)
    return (rng.standard_normal((batch, n)).astype(np.float32)
            + 1j * rng.standard_normal((batch, n)).astype(np.float32)
            ).astype(np.complex64)


@pytest.mark.parametrize("nchunks", NCHUNKS)
@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
def test_pencil_fft_bitwise_vs_jnp(ring, schedule, nchunks):
    batch, n = 2 * NDEV, 1 << 9
    x = _fft_input(batch, n)
    # the bitwise reference is jnp.fft.fft at the SAME (batch/P, n) block
    # shape each rank transforms — XLA's CPU FFT is shape-deterministic but
    # not row-independent across batch sizes, so the monolithic full-batch
    # transform differs in final bits (~1e-7 relative) while the
    # per-block transform, which is literally what the pencil path runs
    # after localizing full signals, must agree exactly
    blk = batch // NDEV
    ref = jax.jit(lambda a: jnp.fft.fft(a, axis=-1))
    want = np.concatenate([np.asarray(ref(x[j * blk:(j + 1) * blk]))
                           for j in range(NDEV)])

    engine = CollectiveEngine.for_mesh(ring, schedule=schedule)
    if nchunks == "auto":
        nchunks = engine.pipeline_chunks(
            "all_to_all_tiles", nbytes=batch * (n // NDEV) * 8, axis="x",
            callsite=FFT.CALLSITE)
    step = FFT.make_dist_step(ring, engine, nchunks=max(int(nchunks), 1))
    x_sh = jax.device_put(jnp.asarray(x), NamedSharding(ring, P(None, "x")))
    got = np.asarray(step(x_sh))
    np.testing.assert_array_equal(got, want, err_msg=f"{schedule}")
    # and the monolithic transform agrees to float32 FFT accuracy
    full = np.asarray(ref(jnp.asarray(x)))
    assert np.max(np.abs(got - full)) / np.max(np.abs(full)) < 1e-5


def test_pencil_fft_schedules_agree_bitwise(ring):
    batch, n = 2 * NDEV, 1 << 9
    x = _fft_input(batch, n)
    x_sh = jax.device_put(jnp.asarray(x), NamedSharding(ring, P(None, "x")))
    outs = {}
    for schedule in A2A_SCHEDULES:
        engine = CollectiveEngine.for_mesh(ring, schedule=schedule)
        for nchunks in (1, 2):
            step = FFT.make_dist_step(ring, engine, nchunks=nchunks)
            outs[(schedule, nchunks)] = np.asarray(step(x_sh))
    keys = sorted(outs)
    base = outs[keys[0]]
    for key in keys[1:]:
        np.testing.assert_array_equal(outs[key], base, err_msg=str(key))


def test_callsites_resolve_to_registered_schedules(ring):
    engine = CollectiveEngine.for_mesh(ring, schedule="auto")
    for callsite, nbytes in ((RA_UPDATES, 1 << 16), (FFT_TRANSPOSE, 1 << 16)):
        name = engine.schedule_for("all_to_all_tiles", nbytes=nbytes,
                                   axis="x", callsite=callsite)
        assert name != "auto" and name in schedules_for("all_to_all_tiles")
