"""Engine-routed explicit tensor-parallel decode on the 8-device mesh.

The serving tentpole guarantee: ``make_decode_step_explicit`` — the paged
single-token decode inside one ``shard_map``, heads exchanged under
``decode.qkv``/``decode.out`` tags and the MoE dispatch/combine under
``decode.moe`` — must match the GSPMD ``make_paged_decode_step`` from
identical pages for EVERY registered ``all_to_all_tiles`` schedule: the
logits AND the page pool, at every decode step. The two programs share all
the math (the exchanges only relocate heads/capacity strips), so the
comparison is exact-tolerance f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.engine import schedules_for
from repro.compat import make_mesh
from repro.configs.qwen3_moe_235b_a22b import tiny
from repro.models import transformer as T
from repro.models.kvcache import (PagedCacheConfig, PageAllocator,
                                  commit_prefill)
from repro.models.model import build_model
from repro.train.serve import (make_decode_step_explicit,
                               make_paged_decode_step, make_prefill_step)

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

B, S0, STEPS = NDEV, 5, 3
PAGE = 4


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


@pytest.fixture(scope="module")
def served():
    """Prefilled pages + the GSPMD decode trajectory (the reference)."""
    cfg = tiny(NDEV)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pcfg = PagedCacheConfig(page_size=PAGE, max_slots=B, max_seq=S0 + STEPS,
                            num_pages=B * pcfg_pages(S0 + STEPS))
    prompts = jax.random.randint(jax.random.key(1), (B, S0), 0,
                                 cfg.vocab_size).astype(jnp.int32)

    prefill = make_prefill_step(model, None)
    alloc = PageAllocator(pcfg)
    pages = T.init_paged_cache(cfg, pcfg, jnp.float32)
    first = np.zeros((B, 1), np.int32)
    for b in range(B):
        slot = alloc.allocate(S0 + STEPS)
        c1 = model.init_cache(1, S0, jnp.float32)
        lg, c1 = prefill(params, {"tokens": prompts[b:b + 1]}, c1)
        pages["layers"] = commit_prefill(
            pages["layers"], c1["layers"],
            jnp.asarray(alloc.block_table[slot]), S0,
            page_size=pcfg.page_size)
        alloc.commit(slot, S0)
        first[slot, 0] = int(jnp.argmax(lg[0, -1]))

    # GSPMD reference trajectory: greedy tokens, logits and pages per step
    pd = make_paged_decode_step(model, None)
    ref = {"logits": [], "pages": [], "tables": [], "toks": [first]}
    pg = jax.tree.map(lambda a: a.copy(), pages)
    a2 = _clone_alloc(alloc, pcfg)
    tok = first
    for _ in range(STEPS):
        bt, ln = a2.device_tables()
        ref["tables"].append((bt, ln))
        lg, pg = pd(params, jnp.asarray(tok), pg, bt, ln)
        # np.array copies: np.asarray can alias the CPU device buffer,
        # which the donating decode step recycles on the next call
        ref["logits"].append(np.array(lg))
        ref["pages"].append([np.array(x) for x in jax.tree.leaves(pg)])
        for s in range(B):
            a2.append(s)
        tok = np.asarray(jnp.argmax(lg[:, -1], -1), np.int32)[:, None]
        ref["toks"].append(tok)
    return cfg, model, params, pcfg, alloc, pages, ref


def pcfg_pages(max_seq: int) -> int:
    return -(-max_seq // PAGE)


def _clone_alloc(alloc, pcfg):
    a2 = PageAllocator(pcfg)
    a2.block_table[:] = alloc.block_table
    a2.seq_lens[:] = alloc.seq_lens
    a2._capacity[:] = alloc._capacity
    return a2


@pytest.mark.parametrize(
    "schedule", [None] + sorted(schedules_for("all_to_all_tiles")))
def test_explicit_decode_matches_gspmd(served, ring, schedule):
    """Logits AND cache parity per decode step, per registered schedule
    (None = the cost-model "auto" resolution)."""
    cfg, model, params, pcfg, alloc, pages, ref = served
    pd_e = make_decode_step_explicit(model, ring, schedule=schedule)
    pe = jax.tree.map(lambda a: a.copy(), pages)
    for i in range(STEPS):
        bt, ln = ref["tables"][i]
        le, pe = pd_e(params, jnp.asarray(ref["toks"][i]), pe, bt, ln)
        np.testing.assert_allclose(np.asarray(le), ref["logits"][i],
                                   rtol=0, atol=2e-5)
        for got, want in zip(jax.tree.leaves(pe), ref["pages"][i]):
            np.testing.assert_allclose(np.asarray(got), want,
                                       rtol=0, atol=2e-5)


def test_explicit_serve_engine_matches_gspmd_engine(served, ring):
    """End-to-end continuous batching: the explicit-mode ServeEngine must
    emit the same token streams as the GSPMD-mode engine on the same
    workload (mixed prompt lengths, slot churn)."""
    from repro.serve import ServeEngine
    cfg, model, params, _, _, _, _ = served

    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (5, 3, 7, 4, 6, 5, 4, 3, 6, 7)]
    max_new = 4

    def run(mode, mesh):
        pcfg = PagedCacheConfig(page_size=PAGE, max_slots=B, max_seq=16,
                                num_pages=B * pcfg_pages(16))
        eng = ServeEngine(model, params, pcfg, mode=mode, mesh=mesh,
                          prefill_token_budget=16)
        return eng.run(prompts, max_new_tokens=max_new, collect_stats=True)

    out_g, _ = run("gspmd", None)
    out_e, stats = run("explicit", ring)
    assert sum(1 for s in stats if s["prefills"] and s["decode_tokens"]) > 0
    for rid in out_g:
        np.testing.assert_array_equal(out_g[rid], out_e[rid])


def test_explicit_decode_divisibility_errors(ring):
    """Head counts that don't divide the axis must fail loudly at build."""
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("llama3.2-3b"), layers=1, d_model=32)  # 4 heads
    model = build_model(cfg)
    with pytest.raises(ValueError, match="divisible"):
        make_decode_step_explicit(model, ring)


def test_explicit_engine_slot_divisibility(served, ring):
    from repro.serve import ServeEngine
    cfg, model, params, _, _, _, _ = served
    pcfg = PagedCacheConfig(page_size=PAGE, max_slots=NDEV + 1, max_seq=16,
                            num_pages=(NDEV + 1) * pcfg_pages(16))
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(model, params, pcfg, mode="explicit", mesh=ring)
