"""Schedule-equivalence suite on the simulated 8-device mesh.

Every registered schedule of the collective engine must produce *identical*
results for the same op — inputs are small integers in float32, so every
summation order is exact and equality is bitwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType as CT
from repro.compat import make_mesh, shard_map

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


@pytest.fixture(scope="module")
def torus():
    return make_mesh((2, 2), ("rows", "cols"))


def _ints(shape, seed=0):
    return np.random.default_rng(seed).integers(-8, 8, shape).astype(np.float32)


def _run_ring(mesh, body):
    spec = P("x", None, None)
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                           check_vma=False))
    return lambda x: np.asarray(fn(jnp.asarray(x)))


# ---------------------------------------------------------------------------
# bcast
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["chain", "native", "staged", "ring2d"])
@pytest.mark.parametrize("src", [0, 3, 7])
def test_bcast_schedules_identical(ring, schedule, src):
    x = _ints((NDEV, 4, 128))
    eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
    out = _run_ring(ring, lambda v: eng.bcast(v[0], "x", jnp.int32(src))[None])(x)
    np.testing.assert_array_equal(out, np.broadcast_to(x[src], out.shape))


def test_bcast_ragged_payload(ring):
    """ring2d pads internally: payload size not divisible by n."""
    x = _ints((NDEV, 3, 5), seed=9)
    for schedule in ("chain", "ring2d", "staged"):
        eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
        out = _run_ring(ring, lambda v: eng.bcast(v[0], "x", 5)[None])(x)
        np.testing.assert_array_equal(out, np.broadcast_to(x[5], out.shape))


# ---------------------------------------------------------------------------
# allreduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["native", "chain", "staged", "rs_ag",
                                      "ring2d"])
def test_allreduce_schedules_identical(ring, schedule):
    x = _ints((NDEV, 6, 128), seed=1)
    eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
    out = _run_ring(ring, lambda v: eng.allreduce(v[0], "x")[None])(x)
    np.testing.assert_array_equal(out, np.broadcast_to(x.sum(0), out.shape))


def test_allreduce_ring2d_torus_axes(torus):
    """ring2d over ('rows','cols'): one ring pass per torus dimension."""
    x = _ints((4, 2, 64), seed=2)
    eng = CollectiveEngine.for_mesh(torus, schedule="ring2d")
    spec = P(("rows", "cols"), None, None)
    fn = jax.jit(shard_map(
        lambda v: eng.allreduce(v[0], ("rows", "cols"))[None],
        mesh=torus, in_specs=(spec,), out_specs=spec, check_vma=False))
    out = np.asarray(fn(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.broadcast_to(x.sum(0), out.shape))


def test_allreduce_scalar_payload(ring):
    x = _ints((NDEV, 1, 1), seed=3)
    for schedule in ("chain", "rs_ag", "staged", "native"):
        eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
        out = _run_ring(ring, lambda v: eng.allreduce(v[0], "x")[None])(x)
        np.testing.assert_array_equal(out, np.broadcast_to(x.sum(0), out.shape))


# ---------------------------------------------------------------------------
# all_to_all_tiles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["native", "chain", "staged"])
def test_all_to_all_schedules_identical(ring, schedule):
    x = _ints((NDEV, NDEV * 2, 16), seed=4)
    eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
    out = _run_ring(ring, lambda v: eng.all_to_all_tiles(
        v[0], "x", split_axis=0, concat_axis=0)[None])(x)
    # reference: rank j gets split j of every source rank, ordered by source
    want = np.stack([
        np.concatenate([x[i, j * 2:(j + 1) * 2] for i in range(NDEV)], 0)
        for j in range(NDEV)])
    np.testing.assert_array_equal(out.reshape(want.shape), want)


# ---------------------------------------------------------------------------
# ring_exchange / grid_transpose
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm,schedule", [(CT.ICI_DIRECT, "direct"),
                                           (CT.ICI_DIRECT, "staged"),
                                           (CT.HOST_STAGED, "auto")])
def test_ring_exchange_schedules_identical(ring, comm, schedule):
    f, b = _ints((NDEV, 1, 32), seed=5), _ints((NDEV, 1, 32), seed=6)
    eng = CollectiveEngine.for_mesh(ring, comm, schedule)
    spec = P("x", None, None)
    fn = jax.jit(shard_map(
        lambda vf, vb: tuple(o[None] for o in
                             eng.ring_exchange(vf[0], vb[0], "x")),
        mesh=ring, in_specs=(spec, spec), out_specs=(spec, spec),
        check_vma=False))
    rl, rr = fn(jnp.asarray(f), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(rl), np.roll(f, 1, 0))
    np.testing.assert_array_equal(np.asarray(rr), np.roll(b, -1, 0))


@pytest.mark.parametrize("schedule", ["direct", "staged", "ring2d"])
def test_grid_transpose_schedules_identical(torus, schedule):
    x = _ints((4, 8, 8), seed=7)
    eng = CollectiveEngine.for_mesh(torus, schedule=schedule)
    spec = P(("rows", "cols"), None, None)
    fn = jax.jit(shard_map(
        lambda v: eng.grid_transpose(v[0], ("rows", "cols"), 2)[None],
        mesh=torus, in_specs=(spec,), out_specs=spec, check_vma=False))
    out = np.asarray(fn(jnp.asarray(x)))
    want = x.reshape(2, 2, 8, 8).transpose(1, 0, 2, 3).reshape(4, 8, 8)
    np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# end-to-end: benchmarks and MoE dispatch through the engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["chain", "native", "ring2d"])
def test_hpl_torus_schedules_converge(torus, schedule):
    from repro.core.hpl import run_hpl
    res = run_hpl(torus, CT.ICI_DIRECT, n=128, b=32, schedule=schedule,
                  reps=1)
    assert res.error < 1.0, (schedule, res.error)
    assert res.details["schedule"] == schedule


def test_ptrans_schedules_agree(torus):
    from repro.core.ptrans import run_ptrans
    for comm, schedule in ((CT.ICI_DIRECT, "auto"), (CT.ICI_DIRECT, "ring2d"),
                           (CT.HOST_STAGED, "auto")):
        res = run_ptrans(torus, comm, n=128, b=32, reps=1, schedule=schedule)
        assert res.error < 1e-5, (comm, schedule, res.error)
        if schedule != "auto":
            assert res.details["schedule"] == schedule


def test_moe_exchange_dispatch_roundtrip(ring):
    from repro.models.moe import exchange_combine, exchange_dispatch
    B_loc, E, C, D = 2, NDEV * 2, 3, 8  # E divisible by ranks
    buf = _ints((NDEV, B_loc, E, C, D), seed=8)
    for schedule in ("native", "chain", "staged"):
        eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
        spec = P("x", None, None, None, None)
        fn = jax.jit(shard_map(
            lambda v: exchange_combine(
                exchange_dispatch(v[0], "x", eng), "x", eng)[None],
            mesh=ring, in_specs=(spec,), out_specs=spec, check_vma=False))
        out = np.asarray(fn(jnp.asarray(buf)))
        np.testing.assert_array_equal(out, buf)


def test_dp_train_step_explicit_engine_schedules(ring):
    """The explicit DP step runs through engine.allreduce for every named
    reduction schedule and produces identical losses (exact for one step
    with identical inputs and bit-equal reductions is not guaranteed for
    float grads, so assert finite + close)."""
    from repro.configs import RunConfig, get_config, reduced
    from repro.models.model import build_model
    from repro.train.step import (init_train_state,
                                  make_dp_train_step_explicit)
    cfg = reduced(get_config("llama3.2-3b"), layers=1, d_model=32)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (NDEV, 16)), jnp.int32)}
    losses = {}
    for kind in ("native", "chain", "rs_ag"):
        run = RunConfig(learning_rate=1e-3, warmup_steps=1)
        state = init_train_state(model, jax.random.key(0))
        step = make_dp_train_step_explicit(model, run, ring,
                                           schedule_kind=kind)
        _, metrics = step(state, batch)
        losses[kind] = float(metrics["loss"])
        assert np.isfinite(losses[kind]), kind
    base = losses["native"]
    for kind, val in losses.items():
        np.testing.assert_allclose(val, base, rtol=1e-5, err_msg=kind)
