"""Explicit expert-parallel MoE equivalence suite on the 8-device mesh.

The engine-routed ``apply_moe_explicit`` path must agree with the dense
``reference_moe`` oracle and with the GSPMD ``apply_moe`` for every
registered ``all_to_all_tiles`` schedule and every pipeline chunk count —
the exchanges are pure data movement and the routing/scatter internals are
shared, so on CPU the agreement is exact (asserted with a tight tolerance
to stay robust to compiler reassociation).
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.engine import CollectiveEngine, schedules_for
from repro.compat import make_mesh
from repro.configs import get_config, reduced
from repro.models import moe as MOE

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

A2A_SCHEDULES = sorted(schedules_for("all_to_all_tiles"))


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


def _cfg(**over):
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    base = dict(num_experts=2 * NDEV, num_experts_per_tok=2,
                capacity_factor=8.0)
    base.update(over)
    return replace(cfg, **base)


def _inputs(cfg, seed=0, B=NDEV, S=16):
    p = MOE.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (B, S, cfg.d_model),
                          jnp.float32)
    return p, x


def _gspmd(cfg, p, x, mesh):
    """The GSPMD path run on the mesh: batch-sharded input, XLA inserts the
    expert resharding itself."""
    xs = jax.device_put(x, NamedSharding(mesh, P("x", None, None)))
    return np.asarray(jax.jit(lambda p, x: MOE.apply_moe(p, cfg, x))(p, xs))


# ---------------------------------------------------------------------------
# explicit == reference == GSPMD, per schedule x chunk count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
@pytest.mark.parametrize("nchunks", [1, 2, "auto"])
def test_explicit_matches_reference_and_gspmd(ring, schedule, nchunks):
    cfg = _cfg()
    p, x = _inputs(cfg)
    out = np.asarray(MOE.apply_moe_explicit(p, cfg, x, ring,
                                            schedule=schedule,
                                            nchunks=nchunks))
    ref = np.asarray(MOE.reference_moe(p, cfg, x))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-4,
                               err_msg=f"{schedule}/nchunks={nchunks}")
    np.testing.assert_allclose(out, _gspmd(cfg, p, x, ring),
                               atol=1e-6, rtol=1e-6,
                               err_msg=f"{schedule}/nchunks={nchunks}")


def test_explicit_schedules_agree_with_each_other(ring):
    """Every (schedule, nchunks) variant lands on the same numbers: the
    exchange route never changes the data, only the wire path."""
    cfg = _cfg()
    p, x = _inputs(cfg, seed=4)
    base = np.asarray(MOE.apply_moe_explicit(p, cfg, x, ring,
                                             schedule="native", nchunks=1))
    for schedule in A2A_SCHEDULES:
        for nchunks in (2, 3, "auto"):
            out = np.asarray(MOE.apply_moe_explicit(
                p, cfg, x, ring, schedule=schedule, nchunks=nchunks))
            np.testing.assert_allclose(
                out, base, atol=1e-6, rtol=1e-6,
                err_msg=f"{schedule}/nchunks={nchunks}")


def test_explicit_auto_engine_resolves_registered(ring):
    """schedule="auto" end-to-end: the engine's per-callsite resolutions are
    registered names (never the literal "auto") and the output still
    matches the oracle."""
    cfg = _cfg()
    p, x = _inputs(cfg, seed=2)
    engine = CollectiveEngine.for_mesh(ring, schedule="auto")
    out = np.asarray(MOE.apply_moe_explicit(p, cfg, x, ring, engine=engine,
                                            nchunks="auto"))
    np.testing.assert_allclose(out, np.asarray(MOE.reference_moe(p, cfg, x)),
                               atol=1e-5, rtol=1e-4)
    nbytes = x.shape[0] // NDEV * cfg.num_experts * 16 * cfg.d_model * 4
    for callsite in (MOE.DISPATCH_CALLSITE, MOE.COMBINE_CALLSITE):
        name = engine.schedule_for("all_to_all_tiles", nbytes=nbytes,
                                   axis="x", callsite=callsite)
        assert name != "auto" and name in schedules_for("all_to_all_tiles")


# ---------------------------------------------------------------------------
# edge cases: capacity overflow, single expert per rank
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
def test_capacity_overflow_drops_match_gspmd(ring, schedule):
    """With capacity_factor << 1 tokens are dropped; the explicit path must
    drop exactly the same slots as the GSPMD path (shared per-row cumsum
    bookkeeping), so outputs agree even though the oracle does not."""
    cfg = _cfg(capacity_factor=0.5)
    p, x = _inputs(cfg, seed=6)
    aux = {}
    want = np.asarray(MOE.apply_moe(p, cfg, x, aux=aux))
    assert float(aux["moe_dropped"]) > 0.0  # the edge case is exercised
    out = np.asarray(MOE.apply_moe_explicit(p, cfg, x, ring,
                                            schedule=schedule, nchunks=2))
    np.testing.assert_allclose(out, want, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("nchunks", [1, "auto"])
def test_single_expert_per_rank_top1(ring, nchunks):
    """E == ranks (one expert per rank, E_loc = 1) with top-1 routing: the
    degenerate exchange shapes still round-trip."""
    cfg = _cfg(num_experts=NDEV, num_experts_per_tok=1, capacity_factor=16.0)
    p, x = _inputs(cfg, seed=8)
    out = np.asarray(MOE.apply_moe_explicit(p, cfg, x, ring,
                                            nchunks=nchunks))
    np.testing.assert_allclose(out, np.asarray(MOE.reference_moe(p, cfg, x)),
                               atol=1e-5, rtol=1e-4)


def test_experts_must_divide_over_axis(ring):
    cfg = _cfg(num_experts=NDEV - 2)
    p, x = _inputs(cfg)
    with pytest.raises(ValueError, match="divisible"):
        MOE.apply_moe_explicit(p, cfg, x, ring)


# ---------------------------------------------------------------------------
# pipelined exchange bit-identity (integer payloads -> exact)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", A2A_SCHEDULES)
def test_pipelined_exchange_bit_identical_to_monolithic(ring, schedule):
    """exchange_dispatch/combine pipelined into capacity strips move exactly
    the same bytes as the monolithic exchange, for every schedule."""
    from repro.compat import shard_map
    rng = np.random.default_rng(9)
    buf = rng.integers(-8, 8, (NDEV, 2, 2 * NDEV, 5, 4)).astype(np.float32)
    eng = CollectiveEngine.for_mesh(ring, schedule=schedule)
    spec = P("x", None, None, None, None)

    def run(nchunks):
        def body(v):
            d = MOE.exchange_dispatch(v[0], "x", eng, nchunks=nchunks)
            return MOE.exchange_combine(d, "x", eng, nchunks=nchunks)[None]
        fn = jax.jit(shard_map(body, mesh=ring, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        return np.asarray(fn(jnp.asarray(buf)))

    mono = run(1)
    np.testing.assert_array_equal(mono, buf)  # dispatch∘combine == identity
    for nchunks in (2, 3, 64, "auto"):  # 64 > C clamps to one slot per strip
        np.testing.assert_array_equal(run(nchunks), mono,
                                      err_msg=str(nchunks))
