"""Whole-model explicit-vs-GSPMD equivalence on the 8-device mesh.

The tentpole guarantee of the explicit path: one full qwen3-moe train step
through ``make_whole_model_train_step_explicit`` — forward+backward inside
a single ``shard_map``, attention exchanged under ``tp.*``/``sp.*`` tags,
MoE under ``moe.*``, gradient buckets under ``dp.grads`` — must match the
GSPMD ``make_train_step`` on the same mesh from identical init: the loss,
the clipped global grad norm, and every updated parameter, for every
registered schedule kind and chunk count tested. The two programs share
all the math; the exchanges and the hand-written reduction/clip only
reassociate float sums, so tolerances are f32-roundoff-sized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import RunConfig
from repro.configs.qwen3_moe_235b_a22b import tiny
from repro.data import DataConfig, SyntheticLMDataset
from repro.models.model import build_model
from repro.models.parallel import ATTN_MODES, make_attn_impl
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.step import (init_train_state, make_train_step,
                              make_whole_model_train_step_explicit)

NDEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NDEV, reason=f"needs {NDEV} devices")

B, S = NDEV, 16


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


def _cfg(layers=2):
    # layers=2 with moe_every=1 gives n_super=2: the super-block scan (and
    # its scanned expert-param specs) is exercised, not just one layer
    return tiny(NDEV, layers=layers)


def _setup(cfg, seed=0):
    model = build_model(cfg)
    data = SyntheticLMDataset(DataConfig(cfg.vocab_size, B, S))
    batch = {"tokens": jnp.asarray(data.batch(0)["tokens"])}
    state = init_train_state(model, jax.random.key(seed))
    return model, batch, state


def _run_cfg():
    return RunConfig(learning_rate=1e-3, warmup_steps=1)


@pytest.fixture(scope="module")
def gspmd_ref(ring):
    """One GSPMD reference step (pure DP on the ring: params replicated)."""
    cfg = _cfg()
    model, batch, state = _setup(cfg)
    step = make_train_step(model, _run_cfg(), ring, donate=False)
    ref_state, ref_metrics = jax.block_until_ready(step(state, batch))
    params = [np.asarray(v, np.float32)
              for v in jax.tree.leaves(ref_state.params)]
    return {"params": params,
            "loss": float(ref_metrics["loss"]),
            "grad_norm": float(ref_metrics["grad_norm"])}


# ---------------------------------------------------------------------------
# explicit whole-model step == GSPMD, per mode x schedule x chunk count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ATTN_MODES)
@pytest.mark.parametrize("schedule_kind", ["native", "chain"])
@pytest.mark.parametrize("nchunks", [1, "auto"])
def test_whole_model_matches_gspmd(ring, gspmd_ref, mode, schedule_kind,
                                   nchunks):
    cfg = _cfg()
    model, batch, state = _setup(cfg)
    step = make_whole_model_train_step_explicit(
        model, _run_cfg(), ring, attn_mode=mode,
        schedule_kind=schedule_kind, nchunks=nchunks)
    new_state, metrics = jax.block_until_ready(step(state, batch))

    tag = f"{mode}/{schedule_kind}/nchunks={nchunks}"
    np.testing.assert_allclose(float(metrics["loss"]), gspmd_ref["loss"],
                               atol=1e-5, rtol=0, err_msg=tag)
    np.testing.assert_allclose(float(metrics["grad_norm"]),
                               gspmd_ref["grad_norm"], rtol=1e-4,
                               err_msg=tag)
    for got, want in zip(jax.tree.leaves(new_state.params),
                         gspmd_ref["params"]):
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   atol=2e-5, rtol=1e-4, err_msg=tag)


def test_modes_agree_with_each_other(ring):
    """tp and sp compute the same updated params (independent of GSPMD)."""
    cfg = _cfg()
    results = {}
    for mode in ATTN_MODES:
        model, batch, state = _setup(cfg)
        step = make_whole_model_train_step_explicit(
            model, _run_cfg(), ring, attn_mode=mode)
        new_state, metrics = jax.block_until_ready(step(state, batch))
        results[mode] = (float(metrics["loss"]),
                         [np.asarray(v, np.float32)
                          for v in jax.tree.leaves(new_state.params)])
    l_tp, p_tp = results["tp"]
    l_sp, p_sp = results["sp"]
    np.testing.assert_allclose(l_tp, l_sp, atol=1e-5, rtol=0)
    for a, b in zip(p_tp, p_sp):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# validation errors
# ---------------------------------------------------------------------------


def test_indivisible_heads_raise(ring):
    import dataclasses
    cfg = dataclasses.replace(_cfg(), num_heads=4, num_kv_heads=4,
                              head_dim=16)  # 4 heads over 8 ranks
    with pytest.raises(ValueError, match="divisible"):
        make_attn_impl("tp", cfg, ring)


def test_unknown_mode_raises(ring):
    with pytest.raises(ValueError, match="unknown attention mode"):
        make_attn_impl("pp", _cfg(), ring)


def test_grad_compression_rejected(ring):
    model, _, _ = _setup(_cfg())
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=1,
                        grad_compression="int8_ef")
    with pytest.raises(ValueError, match="grad_compression"):
        make_whole_model_train_step_explicit(model, run_cfg, ring)


# ---------------------------------------------------------------------------
# explicit train_loop smoke
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("step_mode", ["explicit_tp", "explicit_sp"])
def test_train_loop_explicit_smoke(ring, step_mode):
    cfg = _cfg(layers=1)
    hist = train_loop(
        cfg, _run_cfg(), DataConfig(cfg.vocab_size, B, S),
        TrainLoopConfig(steps=3, log_every=1, step_mode=step_mode),
        mesh=ring)
    assert len(hist["loss"]) == 3
    assert all(np.isfinite(v) for v in hist["loss"])
