"""Unit tests for the legacy GUPS/FFT kernels and the engine-routed
building blocks (the 8-device per-schedule equivalence suite lives in
tests/dist/test_gups_fft.py). Everything here runs at any device count."""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import fft as FFT
from repro.core import randomaccess as RA

NDEV = len(jax.devices())


@pytest.fixture(scope="module")
def ring():
    return make_mesh((NDEV,), ("x",))


# ---------------------------------------------------------------------------
# xorshift generator vs an independent reference
# ---------------------------------------------------------------------------


def _np_xorshift_stream(seed: int, count: int) -> np.ndarray:
    """Pure-python HPCC-style LCG: x <- (x << 1) ^ (msb(x) ? 0x7 : 0)."""
    x = int(seed) & 0xFFFFFFFF
    out = np.empty(count, np.uint32)
    for i in range(count):
        x = ((x << 1) & 0xFFFFFFFF) ^ (int(RA.POLY) if x >> 31 else 0)
        out[i] = x
    return out


@pytest.mark.parametrize("seed", [1, 12345, 0x7FFFFFFF, 0xDEADBEEF])
def test_xorshift_stream_matches_reference(seed):
    got = np.asarray(RA._gen_updates(jnp.uint32(seed), 64))
    want = _np_xorshift_stream(seed, 64)
    np.testing.assert_array_equal(got, want)


def test_xorshift_step_feedback_taps():
    # msb set -> the polynomial is XORed in; msb clear -> plain shift
    assert int(RA._xorshift_step(jnp.uint32(0x80000000))) == int(RA.POLY)
    assert int(RA._xorshift_step(jnp.uint32(1))) == 2


# ---------------------------------------------------------------------------
# legacy drop-local path
# ---------------------------------------------------------------------------


def test_randomaccess_inverse_restore_exact(ring):
    res = RA.run_randomaccess(ring, table_log=12, rngs_per_device=2,
                              updates_per_rng=128, reps=1)
    assert res.error == 0.0


def test_randomaccess_rejects_indivisible_table():
    # must raise (not assert — an `-O` run strips asserts) before any
    # device work: 2**20 is not divisible by 3
    fake = SimpleNamespace(devices=np.zeros(3))
    with pytest.raises(ValueError, match="not divisible"):
        RA.run_randomaccess(fake)


def test_fft_dist_rejects_indivisible_signal():
    fake = SimpleNamespace(devices=np.zeros(3))
    with pytest.raises(ValueError, match="not divisible"):
        FFT.run_fft_dist(fake, log_size=10)


# ---------------------------------------------------------------------------
# update bucketing (the routed path's local half)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sign", [+1, -1])
def test_bucket_updates_matches_numpy_oracle(sign):
    table_log, n_dev = 10, 4
    local_size = (1 << table_log) // n_dev
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 1 << 32, 256, dtype=np.uint32)

    buf = np.asarray(RA._bucket_updates(
        jnp.asarray(vals), table_log=table_log, local_size=local_size,
        n_dev=n_dev, sign=sign))
    assert buf.shape == (n_dev, len(vals), 2)
    assert buf.dtype == np.int32

    addr = (vals & np.uint32((1 << table_log) - 1)).astype(np.int64)
    want_dest = addr // local_size
    # every update lands in exactly its owner's bucket, value preserved
    # (scatter-applying each bucket == applying every update once)
    applied = np.zeros(1 << table_log, np.int64)
    for d in range(n_dev):
        loc, upd = buf[d, :, 0], buf[d, :, 1]
        live = loc < local_size  # sentinel local_size marks unused slots
        assert np.count_nonzero(live) == np.count_nonzero(want_dest == d)
        np.add.at(applied, d * local_size + loc[live], upd[live])
        assert np.all(upd[~live] == 0)
    want = np.zeros(1 << table_log, np.int64)
    np.add.at(want, addr, vals.astype(np.int32).astype(np.int64) * sign)
    np.testing.assert_array_equal(applied, want)


def test_routed_randomaccess_restore_exact(ring):
    res = RA.run_randomaccess_dist(ring, table_log=12, rngs_per_device=2,
                                   updates_per_rng=128, reps=1,
                                   schedule="native", nchunks=1)
    assert res.error == 0.0
    assert res.details["schedule"] == "native"


# ---------------------------------------------------------------------------
# FFT: full-output validation
# ---------------------------------------------------------------------------


def test_fft_error_covers_full_output(ring):
    res = FFT.run_fft(ring, log_size=8, batch_per_device=4, reps=1)
    assert res.error < 1e-5


def test_fft_dist_matches_reference(ring):
    res = FFT.run_fft_dist(ring, log_size=8, batch_per_device=4, reps=1,
                           schedule="native", nchunks=1)
    assert res.error < 1e-5
    assert res.details["schedule"] == "native"
