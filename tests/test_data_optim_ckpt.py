"""Substrate tests: data pipeline determinism/sharding, AdamW, checkpoint
atomicity + retention + elastic restore, LR schedule."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import DataConfig, SyntheticLMDataset, make_batch_iterator
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, global_norm, make_lr_schedule)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic():
    cfg = DataConfig(vocab_size=512, global_batch=8, seq_len=64)
    a = SyntheticLMDataset(cfg).batch(7)
    b = SyntheticLMDataset(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_steps_differ():
    cfg = DataConfig(vocab_size=512, global_batch=8, seq_len=64)
    ds = SyntheticLMDataset(cfg)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


def test_data_shards_partition_batch():
    """Shards are disjoint rows of the same global batch: elastic re-shard."""
    cfg = DataConfig(vocab_size=512, global_batch=8, seq_len=32)
    ds = SyntheticLMDataset(cfg)
    full = ds.batch(3, shard=0, num_shards=1)["tokens"]
    parts = [ds.batch(3, shard=i, num_shards=4)["tokens"] for i in range(4)]
    assert all(p.shape == (2, 32) for p in parts)
    # rows are generated per (step, shard) so shards differ from each other
    assert not np.array_equal(parts[0], parts[1])
    assert full.shape == (8, 32)


def test_data_iterator_resumes():
    cfg = DataConfig(vocab_size=512, global_batch=4, seq_len=32)
    it = make_batch_iterator(cfg)
    batches = [next(it) for _ in range(5)]
    it2 = make_batch_iterator(cfg, start_step=3)
    step, batch = next(it2)
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], batches[3][1]["tokens"])


def test_data_has_learnable_structure():
    """Markov tokens: successor sets are small -> bigram entropy << uniform."""
    cfg = DataConfig(vocab_size=256, global_batch=4, seq_len=256)
    ds = SyntheticLMDataset(cfg)
    toks = ds.batch(0)["tokens"]
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg_branching = np.mean([len(v) for v in succ.values()])
    assert avg_branching <= cfg.branching + 1


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = adamw_update(grads, state, params, cfg,
                                     jnp.asarray(0.1))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(800.0), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: untouched
    small = {"a": jnp.full((4,), 0.01)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.01, rtol=1e-6)


def test_lr_schedule_shape():
    sched = make_lr_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1e-3, rtol=1e-5)
    assert float(sched(5)) == pytest.approx(5e-4, rel=1e-5)
    assert float(sched(100)) == pytest.approx(1e-4, rel=1e-3)  # min_ratio
    assert float(sched(55)) < float(sched(20))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 7, {"state": tree}, extra={"loss": 1.5})
    step, out, extra = ckpt.restore(d, {"state": tree})
    assert step == 7 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        ckpt.save(d, s, {"state": _tree(s)}, keep=3)
    assert ckpt.manager.all_steps(d) == [3, 4, 5]


def test_checkpoint_ignores_stale_tmp(tmp_path):
    """A crash mid-write leaves step_X.tmp; restore must skip it."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"state": _tree()})
    os.makedirs(os.path.join(d, "step_0000000002.tmp"))
    assert ckpt.latest_step(d) == 1
    step, _, _ = ckpt.restore(d, {"state": _tree()})
    assert step == 1
    # next good save garbage-collects the tmp
    ckpt.save(d, 3, {"state": _tree()})
    assert not any(e.endswith(".tmp") for e in os.listdir(d))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"state": _tree()})
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(d, {"state": bad})


def test_checkpoint_mismatch_reports_every_leaf(tmp_path):
    """A structure mismatch raises CheckpointMismatchError carrying the
    complete diagnosis — every missing and shape-mismatched leaf across
    all trees, not a bare KeyError on the first absent array."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"state": _tree()})
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((2, 2))       # wrong shape
    bad["params"]["extra"] = jnp.zeros(3)        # not in the checkpoint
    with pytest.raises(ckpt.CheckpointMismatchError) as ei:
        ckpt.restore(d, {"state": bad})
    err = ei.value
    assert err.missing == ("state:params/extra",)
    assert err.shape_mismatches == (("state:params/w", (4, 4), (2, 2)),)
    for frag in ("missing from checkpoint", "state:params/extra",
                 "state:params/w", "(4, 4)", "(2, 2)"):
        assert frag in str(err), frag


def test_checkpoint_subset_restore_still_allowed(tmp_path):
    """Unexpected-on-disk leaves alone are informational: restoring a
    subset of the saved structure must keep working."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"state": _tree()})
    subset = {"params": {"w": jnp.zeros((4, 4))}}
    step, out, _ = ckpt.restore(d, {"state": subset})
    assert step == 1
    assert set(out["state"]["params"]) == {"w"}


def test_checkpoint_restore_reshard_to_mesh(tmp_path):
    """reshard_to derives replicated NamedShardings for a plain tree —
    the rank-loss recovery path in miniature."""
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 1, {"state": tree})
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    step, out, _ = ckpt.restore(d, {"state": tree}, reshard_to=mesh)
    assert step == 1
    assert out["state"]["params"]["w"].sharding.is_equivalent_to(
        jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), 2)


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore under a different sharding (1-device mesh here; the 8-device
    cross-mesh restore runs in the distributed suite)."""
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 1, {"state": tree})
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    sharding = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), tree)
    step, out, _ = ckpt.restore(d, {"state": tree},
                                shardings={"state": sharding})
    assert out["state"]["params"]["w"].sharding.is_equivalent_to(
        jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), 2)
