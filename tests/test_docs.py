"""Doc-layer drift gates.

Two guarantees, both cheap and fully offline:

* the README's "Callsite tag registry" table is a faithful rendering of
  :data:`repro.comm.callsites.CALLSITES` — same tags, same ops, same
  owning modules, pairing claims that exist in :mod:`repro.comm.autotune`
  — and every constant really is imported and used by its owning module;
* every relative markdown link and anchor in README.md / ROADMAP.md /
  docs/*.md resolves (tools/check_md_links.py).
"""
from __future__ import annotations

import importlib
import importlib.util
import inspect
import os
import re

from repro.comm import callsites as CS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")


# ---------------------------------------------------------------------------
# README table <-> CALLSITES
# ---------------------------------------------------------------------------


def _registry_table_rows():
    """Parse the '### Callsite tag registry' table into
    {tag: (op, module, pairing_cell)}."""
    with open(README, encoding="utf-8") as f:
        text = f.read()
    m = re.search(r"#+\s*Callsite tag registry(.*?)(?:\n#|\Z)", text,
                  re.DOTALL)
    assert m, "README is missing the 'Callsite tag registry' section"
    rows = {}
    for line in m.group(1).splitlines():
        if not line.strip().startswith("|"):
            continue
        cells = [c.strip().strip("`") for c in line.strip().strip("|")
                 .split("|")]
        if len(cells) < 4 or cells[0] in ("tag", "") or set(cells[0]) <= {"-"}:
            continue
        rows[cells[0]] = (cells[1], cells[2], cells[3])
    return rows


def test_readme_table_matches_registry():
    rows = _registry_table_rows()
    assert set(rows) == set(CS.CALLSITES), (
        f"README table rows {sorted(rows)} != registry tags "
        f"{sorted(CS.CALLSITES)}")
    for tag, (op, module, pairing) in rows.items():
        cs = CS.CALLSITES[tag]
        assert op == cs.op, (tag, op, cs.op)
        assert module == cs.module, (tag, module, cs.module)
        if cs.tuned is None:
            assert "fallback" in pairing or "untagged" in pairing, (
                f"{tag}: registry says untagged fallback, table says "
                f"{pairing!r}")
        else:
            assert cs.tuned in pairing, (
                f"{tag}: table pairing {pairing!r} does not name the "
                f"measured pattern {cs.tuned!r}")


def test_constants_used_by_owning_modules():
    """Each tag's constant is imported from repro.comm.callsites by its
    owning module and actually used there — a renamed or orphaned tag
    fails here, not silently at tuning time."""
    for tag, cs in CS.CALLSITES.items():
        assert getattr(CS, cs.const) == tag, (cs.const, tag)
        mod = importlib.import_module(cs.module)
        src = inspect.getsource(mod)
        assert re.search(r"from repro\.comm\.callsites import", src), (
            f"{cs.module} does not import from repro.comm.callsites")
        assert re.search(rf"\b{cs.const}\b", src), (
            f"constant {cs.const} ({tag!r}) unused in {cs.module}")
        assert f'"{tag}"' not in src.replace(f'"{cs.op}@{tag}"', ""), (
            f"{cs.module} inlines the literal {tag!r} instead of "
            f"using {cs.const}")


def test_tuned_patterns_exist_in_autotune():
    """Every `tuned` claim maps to a real autotune pattern: the key is in
    autotune_mesh's default op list, and when a tag inherits a paired
    measurement, PAIRED_ALIASES really aliases it."""
    from repro.comm.autotune import PAIRED_ALIASES, autotune_mesh

    default_ops = inspect.signature(autotune_mesh).parameters["ops"].default
    for tag, cs in CS.CALLSITES.items():
        if cs.tuned is None:
            continue
        assert cs.tuned in default_ops, (
            f"{tag}: measured pattern {cs.tuned!r} is not in "
            f"autotune_mesh's default ops {default_ops}")
        own_key = f"{cs.op}@{tag}"
        if cs.tuned != own_key:
            assert own_key in PAIRED_ALIASES.get(cs.tuned, ()), (
                f"{tag}: inherits {cs.tuned!r} but PAIRED_ALIASES does "
                f"not alias {own_key!r} to it")


# ---------------------------------------------------------------------------
# resilience docs <-> code
# ---------------------------------------------------------------------------


def _section(path, heading_re):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    m = re.search(heading_re + r"(.*?)(?:\n## |\Z)", text, re.DOTALL)
    assert m, f"{os.path.relpath(path, REPO)} is missing {heading_re!r}"
    return m.group(1)


def test_architecture_resilience_section_names_real_api():
    """ARCHITECTURE.md §8 must keep naming the symbols it documents, and
    every one of them must still exist where the section says it lives."""
    sec = _section(os.path.join(REPO, "docs", "ARCHITECTURE.md"),
                   r"## 8\. Resilience")
    symbols = {
        "repro.comm.faults": ["FaultInjector", "LinkFault", "FaultSchedule",
                              "degrade_window", "hardware_view", "injected",
                              "extra_time", "sleep"],
        "repro.comm.retune": ["RetuneController", "RetuneEvent",
                              "on_straggler", "hw_probe"],
        "repro.train.straggler": ["StragglerMonitor", "POLICIES"],
    }
    for module, names in symbols.items():
        mod = importlib.import_module(module)
        src = inspect.getsource(mod)
        for name in names:
            assert name in sec, f"ARCHITECTURE §8 no longer mentions {name}"
            assert re.search(rf"\b{name}\b", src), (
                f"§8 documents {name} but {module} no longer defines/uses it")
    # the engine hook the whole section pivots on
    from repro.comm.engine import CollectiveEngine
    assert "invalidate_resolutions" in sec
    assert callable(CollectiveEngine.invalidate_resolutions)
    # the documented straggler policies are the real ones
    from repro.train.straggler import POLICIES
    for policy in POLICIES:
        assert f"`{policy}`" in sec, f"§8 does not document policy {policy!r}"
    # the documented serve finish reasons exist in the scheduler contract
    import repro.serve.scheduler as sched
    for reason in ("timeout", "rejected"):
        assert f'"{reason}"' in sec
        assert f'"{reason}"' in inspect.getsource(sched)


def test_readme_resilience_quickstart_executes():
    """The README's fault-injection quickstart is executable as written —
    including its asserts, so the documented chain -> staged -> chain flip
    is re-proven against the live cost model on every run."""
    sec = _section(README, r"## Resilience")
    m = re.search(r"```python\n(.*?)```", sec, re.DOTALL)
    assert m, "README Resilience section lost its python quickstart"
    exec(compile(m.group(1), "README.md#resilience", "exec"), {})


def test_readme_failover_quickstart_executes():
    """The README's dead-link quickstart is executable as written —
    including its asserts, so the documented chain -> chain_rooted ->
    chain reroute and the route-exclusion proof are re-proven against the
    live cost model on every run."""
    sec = _section(README, r"## Failover")
    m = re.search(r"```python\n(.*?)```", sec, re.DOTALL)
    assert m, "README Failover section lost its python quickstart"
    exec(compile(m.group(1), "README.md#failover", "exec"), {})


# ---------------------------------------------------------------------------
# markdown links
# ---------------------------------------------------------------------------


def _load_checker():
    path = os.path.join(REPO, "tools", "check_md_links.py")
    spec = importlib.util.spec_from_file_location("check_md_links", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    checker = _load_checker()
    problems = []
    for f in checker.default_files():
        problems += [(os.path.relpath(f, REPO), link, why)
                     for link, why in checker.check_file(f)]
    assert not problems, f"broken markdown links: {problems}"
