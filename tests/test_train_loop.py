"""Fault tolerance: loss decreases, crash injection + auto-resume is
bit-exact with the uninterrupted run, straggler monitor flags outliers."""
from __future__ import annotations

import numpy as np
import pytest

from repro.checkpoint.manager import all_steps, restore
from repro.comm.faults import FaultInjector, FaultSchedule
from repro.configs import RunConfig, get_config, reduced
from repro.data import DataConfig
from repro.train.loop import InjectedFailure, TrainLoopConfig, train_loop
from repro.train.straggler import POLICIES, StragglerMonitor


def _cfgs(tmp_path, steps=14, every=5):
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    run = RunConfig(checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=every,
                    learning_rate=1e-2, warmup_steps=2)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32)
    return cfg, run, data


def test_loss_decreases(tmp_path):
    cfg, run, data = _cfgs(tmp_path)
    hist = train_loop(cfg, run, data, TrainLoopConfig(steps=14))
    assert hist["loss"][-1] < hist["loss"][0]
    assert hist["step"] == list(range(14))


def test_crash_resume_bit_exact(tmp_path):
    cfg, run, data = _cfgs(tmp_path)
    with pytest.raises(InjectedFailure):
        train_loop(cfg, run, data, TrainLoopConfig(steps=14, fail_at_step=12))
    resumed = train_loop(cfg, run, data, TrainLoopConfig(steps=14))
    assert resumed["step"][0] == 10  # restarted from the step-10 checkpoint

    cfg2, run2, data2 = _cfgs(tmp_path / "fresh")
    clean = train_loop(cfg2, run2, data2, TrainLoopConfig(steps=14))
    np.testing.assert_allclose(resumed["loss"][-1], clean["loss"][-1],
                               rtol=1e-6)


def test_straggler_monitor_flags():
    mon = StragglerMonitor(deadline_factor=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)           # 5x median -> flagged
    assert not mon.record(11, 0.15)
    assert list(mon.flagged) == [10]
    s = mon.summary()
    assert s["median_s"] == pytest.approx(0.1, rel=0.2)
    assert mon.deadline() == pytest.approx(0.2, rel=0.2)


def test_straggler_policy_validated():
    assert POLICIES == ("warn", "checkpoint", "retune")
    with pytest.raises(ValueError, match="straggler policy"):
        StragglerMonitor(policy="evict")
    with pytest.raises(ValueError, match="straggler policy"):
        train_loop(*_cfgs_noop(), TrainLoopConfig(
            steps=1, straggler_policy="evict"))


def _cfgs_noop():
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    run = RunConfig(learning_rate=1e-2, warmup_steps=2)  # no checkpointing
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32)
    return cfg, run, data


def test_forced_checkpoint_on_injected_straggler(tmp_path):
    """An injected host delay blows the step deadline; under policy
    'checkpoint' every flagged step forces an off-cadence save."""
    cfg, run, data = _cfgs(tmp_path, steps=12, every=100)  # cadence never hits
    inj = FaultInjector()
    fault = FaultSchedule.degrade_window(inj, 9, 11, axis="x",
                                         host_delay_s=0.3,
                                         callsite="train.step")
    hist = train_loop(cfg, run, data, TrainLoopConfig(
        steps=12, straggler_policy="checkpoint", fault_schedule=fault))

    flagged = hist["straggler"]["flagged"]
    assert flagged and set(flagged) <= {9, 10}  # only the injected window
    steps = all_steps(str(tmp_path / "ck"))
    forced = [s for s in steps
              if restore(str(tmp_path / "ck"), {}, step=s)[2].get("forced")]
    assert forced == [s + 1 for s in flagged]  # saved right after each flag
    assert steps[-1] == 12  # the final save still lands


def test_retune_policy_routes_straggler_flags(tmp_path):
    """Under policy 'retune' a flagged step goes to the controller's
    on_straggler; nominal steps feed observe. Duck-typed controller — the
    loop only needs observe/on_straggler/events."""

    class _FakeController:
        def __init__(self):
            self.observed, self.straggled, self.events = [], [], []

        def observe(self, step, duration):
            self.observed.append(step)
            return None

        def on_straggler(self, step):
            self.straggled.append(step)
            return None

    cfg, run, data = _cfgs_noop()
    inj = FaultInjector()
    fault = FaultSchedule.degrade_window(inj, 9, 11, axis="x",
                                         host_delay_s=0.3,
                                         callsite="train.step")
    ctrl = _FakeController()
    hist = train_loop(cfg, run, data, TrainLoopConfig(
        steps=12, straggler_policy="retune", fault_schedule=fault,
        retune=ctrl))

    assert ctrl.straggled == hist["straggler"]["flagged"]
    assert ctrl.straggled and set(ctrl.straggled) <= {9, 10}
    assert sorted(ctrl.observed + ctrl.straggled) == list(range(12))
    assert hist["retune_events"] is ctrl.events
