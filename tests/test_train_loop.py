"""Fault tolerance: loss decreases, crash injection + auto-resume is
bit-exact with the uninterrupted run, straggler monitor flags outliers."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced
from repro.data import DataConfig
from repro.train.loop import InjectedFailure, TrainLoopConfig, train_loop
from repro.train.straggler import StragglerMonitor


def _cfgs(tmp_path, steps=14, every=5):
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    run = RunConfig(checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=every,
                    learning_rate=1e-2, warmup_steps=2)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32)
    return cfg, run, data


def test_loss_decreases(tmp_path):
    cfg, run, data = _cfgs(tmp_path)
    hist = train_loop(cfg, run, data, TrainLoopConfig(steps=14))
    assert hist["loss"][-1] < hist["loss"][0]
    assert hist["step"] == list(range(14))


def test_crash_resume_bit_exact(tmp_path):
    cfg, run, data = _cfgs(tmp_path)
    with pytest.raises(InjectedFailure):
        train_loop(cfg, run, data, TrainLoopConfig(steps=14, fail_at_step=12))
    resumed = train_loop(cfg, run, data, TrainLoopConfig(steps=14))
    assert resumed["step"][0] == 10  # restarted from the step-10 checkpoint

    cfg2, run2, data2 = _cfgs(tmp_path / "fresh")
    clean = train_loop(cfg2, run2, data2, TrainLoopConfig(steps=14))
    np.testing.assert_allclose(resumed["loss"][-1], clean["loss"][-1],
                               rtol=1e-6)


def test_straggler_monitor_flags():
    mon = StragglerMonitor(deadline_factor=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)           # 5x median -> flagged
    assert not mon.record(11, 0.15)
    assert mon.flagged == [10]
    s = mon.summary()
    assert s["median_s"] == pytest.approx(0.1, rel=0.2)
    assert mon.deadline() == pytest.approx(0.2, rel=0.2)
