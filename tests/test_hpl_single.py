"""Single-device blocked LU against scipy-grade references + HPL metrics."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hpl import (generate_system, normalized_residual,
                            solve_from_lu)
from repro.core.hpl_blocked import lu_blocked


@pytest.mark.parametrize("n,b", [(64, 32), (128, 32), (128, 64), (192, 64)])
def test_lu_blocked_reconstructs(n, b):
    a, _, _ = generate_system(n)
    lu = np.asarray(lu_blocked(jnp.asarray(a), b))
    l = np.tril(lu, -1) + np.eye(n, dtype=np.float32)
    u = np.triu(lu)
    np.testing.assert_allclose(l @ u, a, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,b", [(128, 32), (256, 64)])
def test_hpl_end_to_end_residual(n, b):
    a, x_true, b_vec = generate_system(n)
    lu = np.asarray(lu_blocked(jnp.asarray(a), b))
    x = solve_from_lu(lu, b_vec)
    np.testing.assert_allclose(x, x_true, atol=1e-3)
    assert normalized_residual(a, x, b_vec) < 1.0


def test_lookahead_depth_normalization():
    from repro.core.hpl import lookahead_depth
    assert lookahead_depth(False) == 0
    assert lookahead_depth(None) == 0
    assert lookahead_depth(True) == 1
    assert lookahead_depth(3) == 3
    with pytest.raises(ValueError):
        lookahead_depth(-1)


def test_block_size_invariance():
    """The factorization must not depend on the block size."""
    n = 128
    a, _, _ = generate_system(n)
    lu32 = np.asarray(lu_blocked(jnp.asarray(a), 32))
    lu64 = np.asarray(lu_blocked(jnp.asarray(a), 64))
    np.testing.assert_allclose(lu32, lu64, rtol=1e-4, atol=1e-4)
