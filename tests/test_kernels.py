"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle (ref.py), interpret=True (the assignment's validation mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gemm import fit_block

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 8e-2}


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 64, 64, 64),
    (128, 64, 192, 64, 64, 32),
    (256, 128, 128, 128, 128, 128),
    (96, 48, 80, 32, 16, 16),
])
def test_matmul_sweep(rng, dtype, m, k, n, bm, bn, bk):
    a = _rand(rng, (m, k), dtype)
    b = _rand(rng, (k, n), dtype)
    out = ops.matmul(a, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype] * k ** 0.5, rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [-1.0, 0.5])
def test_gemm_update(rng, dtype, alpha):
    m, k, n = 128, 96, 64
    c = _rand(rng, (m, n), dtype)
    a = _rand(rng, (m, k), dtype)
    b = _rand(rng, (k, n), dtype)
    out = ops.gemm_update(c.copy(), a, b, alpha=alpha, bm=64, bn=32, bk=32)
    want = ref.gemm_update(c, a, b, alpha=alpha)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype] * k ** 0.5, rtol=1e-2)


@pytest.mark.parametrize("n,block", [(64, 64), (128, 64), (256, 128), (192, 64)])
def test_transpose_add(rng, n, block):
    a = _rand(rng, (n, n), jnp.float32)
    b = _rand(rng, (n, n), jnp.float32)
    out = ops.transpose_add(a, b, block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.transpose_add(a, b)),
                               atol=1e-6)


@pytest.mark.parametrize("n", [16, 64, 128])
def test_lu_factor_block(rng, n):
    a = rng.standard_normal((n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n  # diagonally dominant (HPL-AI rule)
    a = jnp.asarray(a)
    lu = ops.lu_factor_block(a)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ref.lu_factor_block(a)),
                               rtol=1e-5, atol=1e-5)
    # L @ U must reconstruct A
    l, u = ref.unpack_lu(np.asarray(lu))
    np.testing.assert_allclose(np.asarray(l) @ np.asarray(u), np.asarray(a),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b_cols", [64, 192])
def test_trsm_lower_left(rng, b_cols):
    n = 64
    a = rng.standard_normal((n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n
    lu = ops.lu_factor_block(jnp.asarray(a))
    rhs = _rand(rng, (n, b_cols), jnp.float32)
    out = ops.trsm_lower_left(lu, rhs, bn=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.trsm_lower_left(lu, rhs)),
                               rtol=1e-4, atol=1e-4)
    # residual: L @ X == B
    l, _ = ref.unpack_lu(np.asarray(lu))
    np.testing.assert_allclose(l @ np.asarray(out), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b_rows", [64, 192])
def test_trsm_upper_right(rng, b_rows):
    n = 64
    a = rng.standard_normal((n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n
    lu = ops.lu_factor_block(jnp.asarray(a))
    rhs = _rand(rng, (b_rows, n), jnp.float32)
    out = ops.trsm_upper_right(lu, rhs, bm=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.trsm_upper_right(lu, rhs)),
                               rtol=1e-4, atol=1e-4)
    _, u = ref.unpack_lu(np.asarray(lu))
    np.testing.assert_allclose(np.asarray(out) @ u, np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,H,KV,S,hd,bq,bk", [
    (2, 4, 4, 128, 32, 64, 64),     # MHA
    (1, 8, 2, 256, 64, 128, 64),    # GQA 4:1
    (2, 8, 1, 96, 32, 32, 32),      # MQA
])
def test_flash_attention_sweep(rng, dtype, causal, B, H, KV, S, hd, bq, bk):
    q = _rand(rng, (B, S, H, hd), dtype)
    k = _rand(rng, (B, S, KV, hd), dtype)
    v = _rand(rng, (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=2e-2)


def test_flash_attention_q_offset(rng):
    """Decode-style offset: last-row attention equals full attention row."""
    B, S, H, hd = 1, 128, 4, 32
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    full = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    tail = ops.flash_attention(q[:, -32:], k, v, causal=True, q_offset=S - 32,
                               bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -32:]),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("n", [1 << 10, 3 << 10])
def test_stream_kernels(rng, n):
    a = _rand(rng, (n,), jnp.float32)
    b = _rand(rng, (n,), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.stream_copy(a)),
                               np.asarray(ref.stream_copy(a)))
    np.testing.assert_allclose(np.asarray(ops.stream_scale(a, 3.0)),
                               np.asarray(ref.stream_scale(a, 3.0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.stream_add(a, b)),
                               np.asarray(ref.stream_add(a, b)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.stream_triad(a, b, 3.0)),
                               np.asarray(ref.stream_triad(a, b, 3.0)), atol=1e-5)


def test_fit_block():
    assert fit_block(256, 256) == 256
    assert fit_block(96, 64) == 48
    assert fit_block(100, 64) == 50
    for size in (64, 96, 100, 257):
        for pref in (16, 64, 256):
            b = fit_block(size, pref)
            assert size % b == 0 and b <= max(pref, 1)
