"""Shared test fixtures. NOTE: no XLA_FLAGS here — single-process tests see
one CPU device (the dry-run sets its own 512-device flag in its own
process; distributed tests run in a subprocess via tests/test_dist_wrapper)."""
from __future__ import annotations

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
