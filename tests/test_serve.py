"""Serving path: batched generation, greedy determinism, EOS handling."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.train.serve import generate, make_decode_step, make_prefill_step


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3.2-3b"), layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_generate_greedy_deterministic(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 8)), jnp.int32)
    a = generate(model, params, prompts, max_new_tokens=6)
    b = generate(model, params, prompts, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (3, 14)
    np.testing.assert_array_equal(np.asarray(a[:, :8]), np.asarray(prompts))


def test_generate_matches_stepwise_forward(setup):
    """Cached decode equals repeated full forwards (greedy)."""
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    out = generate(model, params, prompt, max_new_tokens=4)

    seq = prompt
    for _ in range(4):
        logits, _, _ = model.apply(params, {"tokens": seq})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        seq = jnp.concatenate([seq, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))


def test_generate_eos_padding(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    free = generate(model, params, prompts, max_new_tokens=8)
    eos = int(free[0, 9])  # force EOS at the 2nd generated token
    out = generate(model, params, prompts, max_new_tokens=8, eos_id=eos)
    row = np.asarray(out[0, 8:])
    hit = np.where(row == eos)[0]
    assert len(hit) > 0
    np.testing.assert_array_equal(row[hit[0]:], eos)  # padded after EOS


def test_generate_eos_stops_decoding_early(setup, monkeypatch):
    """Once every row has hit EOS the loop must stop issuing decode steps
    (the output keeps its fixed (B, S0+max_new) shape via EOS padding)."""
    import repro.train.serve as serve_mod
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    calls = []
    orig = serve_mod.make_decode_step

    def counting(model, mesh=None, **kw):
        step = orig(model, mesh, **kw)

        def wrapped(*a, **k):
            calls.append(1)
            return step(*a, **k)
        return wrapped

    monkeypatch.setattr(serve_mod, "make_decode_step", counting)
    free = generate(model, params, prompt, max_new_tokens=8)
    assert len(calls) == 7  # baseline: max_new - 1 decode steps
    eos = int(free[0, 8])  # greedy repeats on this tiny model: hit = 1st tok

    calls.clear()
    out = generate(model, params, prompt, max_new_tokens=8, eos_id=eos)
    assert out.shape == (1, 16)  # shape contract unchanged by the early stop
    gen = np.asarray(free[0, 8:])
    k = int(np.flatnonzero(gen == eos)[0])  # decode steps until the EOS hit
    assert len(calls) == k < 7
    np.testing.assert_array_equal(np.asarray(out[0, 8:8 + k + 1]),
                                  gen[:k + 1])
    np.testing.assert_array_equal(np.asarray(out[0, 8 + k + 1:]), eos)


def test_prefill_then_decode_shapes(setup):
    cfg, model, params = setup
    B, S, MAX = 2, 8, 16
    cache = model.init_cache(B, MAX, jnp.float32)
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    logits, cache = prefill(params, batch, cache)
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert int(cache["pos"]) == S
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache = decode(params, tok, cache, {})
    assert logits2.shape == (B, 1, cfg.padded_vocab())
    assert int(cache["pos"]) == S + 1
