"""Fused local step of the ring reduce-scatter / all-gather allreduce.

The bandwidth-optimal ring allreduce (the engine's ``rs_ag`` schedule) moves
one 1/n-sized chunk per hop: the reduce-scatter half *adds* the received
chunk into the local accumulator, the all-gather half *copies* it into the
output slot. On TPU the add is the fusion opportunity — receive buffer and
accumulator stream through VMEM once, instead of a ppermute output
materializing in HBM and a separate add reading it back. ``ring_add_step``
is that fused add as a Pallas kernel (interpret mode off-TPU, same
semantics); ``fused_chunk_add`` is the shape-tolerant wrapper the engine
calls per hop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _add_kernel(acc_ref, recv_ref, o_ref):
    o_ref[...] = (acc_ref[...].astype(jnp.float32)
                  + recv_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def ring_add_step(acc: jnp.ndarray, recv: jnp.ndarray, *, block_rows: int = 512,
                  interpret: bool = False) -> jnp.ndarray:
    """acc + recv over (rows, LANES)-shaped chunks, one VMEM pass."""
    assert acc.shape == recv.shape and acc.ndim == 2, (acc.shape, recv.shape)
    rows = acc.shape[0]
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _add_kernel,
        grid=(rows // br,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        interpret=interpret,
    )(acc, recv)


def fused_chunk_add(acc: jnp.ndarray, recv: jnp.ndarray,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused accumulate for one ring hop. Falls back to a plain jnp add when
    the chunk cannot be laid out as (rows, 128) lanes (tiny or ragged chunks
    in tests); the engine's schedule semantics do not change, only fusion."""
    flat = acc.reshape(-1)
    if flat.size % LANES or flat.size == 0:
        return acc + recv
    out = ring_add_step(flat.reshape(-1, LANES),
                        recv.reshape(-1, LANES), interpret=interpret)
    return out.reshape(acc.shape)
