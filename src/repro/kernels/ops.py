"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies then execute as the Python/jnp semantics of the same BlockSpec
pipeline, which is the validation mode the assignment prescribes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import attention as _attn
from repro.kernels import gemm as _gemm
from repro.kernels import lu as _lu
from repro.kernels import stream as _stream
from repro.kernels import transpose as _transpose


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(flag):
    return (not on_tpu()) if flag is None else flag


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def matmul(a, b, *, bm=256, bn=256, bk=256, out_dtype=None, interpret=None):
    return _gemm.matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                        interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("alpha", "bm", "bn", "bk", "interpret"),
         donate_argnums=(0,))
def gemm_update(c, a, b, *, alpha=-1.0, bm=256, bn=256, bk=256, interpret=None):
    return _gemm.gemm_update(c, a, b, alpha=alpha, bm=bm, bn=bn, bk=bk,
                             interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("block", "interpret"))
def transpose_add(a, b, *, block=256, interpret=None):
    return _transpose.transpose_add(a, b, block=block,
                                    interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def lu_factor_block(a, *, interpret=None):
    return _lu.lu_factor_block(a, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("bn", "interpret"))
def trsm_lower_left(lu, b, *, bn=256, interpret=None):
    return _lu.trsm_lower_left(lu, b, bn=bn, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("bm", "interpret"))
def trsm_upper_right(lu, b, *, bm=256, interpret=None):
    return _lu.trsm_upper_right(lu, b, bm=bm, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, bq=512, bk=512,
                    interpret=None):
    return _attn.flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 bq=bq, bk=bk, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_copy(a, *, interpret=None):
    return _stream.stream_copy(a, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("alpha", "interpret"))
def stream_scale(c, alpha, *, interpret=None):
    return _stream.stream_scale(c, alpha, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_add(a, b, *, interpret=None):
    return _stream.stream_add(a, b, interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("alpha", "interpret"))
def stream_triad(b, c, alpha, *, interpret=None):
    return _stream.stream_triad(b, c, alpha, interpret=_interp(interpret))
