"""Blocked MXU matmul kernels (Pallas TPU).

The GEMM is the paper's HPL update-phase workhorse (paper §2.3: "for large
matrices the performance of the implementation is limited by the aggregated
performance of the matrix multiplication kernels"). Block sizes default to
MXU-aligned 256x256x256 bf16 tiles: A-tile (256x256x2 B) + B-tile + fp32
accumulator (256x256x4 B) = 512 KiB working set, comfortably inside the
16 MiB VMEM budget with double buffering.

The paper's two-level blocking (LOCAL_MEM_BLOCK / REGISTER_BLOCK, Table 4)
maps to: level 1 = the BlockSpec HBM->VMEM tile; level 2 = the MXU's native
128x128 systolic tile, which jnp.dot inside the kernel lowers onto.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def fit_block(size: int, pref: int) -> int:
    """Largest divisor of ``size`` that is <= pref (block shapes must tile)."""
    b = min(pref, size)
    while size % b:
        b -= 1
    return b


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 256, out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    """C = A @ B with fp32 accumulation. Shapes must tile evenly."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = fit_block(M, bm), fit_block(N, bn), fit_block(K, bk)
    out_dtype = out_dtype or a.dtype
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _gemm_update_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int,
                        alpha: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += alpha * jnp.dot(a_ref[...], b_ref[...],
                                    preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_update(c: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, *,
                alpha: float = -1.0, bm: int = 256, bn: int = 256,
                bk: int = 256, interpret: bool = False) -> jnp.ndarray:
    """C <- C + alpha * A @ B (HPL trailing update with alpha = -1).

    The output buffer aliases C (in-place on TPU) — the HPL trailing matrix
    is updated without a second HBM allocation.
    """
    M, K = a.shape
    _, N = b.shape
    assert c.shape == (M, N)
    bm, bn, bk = fit_block(M, bm), fit_block(N, bn), fit_block(K, bk)
    grid = (M // bm, N // bn, K // bk)
    # aliasing is the TPU in-place path; interpret mode implements donation
    # with a defensive whole-buffer copy per grid step (measured, §Perf C3)
    alias = {} if interpret else {0: 0}
    return pl.pallas_call(
        partial(_gemm_update_kernel, nk=grid[2], alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        input_output_aliases=alias,
        interpret=interpret,
    )(c, a, b)
