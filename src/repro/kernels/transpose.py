"""Tiled transpose-add kernel: C = B + A^T (the PTRANS inner operation).

The paper's PTRANS kernel (§2.2) streams a block of A into local memory,
reads it back transposed, adds the matching block of B, and writes C. The
TPU version does exactly that per (bi, bj) grid cell: the BlockSpec fetches
A's (j, i) tile and B's (i, j) tile into VMEM; the in-VMEM transpose is a
register-level permutation on the VPU.

Paper Eq. 6 balance: each output tile moves 3 tiles of HBM traffic (read A^T
tile, read B tile, write C tile) — the kernel is HBM-bandwidth-bound, which
is what the PTRANS roofline records.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = (b_ref[...].astype(jnp.float32)
                  + a_ref[...].astype(jnp.float32).T).astype(o_ref.dtype)


def transpose_add(a: jnp.ndarray, b: jnp.ndarray, *, block: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """C = B + A^T for square-tileable matrices. a: (M, N), b/C: (N, M)."""
    from repro.kernels.gemm import fit_block
    M, N = a.shape
    assert b.shape == (N, M)
    bs = fit_block(M, fit_block(N, block))
    while M % bs or N % bs:
        bs -= 1
    grid = (N // bs, M // bs)  # output tile (i, j) of C
    return pl.pallas_call(
        _transpose_add_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j: (j, i)),  # A tile transposed
            pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), b.dtype),
        interpret=interpret,
    )(a, b)
