"""Pure-jnp oracles for every Pallas kernel (the CPU reference the paper
validates against on the host side)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a, b, out_dtype=None):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        out_dtype or a.dtype)


def gemm_update(c, a, b, alpha=-1.0):
    return (c.astype(jnp.float32)
            + alpha * jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
            ).astype(c.dtype)


def transpose_add(a, b):
    return (b.astype(jnp.float32) + a.astype(jnp.float32).T).astype(b.dtype)


def lu_factor_block(a):
    """Packed L\\U (unit lower diag), no pivoting."""
    a = a.astype(jnp.float32)
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, a):
        pivot = a[k, k]
        col = jnp.where(idx > k, a[:, k] / pivot, 0.0)
        urow = jnp.where(idx > k, a[k, :], 0.0)
        a = a - jnp.outer(col, urow)
        a = a.at[:, k].set(jnp.where(idx > k, col, a[:, k]))
        return a

    return jax.lax.fori_loop(0, n, body, a)


def unpack_lu(lu):
    l = jnp.tril(lu, -1) + jnp.eye(lu.shape[0], dtype=lu.dtype)
    u = jnp.triu(lu)
    return l, u


def trsm_lower_left(lu, b):
    l, _ = unpack_lu(lu.astype(jnp.float32))
    return jax.scipy.linalg.solve_triangular(
        l, b.astype(jnp.float32), lower=True, unit_diagonal=True).astype(b.dtype)


def trsm_upper_right(lu, b):
    _, u = unpack_lu(lu.astype(jnp.float32))
    # X U = B  <=>  U^T X^T = B^T
    xt = jax.scipy.linalg.solve_triangular(
        u.T, b.astype(jnp.float32).T, lower=True)
    return xt.T.astype(b.dtype)


def attention(q, k, v, *, causal=True, q_offset=0):
    """Dense softmax attention with GQA. q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = jnp.arange(Skv)[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def stream_copy(a):
    return a


def stream_scale(c, alpha):
    return (alpha * c.astype(jnp.float32)).astype(c.dtype)


def stream_add(a, b):
    return (a.astype(jnp.float32) + b.astype(jnp.float32)).astype(a.dtype)


def stream_triad(b, c, alpha):
    return (b.astype(jnp.float32) + alpha * c.astype(jnp.float32)).astype(b.dtype)
