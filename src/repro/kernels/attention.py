"""Flash attention (prefill/train) Pallas kernel with GQA head mapping.

Blockwise online-softmax attention: grid (batch*heads, q_blocks, kv_blocks),
carries (acc, m, l) live in VMEM scratch across the kv_block dimension.
Causal blocks strictly above the diagonal are skipped with ``pl.when`` — on
TPU the grid still visits them but issues no MXU work, halving FLOPs for the
causal case. Default blocks 512(q) x 512(kv) x 128(hd): q-tile + k-tile +
v-tile + fp32 acc = 4 x 512 x 128 x ~4 B ~= 1.3 MiB << VMEM.

The KV head for a q head h is h // (H/KV) — computed in the BlockSpec index
map, so GQA costs no extra copies (the paper's NUM_REPLICATIONS analogue is
the grid's batch*heads dimension).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  nk: int, bq: int, bk: int, causal: bool, scale: float,
                  q_offset: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: the whole block is masked iff its first kv pos exceeds the
    # last q pos of this q block.
    needed = True
    if causal:
        needed = (j * bk) <= (q_offset + i * bq + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_offset: int = 0, bq: int = 512,
                    bk: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    scale = hd ** -0.5

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    def kv_row(bh):  # q row index -> kv row index
        return (bh // H) * KV + (bh % H) // G

    grid = (B * H, Sq // bq, Skv // bk)
    out = pl.pallas_call(
        partial(_flash_kernel, nk=grid[2], bq=bq, bk=bk, causal=causal,
                scale=scale, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j: (kv_row(bh), j, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j: (kv_row(bh), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
