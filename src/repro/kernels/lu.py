"""Blocked-LU building blocks (Pallas TPU): the paper's four HPL kernels.

Paper §2.3/Fig. 4 decomposes each iteration into: LU (diagonal block
factorization), Top (U panel via lower-triangular solve), Left (L panel via
upper-triangular solve, transposed on the fly), and the inner matrix
multiplications (see kernels/gemm.py). No pivoting (HPL-AI ruleset,
diagonally-dominant A).

The diagonal factorization and the triangular solves are sequential over the
block dimension — that is inherent to LU — but they touch O(b^2) data while
the trailing GEMMs touch O(n^2) per iteration, so these kernels sit off the
critical roofline for large n (paper Fig. 13: performance converges to the
matmul bound).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# diagonal block: in-place LU (Doolittle, unit lower diagonal)
# ---------------------------------------------------------------------------


def _lu_block_kernel(a_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, a):
        pivot = lax.dynamic_index_in_dim(lax.dynamic_index_in_dim(a, k, 0, False),
                                         k, 0, False)
        col = jnp.where(idx > k, a[:, k] / pivot, 0.0)  # L column below diag
        row = lax.dynamic_index_in_dim(a, k, 0, False)  # a[k, :]
        urow = jnp.where(idx > k, row, 0.0)             # U row right of diag
        a = a - jnp.outer(col, urow)
        a = a.at[:, k].set(jnp.where(idx > k, col, a[:, k]))
        return a

    a = lax.fori_loop(0, n, body, a)
    o_ref[...] = a.astype(o_ref.dtype)


def lu_factor_block(a: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """LU-factorize one (b, b) block, returning L\\U packed (unit L diag)."""
    n = a.shape[0]
    assert a.shape == (n, n)
    return pl.pallas_call(
        _lu_block_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=interpret,
    )(a)


# ---------------------------------------------------------------------------
# panel solves
# ---------------------------------------------------------------------------


def _trsm_lower_kernel(lu_ref, b_ref, o_ref):
    """Solve L X = B where L is unit-lower from packed LU. One grid cell per
    panel block (the paper's Top kernel: U_kj = L_kk^{-1} A_kj)."""
    l = lu_ref[...].astype(jnp.float32)
    x = b_ref[...].astype(jnp.float32)
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(i, x):
        li = lax.dynamic_index_in_dim(l, i, 0, False)  # L[i, :]
        li = jnp.where(idx < i, li, 0.0)
        xi = lax.dynamic_index_in_dim(x, i, 0, False) - li @ x
        return lax.dynamic_update_index_in_dim(x, xi, i, 0)

    x = lax.fori_loop(0, n, body, x)
    o_ref[...] = x.astype(o_ref.dtype)


def trsm_lower_left(lu: jnp.ndarray, b: jnp.ndarray, *, bn: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """X = L^{-1} B for packed-LU ``lu`` (b, b) and panel ``b`` (b, N)."""
    from repro.kernels.gemm import fit_block
    n = lu.shape[0]
    N = b.shape[1]
    bn = fit_block(N, bn)
    return pl.pallas_call(
        _trsm_lower_kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret,
    )(lu, b)


def _trsm_upper_kernel(lu_ref, b_ref, o_ref):
    """Solve X U = B for U upper from packed LU (the paper's Left kernel:
    L_ik = A_ik U_kk^{-1})."""
    u = lu_ref[...].astype(jnp.float32)
    x = b_ref[...].astype(jnp.float32)  # (bm, n)
    n = u.shape[0]
    idx = jnp.arange(n)

    def body(j, x):
        uj = lax.dynamic_slice_in_dim(u, j, 1, 1)[:, 0]  # U[:, j]
        ujj = lax.dynamic_index_in_dim(uj, j, 0, False)
        uj = jnp.where(idx < j, uj, 0.0)
        xj = (lax.dynamic_slice_in_dim(x, j, 1, 1)[:, 0] - x @ uj) / ujj
        return lax.dynamic_update_slice_in_dim(x, xj[:, None], j, 1)

    x = lax.fori_loop(0, n, body, x)
    o_ref[...] = x.astype(o_ref.dtype)


def trsm_upper_right(lu: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """X = B U^{-1} for packed-LU ``lu`` (b, b) and panel ``b`` (M, b)."""
    from repro.kernels.gemm import fit_block
    n = lu.shape[0]
    M = b.shape[0]
    bm = fit_block(M, bm)
    return pl.pallas_call(
        _trsm_upper_kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret,
    )(lu, b)
