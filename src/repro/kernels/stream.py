"""STREAM kernels (copy / scale / add / triad) — HBM bandwidth probes.

Arrays are processed as (rows, 128) lanes; block rows sized so each tile is
a few MiB of VMEM (default 2048 x 128 x 4 B = 1 MiB per operand). These are
the paper's STREAM benchmark kernels, unchanged semantics (§3.4): the metric
is bytes moved / time, normalized per memory bank in the paper and per HBM
stack here.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _as2d(x):
    assert x.size % LANES == 0, x.shape
    return x.reshape(-1, LANES)


def _copy_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...]


def _scale_kernel(c_ref, o_ref, *, alpha):
    o_ref[...] = (alpha * c_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = (a_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _triad_kernel(b_ref, c_ref, o_ref, *, alpha):
    o_ref[...] = (b_ref[...].astype(jnp.float32)
                  + alpha * c_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _run(kernel, args, out_dtype, *, block_rows=2048, interpret=False):
    x0 = _as2d(args[0])
    rows = x0.shape[0]
    br = min(block_rows, rows)
    assert rows % br == 0
    grid = (rows // br,)
    spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(args),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x0.shape, out_dtype),
        interpret=interpret,
    )(*[_as2d(a) for a in args])
    return out.reshape(args[0].shape)


def stream_copy(a, *, interpret=False):
    return _run(_copy_kernel, (a,), a.dtype, interpret=interpret)


def stream_scale(c, alpha: float, *, interpret=False):
    return _run(partial(_scale_kernel, alpha=alpha), (c,), c.dtype,
                interpret=interpret)


def stream_add(a, b, *, interpret=False):
    return _run(_add_kernel, (a, b), a.dtype, interpret=interpret)


def stream_triad(b, c, alpha: float, *, interpret=False):
    return _run(partial(_triad_kernel, alpha=alpha), (b, c), b.dtype,
                interpret=interpret)
