"""Straggler detection — the multi-node analogue of the paper's
"slowest execution time among all FPGAs is reported" barrier discipline.

On a real pod every worker executes the same jitted step, so a straggler
shows up as a slow *global* step (XLA collectives are barriers). The monitor
tracks a running median of step wall-times and flags steps slower than
``deadline_factor`` x median; the loop reacts per policy ('warn' — log and
continue; 'checkpoint' — force an early checkpoint so a restart loses
nothing; real deployments add 'evict' via the cluster scheduler).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    policy: str = "warn"  # 'warn' | 'checkpoint'
    window: int = 128
    _times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(duration)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 8:  # need a baseline first
            return False
        med = self.median()
        if duration > self.deadline_factor * med:
            self.flagged.append(step)
            return True
        return False

    def median(self) -> float:
        s = sorted(self._times)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def deadline(self) -> Optional[float]:
        if len(self._times) < 8:
            return None
        return self.deadline_factor * self.median()

    def summary(self) -> dict:
        if not self._times:
            return {"steps": 0}
        return {
            "steps": len(self._times),
            "median_s": self.median(),
            "max_s": max(self._times),
            "flagged": list(self.flagged),
        }


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self.t0
        return False
