"""Straggler detection — the multi-node analogue of the paper's
"slowest execution time among all FPGAs is reported" barrier discipline.

On a real pod every worker executes the same jitted step, so a straggler
shows up as a slow *global* step (XLA collectives are barriers). The monitor
tracks a running median of step wall-times and flags steps slower than
``deadline_factor`` x median; the loop reacts per policy ('warn' — log and
continue; 'checkpoint' — force an early checkpoint so a restart loses
nothing; 'retune' — hand the flag to a
:class:`repro.comm.retune.RetuneController`, which re-resolves the hot
collective schedules on the degraded link numbers; real deployments add
'evict' via the cluster scheduler).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

POLICIES = ("warn", "checkpoint", "retune")

_MIN_BASELINE = 8  # samples before the median is trusted


@dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    policy: str = "warn"  # one of POLICIES
    window: int = 128
    max_flagged: int = 256  # bounds the flag log over unbounded runs
    _times: Deque[float] = field(default_factory=deque, repr=False)
    flagged: Deque[int] = field(default_factory=deque)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown straggler policy {self.policy!r}; "
                             f"policies are {POLICIES}")
        self._times = deque(self._times, maxlen=self.window)
        self.flagged = deque(self.flagged, maxlen=self.max_flagged)

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(duration)
        if len(self._times) < _MIN_BASELINE:  # need a baseline first
            return False
        med = self.median()
        if duration > self.deadline_factor * med:
            self.flagged.append(step)
            return True
        return False

    def median(self) -> float:
        s = sorted(self._times)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def deadline(self) -> Optional[float]:
        if len(self._times) < _MIN_BASELINE:
            return None
        return self.deadline_factor * self.median()

    def summary(self) -> dict:
        if not self._times:
            return {"steps": 0}
        return {
            "steps": len(self._times),
            "median_s": self.median(),
            "max_s": max(self._times),
            "flagged": list(self.flagged),
        }


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self.t0
        return False
