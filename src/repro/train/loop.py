"""Fault-tolerant training loop.

One loop covers the quickstart, the 100M end-to-end example, and the
fault-tolerance tests:

* auto-resume: on start, if the checkpoint dir holds a valid step, restore
  it (elastically — the current mesh may differ from the saving mesh);
* periodic atomic checkpoints (+ a forced one when the straggler policy is
  'checkpoint' and a step blows its deadline);
* crash injection for tests: ``fail_at_step`` raises mid-run *after* the
  optimizer update but *before* that step's checkpoint, proving restart
  loses at most ``checkpoint_every`` steps;
* deterministic data: batches are a pure function of (seed, step), so a
  resumed run consumes exactly the batches the crashed run would have;
* elastic rank-loss recovery: when the fault schedule declares a device
  lost (``FaultInjector.fail_rank``), the loop raises
  :class:`~repro.comm.faults.RankLostError` and
  :func:`train_loop_elastic` rebuilds the mesh on the largest survivor
  count dividing the global batch, restores the latest checkpoint
  *resharded* onto it (``checkpoint.restore(..., reshard_to=mesh)``),
  and resumes — losing at most ``checkpoint_every`` steps of progress
  and zero data (batches are step-indexed).
"""
from __future__ import annotations

import logging
import shutil
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro import sharding as sh
from repro.comm.faults import RankLostError
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models.model import Model, build_model
from repro.train.step import (TrainState, init_train_state, make_train_step,
                              make_whole_model_train_step_explicit,
                              shard_state, state_specs)
from repro.train.straggler import StepTimer, StragglerMonitor

log = logging.getLogger("repro.train")


@dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    fail_at_step: Optional[int] = None  # crash injection (tests)
    zero1: bool = True
    # "gspmd" (production jit path) | "explicit_tp" | "explicit_sp": the
    # explicit modes run the whole forward+backward inside one shard_map
    # with engine-routed collectives (make_whole_model_train_step_explicit)
    step_mode: str = "gspmd"
    # straggler reaction (repro.train.straggler.POLICIES): 'warn' |
    # 'checkpoint' (force an early save) | 'retune' (hand the flag to the
    # RetuneController below)
    straggler_policy: str = "checkpoint"
    # scripted degraded-link timeline (repro.comm.faults.FaultSchedule):
    # applied at each step's start, its host delays land inside the timed
    # region so the StragglerMonitor sees them
    fault_schedule: Optional[object] = None
    # adaptive retuning (repro.comm.retune.RetuneController): observes every
    # step duration; on a retune event under an explicit step_mode the
    # jitted step is rebuilt so the next trace picks up the swapped
    # schedules (the engine itself is never rebuilt)
    retune: Optional[object] = None


class InjectedFailure(RuntimeError):
    pass


def train_loop(model_cfg: ModelConfig, run_cfg: RunConfig, data_cfg: DataConfig,
               loop_cfg: TrainLoopConfig, *, mesh=None,
               key=None) -> Dict[str, List[float]]:
    """Returns metric history. Resumes from run_cfg.checkpoint_dir if set."""
    model = build_model(model_cfg)
    dataset = SyntheticLMDataset(data_cfg)
    key = key if key is not None else jax.random.key(run_cfg.seed)

    state = init_train_state(model, key)
    start_step = 0

    manager = None
    if run_cfg.checkpoint_dir:
        manager = ckpt.CheckpointManager(
            run_cfg.checkpoint_dir, every=run_cfg.checkpoint_every,
            keep=run_cfg.keep_checkpoints)
        if manager.has_checkpoint:
            if mesh is not None and loop_cfg.step_mode != "gspmd":
                # explicit whole-model layout: derive the shardings from
                # whole_model_param_specs on the *current* mesh — the
                # elastic path when it differs from the saving mesh
                start_step, trees, extra = manager.restore_latest(
                    {"state": state}, reshard_to=mesh)
            else:
                shardings = None
                if mesh is not None:
                    rules = sh.rules_for(mesh)
                    specs = state_specs(state, rules, mesh,
                                        zero1=loop_cfg.zero1)
                    shardings = {"state": jax.tree.map(
                        lambda s: jax.NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))}
                start_step, trees, extra = manager.restore_latest(
                    {"state": state}, shardings)
            state = trees["state"]
            log.info("resumed from checkpoint step %d", start_step)

    explicit = loop_cfg.step_mode != "gspmd"
    if explicit:
        # whole-model explicit path: the step's own shard_map in_specs place
        # the state (experts sharded, rest replicated) — no GSPMD shard_state
        if loop_cfg.step_mode not in ("explicit_tp", "explicit_sp"):
            raise ValueError(f"unknown step_mode {loop_cfg.step_mode!r}; "
                             "use 'gspmd', 'explicit_tp', or 'explicit_sp'")
        if mesh is None:
            raise ValueError("explicit step_mode requires a mesh")
        step_fn = make_whole_model_train_step_explicit(
            model, run_cfg, mesh, attn_mode=loop_cfg.step_mode[len("explicit_"):],
            total_steps=loop_cfg.steps)
    else:
        if mesh is not None and start_step == 0:
            state = shard_state(state, mesh, zero1=loop_cfg.zero1)
        step_fn = make_train_step(model, run_cfg, mesh or jax.sharding.Mesh(
            np.array(jax.devices()[:1]), ("x",)), total_steps=loop_cfg.steps)

    monitor = StragglerMonitor(deadline_factor=run_cfg.step_deadline_factor,
                               policy=loop_cfg.straggler_policy)
    retuner = loop_cfg.retune
    schedule = loop_cfg.fault_schedule
    history: Dict[str, List[float]] = {"loss": [], "step_time": [], "step": []}

    for step in range(start_step, loop_cfg.steps):
        if schedule is not None:
            schedule.apply(step)
            lost = schedule.injector.lost_ranks
            if lost:
                # the mesh as built no longer exists: surface the loss with
                # the partial history attached so train_loop_elastic can
                # rebuild on the survivors and resume from the checkpoint
                err = RankLostError(lost, step)
                err.history = history
                raise err
        batch_np = dataset.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if mesh is not None and not explicit:
            rules = sh.rules_for(mesh)
            bspec = sh.batch_specs(batch, rules, mesh)
            batch = {k: jax.device_put(v, jax.NamedSharding(mesh, bspec[k]))
                     for k, v in batch.items()}

        with StepTimer() as t:
            if schedule is not None:
                # inside the timed region: the monitor and the retune
                # controller both see the injected degradation
                schedule.injector.sleep("train.step")
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
        straggled = monitor.record(step, t.duration)

        if retuner is not None:
            if straggled and monitor.policy == "retune":
                event = retuner.on_straggler(step)
            else:
                event = retuner.observe(step, t.duration)
            if event is not None and explicit:
                # resolutions swapped — rebuild the (cheap) jitted step so
                # the next trace picks up the new schedules
                step_fn = make_whole_model_train_step_explicit(
                    model, run_cfg, mesh,
                    attn_mode=loop_cfg.step_mode[len("explicit_"):],
                    total_steps=loop_cfg.steps)

        history["loss"].append(loss)
        history["step_time"].append(t.duration)
        history["step"].append(step)
        if step % loop_cfg.log_every == 0:
            log.info("step %d loss %.4f (%.3fs)", step, loss, t.duration)

        next_step = step + 1
        if loop_cfg.fail_at_step is not None and next_step == loop_cfg.fail_at_step:
            raise InjectedFailure(f"injected failure before step {next_step}")

        if manager is not None:
            if straggled and monitor.policy == "checkpoint":
                manager.save(next_step, {"state": state},
                             extra={"loss": loss, "forced": True}, force=True)
            else:
                manager.maybe_save(next_step, {"state": state},
                                   extra={"loss": loss})

    if manager is not None:
        manager.save(loop_cfg.steps, {"state": state}, extra={"final": True},
                     force=True)
    history["straggler"] = monitor.summary()  # type: ignore[assignment]
    if retuner is not None:
        history["retune_events"] = retuner.events  # type: ignore[assignment]
    return history


def largest_divisible(survivors: int, global_batch: int) -> int:
    """The largest rank count <= ``survivors`` dividing ``global_batch`` —
    the biggest mesh the fixed batch reshards onto evenly."""
    if survivors < 1:
        raise ValueError(f"no survivors ({survivors})")
    for n in range(survivors, 1, -1):
        if global_batch % n == 0:
            return n
    return 1


def train_loop_elastic(model_cfg: ModelConfig, run_cfg: RunConfig,
                       data_cfg: DataConfig, loop_cfg: TrainLoopConfig, *,
                       mesh, key=None, snapshot_dir: Optional[str] = None
                       ) -> Tuple[Dict[str, List[float]], Optional[Dict]]:
    """:func:`train_loop` that survives a scripted rank loss.

    Runs the loop on ``mesh``; when the fault schedule fires ``fail_rank``
    and :class:`~repro.comm.faults.RankLostError` surfaces, it

    1. rebuilds the mesh on the **largest survivor count dividing the
       global batch** (:func:`largest_divisible` — the batch layout, not
       the hardware, caps elasticity),
    2. optionally snapshots the checkpoint directory to ``snapshot_dir``
       *before* resuming (so a control rerun can restore the exact
       checkpoint the recovery used),
    3. clears the injector's lost ranks (the one-shot schedule will not
       re-fire) and re-enters :func:`train_loop` on the survivor mesh —
       auto-resume restores the latest checkpoint resharded onto it via
       ``checkpoint.restore(..., reshard_to=mesh)``.

    Returns ``(history, recovery)``: the merged metric history (pre-loss
    steps + resumed steps) and a recovery record (``None`` when no rank
    was lost) with the lost ranks, fail/resume steps, survivor mesh size,
    and recovery wall-clock seconds.
    """
    try:
        return train_loop(model_cfg, run_cfg, data_cfg, loop_cfg,
                          mesh=mesh, key=key), None
    except RankLostError as e:
        t0 = time.perf_counter()
        if not run_cfg.checkpoint_dir:
            raise RuntimeError(
                "elastic recovery needs run_cfg.checkpoint_dir") from e
        devices = list(np.asarray(mesh.devices).flat)
        survivors = [d for i, d in enumerate(devices) if i not in e.ranks]
        if not survivors:
            raise RuntimeError("every rank lost; nothing to resume on") from e
        n = largest_divisible(len(survivors), data_cfg.global_batch)
        from repro.compat import make_mesh
        new_mesh = make_mesh((n,), tuple(mesh.axis_names),
                             devices=np.array(survivors[:n]))
        log.warning("rank(s) %s lost at step %d; resuming on %d survivors",
                    e.ranks, e.step, n)
        if snapshot_dir is not None:
            shutil.copytree(run_cfg.checkpoint_dir, snapshot_dir,
                            dirs_exist_ok=True)
        schedule = loop_cfg.fault_schedule
        if schedule is not None:
            schedule.injector.restore_ranks()
        resumed = train_loop(model_cfg, run_cfg, data_cfg, loop_cfg,
                             mesh=new_mesh, key=key)
        recovery = {
            "lost_ranks": list(e.ranks),
            "fail_step": e.step,
            "resume_step": int(resumed["step"][0]) if resumed["step"]
            else e.step,
            "old_size": len(devices),
            "new_size": n,
            "recovery_s": time.perf_counter() - t0,
        }
        partial = getattr(e, "history", None) or {}
        merged: Dict[str, List[float]] = dict(resumed)
        for k in ("loss", "step_time", "step"):
            pre = list(partial.get(k, ()))
            keep = [v for s, v in zip(partial.get("step", ()), pre)
                    if s < recovery["resume_step"]] if k != "step" else \
                   [s for s in partial.get("step", ())
                    if s < recovery["resume_step"]]
            merged[k] = keep + list(resumed.get(k, ()))
        return merged, recovery
