"""Train-step factories.

Two step builders mirror the paper's two ``ExecutionImplementation``s
(Fig. 1), selected by ``RunConfig.comm_type`` exactly like the paper selects
by bitstream name:

* :func:`make_train_step` — the production GSPMD path: one ``jax.jit`` with
  in/out shardings; XLA inserts and schedules all collectives (the
  "native/ICI" path). Supports microbatching (gradient accumulation under
  ``lax.scan``), remat policies, and ZeRO-1 optimizer-state sharding.

* :func:`make_dp_train_step_explicit` — the paper-faithful explicit path:
  the whole step runs inside ``shard_map`` over the data axes with
  *hand-written* gradient reduction from :mod:`repro.comm.collectives`
  (``native`` / ``chain`` ring / ``staged`` host-staged), optionally int8-
  compressed with error feedback. This is the circuit-switched 'network
  kernel' schedule applied to LM training, and is what benchmarks compare.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.comm import compression
from repro.comm.callsites import DP_GRADS
from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType, comm_type
from repro.compat import shard_map
from repro.configs.base import ModelConfig, RunConfig
from repro.models import moe as MOE
from repro.models.model import Model, next_token_loss
from repro.models.parallel import make_attn_impl
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, make_lr_schedule)


@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    params: Dict
    opt: Dict
    step: jnp.ndarray
    error: Optional[Dict] = None  # compression error-feedback tree

    def tree_flatten(self):
        return (self.params, self.opt, self.step, self.error), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(model: Model, key, *, compression_on: bool = False) -> TrainState:
    params = model.init(key)
    opt = adamw_init(params)
    err = compression.init_error_tree(params) if compression_on else None
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32),
                      error=err)


def state_specs(state: TrainState, rules: sh.MeshRules, mesh: Mesh,
                *, zero1: bool = True) -> TrainState:
    """PartitionSpec pytree matching a TrainState."""
    pspec = sh.param_specs(state.params, rules, mesh)
    ospec = {
        "mu": sh.opt_state_specs(state.params, rules, mesh, zero1=zero1),
        "nu": sh.opt_state_specs(state.params, rules, mesh, zero1=zero1),
        "count": P(),
    }
    espec = None
    if state.error is not None:
        espec = sh.param_specs(state.error, rules, mesh)
    return TrainState(params=pspec, opt=ospec, step=P(), error=espec)


# ---------------------------------------------------------------------------
# production GSPMD step
# ---------------------------------------------------------------------------


def make_train_step_fn(model: Model, run_cfg: RunConfig, mesh: Mesh,
                       *, adamw: Optional[AdamWConfig] = None,
                       total_steps: int = 10_000, fsdp: bool = False) -> Callable:
    """Un-jitted (state, batch) -> (state, metrics); caller picks jit options
    (the dry-run passes explicit in/out shardings and donation)."""
    adamw = adamw or AdamWConfig(lr=run_cfg.learning_rate,
                                 weight_decay=run_cfg.weight_decay,
                                 max_grad_norm=run_cfg.max_grad_norm)
    schedule = make_lr_schedule(adamw.lr, run_cfg.warmup_steps, total_steps)
    rules = sh.rules_for(mesh, fsdp=fsdp)
    shard = sh.make_shard_fn(mesh, rules)
    nmicro = max(run_cfg.microbatches, 1)

    def loss_fn(params, batch):
        logits, _, _ = model.apply(params, batch, shard=shard,
                                   remat=run_cfg.remat)
        return next_token_loss(logits, batch["tokens"])

    grad_fn = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        if nmicro == 1:
            return grad_fn(params, batch)
        # gradient accumulation: scan over microbatches (batch-major split)
        def resplit(x):
            b = x.shape[0]
            assert b % nmicro == 0, (b, nmicro)
            return x.reshape((nmicro, b // nmicro) + x.shape[1:])
        micro = {k: resplit(v) for k, v in batch.items()}

        def body(acc, mb):
            loss, g = grad_fn(params, mb)
            acc_loss, acc_g = acc
            acc_g = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / nmicro, acc_g, g)
            return (acc_loss + loss / nmicro, acc_g), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = lax.scan(body, (jnp.zeros((), jnp.float32), zero),
                                    micro)
        return loss, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, adamw.max_grad_norm)
        lr = schedule(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           adamw, lr)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, error=state.error)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_train_step(model: Model, run_cfg: RunConfig, mesh: Mesh,
                    *, adamw: Optional[AdamWConfig] = None,
                    total_steps: int = 10_000,
                    donate: bool = True, fsdp: bool = False) -> Callable:
    """jit'd (state, batch) -> (state, metrics) with full sharding annotations."""
    train_step = make_train_step_fn(model, run_cfg, mesh, adamw=adamw,
                                    total_steps=total_steps, fsdp=fsdp)
    jit_kwargs = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(train_step, **jit_kwargs)


def shard_state(state: TrainState, mesh: Mesh, *, zero1: bool = True,
                fsdp: bool = False) -> TrainState:
    """Place a host-initialized TrainState onto the mesh per the rules."""
    rules = sh.rules_for(mesh, fsdp=fsdp)
    specs = state_specs(state, rules, mesh, zero1=zero1)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# paper-faithful explicit-collectives DP step (shard_map over 'x')
# ---------------------------------------------------------------------------

# tuning-table callsite tag for the bucketed gradient reduction: buckets are
# issued back-to-back against the remaining backward compute, so a measured
# ``allreduce@dp.grads`` table entry wins over the isolated-allreduce entry
GRADS_CALLSITE = DP_GRADS


def make_dp_train_step_explicit(model: Model, run_cfg: RunConfig, mesh: Mesh,
                                *, axis: str = "x",
                                adamw: Optional[AdamWConfig] = None,
                                schedule_kind: str = "auto",
                                bucket_bytes: Optional[int] = None,
                                total_steps: int = 10_000) -> Callable:
    """Pure data-parallel step with hand-written gradient reduction.

    The gradient all-reduce routes through
    :meth:`~repro.comm.engine.CollectiveEngine.allreduce_tree`, the bucketed
    overlap path (paper Fig. 5/7's comm/compute overlap applied to the
    backward pass): leaves are packed into ~``bucket_bytes`` buckets and each
    bucket is reduced independently, so XLA can overlap early buckets'
    collectives with the remaining backward compute. ``run_cfg.comm_type``
    picks ICI_DIRECT vs HOST_STAGED, ``schedule_kind`` names the registered
    reduction schedule (``native`` / ``chain`` ring / ``rs_ag`` fused ring /
    ``ring2d`` / ``staged``) — the default ``"auto"`` resolves per bucket
    through the cost model (:mod:`repro.comm.autotune`).
    ``bucket_bytes=None`` derives the bucket size from the DP-axis topology
    and hardware link numbers (pipeline depth x per-hop latency-bandwidth
    product) instead of a fixed constant. Every bucket's reduction is tagged
    ``dp.grads``, so a measured tuning-table entry for the bucketed-gradient
    pattern overrides the isolated-allreduce entry per callsite.

    ``run_cfg.grad_compression`` turns on the int8 error-feedback reduction
    (beyond-paper): that path reduces *leaf-wise* — per-leaf error state
    cannot be bucketed without re-blocking the quantizer — so
    ``bucket_bytes`` does not apply, but the wire payload still rides the
    engine's ring schedules via ``compressed_psum(engine=...)``.
    """
    adamw = adamw or AdamWConfig(lr=run_cfg.learning_rate,
                                 weight_decay=run_cfg.weight_decay,
                                 max_grad_norm=run_cfg.max_grad_norm)
    schedule = make_lr_schedule(adamw.lr, run_cfg.warmup_steps, total_steps)
    engine = CollectiveEngine.for_mesh(mesh, comm_type(run_cfg.comm_type),
                                       schedule_kind)
    compress = run_cfg.grad_compression == "int8_ef"
    ndev = mesh.shape[axis]

    def loss_fn(params, batch):
        logits, _, _ = model.apply(params, batch, remat=run_cfg.remat)
        return next_token_loss(logits, batch["tokens"])

    def step_body(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        # mean over DP ranks, via the selected schedule
        if compress:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(state.error)
            red, errs = [], []
            for g, e in zip(flat_g, flat_e):
                r, ne = compression.compressed_psum(
                    g.astype(jnp.float32) / ndev, axis, e, engine=engine)
                red.append(r)
                errs.append(ne)
            grads = jax.tree.unflatten(treedef, red)
            new_error = jax.tree.unflatten(treedef, errs)
        else:
            grads = engine.allreduce_tree(
                jax.tree.map(lambda g: g.astype(jnp.float32) / ndev, grads),
                axis, bucket_bytes=bucket_bytes, callsite=GRADS_CALLSITE)
            new_error = state.error
        loss = engine.allreduce(loss / ndev, axis)

        grads, gnorm = clip_by_global_norm(grads, adamw.max_grad_norm)
        lr = schedule(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           adamw, lr)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, error=new_error)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    def spec_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def wrapped(state, batch):
        st_spec = TrainState(
            params=spec_like(state.params, P()),
            opt={"mu": spec_like(state.opt["mu"], P()),
                 "nu": spec_like(state.opt["nu"], P()),
                 "count": P()},
            step=P(),
            error=spec_like(state.error, P()) if state.error is not None else None,
        )
        batch_spec = {k: P(axis) for k in batch}
        metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        fn = shard_map(
            step_body, mesh=mesh,
            in_specs=(st_spec, batch_spec),
            out_specs=(st_spec, metrics_spec),
            check_vma=False)
        return fn(state, batch)

    return jax.jit(wrapped, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# whole-model explicit step: full forward+backward inside one shard_map
# ---------------------------------------------------------------------------


def whole_model_param_specs(params: Dict, axis: str = "x") -> Dict:
    """PartitionSpecs for the explicit whole-model step: everything
    replicated except MoE expert weights, which are sharded over ``axis``
    (the leading dim after the super-block scan dim —
    :func:`repro.models.moe.moe_param_specs` with ``scanned=True``)."""
    specs = jax.tree.map(lambda _: P(), params)
    for kp, blk in params["blocks"].items():
        if "moe" in blk:
            specs["blocks"][kp]["moe"] = MOE.moe_param_specs(
                blk["moe"], axis, scanned=True)
    return specs


_IS_SPEC = lambda x: isinstance(x, P)  # noqa: E731 — P() flattens to nothing otherwise


def make_whole_model_train_step_explicit(
        model: Model, run_cfg: RunConfig, mesh: Mesh, *, axis: str = "x",
        attn_mode: str = "tp", adamw: Optional[AdamWConfig] = None,
        schedule_kind: str = "auto", nchunks=1,
        bucket_bytes: Optional[int] = None,
        total_steps: int = 10_000) -> Callable:
    """Whole-model engine-routed step: the full forward+backward runs
    inside ONE ``shard_map`` over ``axis``, every wire hop an explicit
    :class:`~repro.comm.engine.CollectiveEngine` call under a registered
    callsite tag (see :mod:`repro.comm.callsites`):

    * attention activations are exchanged per layer via the ``attn_mode``
      hook from :mod:`repro.models.parallel` — head-parallel (``tp``,
      ``@tp.qkv``/``@tp.out``) or sequence-parallel ring attention (``sp``,
      ``@sp.qkv``/``@sp.kv``/``@sp.out``);
    * MoE dispatch/combine keep ``@moe.dispatch``/``@moe.combine`` with
      experts sharded across ranks in the *param tree* (``nchunks``
      pipelines the capacity strips exactly as in the single-layer path);
    * data-parallel gradient buckets keep ``allreduce @ dp.grads``.

    Gradient semantics: the residual stream is batch-sharded, so the local
    backward already yields *complete* gradients for expert-sharded leaves
    (the dispatch/combine transposes aggregate the other ranks' terms) —
    those are only rescaled by 1/ndev, never reduced — while replicated
    leaves take the bucketed ``allreduce_tree``. The global-norm clip
    mirrors :func:`repro.optim.adamw.clip_by_global_norm` but reduces the
    expert-shard sum-of-squares across ranks first, so the clip scale (and
    the reported ``grad_norm``) equals the GSPMD value.

    Differences vs GSPMD (:func:`make_train_step`) are pure reassociation:
    loss, gradients, and updated params match on the same mesh to float32
    tolerance for every registered schedule and chunk count
    (tests/dist/test_transformer.py).
    """
    cfg = model.cfg
    if cfg.is_encoder_decoder:
        raise ValueError("whole-model explicit step supports decoder-only "
                         "models (encoder-decoder has no explicit path)")
    if run_cfg.grad_compression != "none":
        raise ValueError(
            "whole-model explicit step does not support grad_compression="
            f"{run_cfg.grad_compression!r}: the int8 error-feedback path "
            "reduces leaf-wise and cannot skip the expert-sharded leaves")
    adamw = adamw or AdamWConfig(lr=run_cfg.learning_rate,
                                 weight_decay=run_cfg.weight_decay,
                                 max_grad_norm=run_cfg.max_grad_norm)
    schedule = make_lr_schedule(adamw.lr, run_cfg.warmup_steps, total_steps)
    engine = CollectiveEngine.for_mesh(mesh, comm_type(run_cfg.comm_type),
                                       schedule_kind)
    ndev = mesh.shape[axis]
    # schedule=None: the hooks inherit the engine-wide resolution (auto via
    # the cost model, or the engine's explicit schedule_kind)
    attn_impl = make_attn_impl(attn_mode, cfg, mesh, axis=axis, engine=engine)
    moe_impl = None
    if cfg.has_moe:
        moe_impl = MOE.make_moe_impl(cfg, mesh, axis=axis, engine=engine,
                                     nchunks=nchunks)

    def loss_fn(params, batch):
        logits, _, _ = model.apply(params, batch, remat=run_cfg.remat,
                                   attn_impl=attn_impl, moe_impl=moe_impl)
        return next_token_loss(logits, batch["tokens"])

    def step_body(state: TrainState, batch, *, param_spec):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        # Mean over DP ranks. Expert-sharded leaves already carry the full
        # cross-rank sum (the collective transposes of dispatch/combine
        # aggregate every rank's contribution), so they are only rescaled;
        # replicated leaves ride the bucketed reduction.
        g_leaves, treedef = jax.tree.flatten(grads)
        s_leaves = jax.tree.flatten(param_spec, is_leaf=_IS_SPEC)[0]
        scaled = [g.astype(jnp.float32) / ndev for g in g_leaves]
        rep = {str(i): g for i, (g, s) in enumerate(zip(scaled, s_leaves))
               if s == P()}
        rep = engine.allreduce_tree(rep, axis, bucket_bytes=bucket_bytes,
                                    callsite=GRADS_CALLSITE)
        merged = [rep[str(i)] if str(i) in rep else g
                  for i, g in enumerate(scaled)]
        loss = engine.allreduce(loss / ndev, axis)

        # Global-norm clip, sharding-aware: expert-shard sumsq needs a
        # cross-rank psum; replicated leaves are identical post-allreduce,
        # so their sumsq is local. Same formula as clip_by_global_norm.
        rep_sq = sum(jnp.sum(jnp.square(g)) for g, s in
                     zip(merged, s_leaves) if s == P())
        shard_sq = sum(jnp.sum(jnp.square(g)) for g, s in
                      zip(merged, s_leaves) if s != P())
        if not isinstance(shard_sq, int):  # any expert-sharded leaves?
            rep_sq = rep_sq + engine.allreduce(shard_sq, axis)
        gnorm = jnp.sqrt(rep_sq)
        scale = jnp.minimum(1.0, adamw.max_grad_norm /
                            jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.unflatten(treedef, [g * scale for g in merged])

        lr = schedule(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           adamw, lr)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, error=state.error)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    def wrapped(state, batch):
        pspec = whole_model_param_specs(state.params, axis)
        st_spec = TrainState(
            params=pspec,
            opt={"mu": jax.tree.map(lambda s: s, pspec, is_leaf=_IS_SPEC),
                 "nu": jax.tree.map(lambda s: s, pspec, is_leaf=_IS_SPEC),
                 "count": P()},
            step=P(),
            error=None,
        )
        batch_spec = {k: P(axis) for k in batch}
        metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        fn = shard_map(
            partial(step_body, param_spec=pspec), mesh=mesh,
            in_specs=(st_spec, batch_spec),
            out_specs=(st_spec, metrics_spec),
            check_vma=False)
        return fn(state, batch)

    return jax.jit(wrapped, donate_argnums=(0,))
