from repro.train.step import TrainState, make_train_step, init_train_state  # noqa: F401
from repro.train.serve import make_prefill_step, make_decode_step, generate  # noqa: F401
from repro.train.straggler import StragglerMonitor  # noqa: F401
from repro.train.loop import TrainLoopConfig, train_loop  # noqa: F401
