"""Serving steps: prefill + decode with a sharded KV/SSM cache.

``serve_step`` for the dry-run lowers one decode token against a cache of
``seq_len`` (the assigned decode_*/long_* cells). ``generate`` is a small
batched greedy/temperature sampler driving the two jitted steps — the
"batched requests" server of deliverable (b).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.models.model import Model


def make_prefill_step(model: Model, mesh: Optional[Mesh] = None) -> Callable:
    """(params, batch, cache) -> (logits, cache). Writes positions [0, S)."""
    shard = sh.make_shard_fn(mesh, sh.rules_for(mesh)) if mesh is not None \
        else (lambda x, _: x)

    def prefill(params, batch, cache):
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    return jax.jit(prefill, donate_argnums=(2,))


def make_decode_step(model: Model, mesh: Optional[Mesh] = None,
                     *, seq_shard: bool = False) -> Callable:
    """(params, tokens(B,1), cache, extras) -> (logits(B,1,V), cache)."""
    shard = sh.make_shard_fn(mesh, sh.rules_for(mesh, seq_shard=seq_shard)) \
        if mesh is not None else (lambda x, _: x)

    def decode(params, tokens, cache, extras):
        batch = {"tokens": tokens, **extras}
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    return jax.jit(decode, donate_argnums=(2,))


def generate(model: Model, params, prompts: jnp.ndarray, *,
             max_new_tokens: int = 32, max_seq: Optional[int] = None,
             temperature: float = 0.0, key=None, mesh: Optional[Mesh] = None,
             extras: Optional[Dict] = None,
             eos_id: Optional[int] = None) -> jnp.ndarray:
    """Batched generation. prompts: (B, S0) int32 -> (B, S0 + new)."""
    B, S0 = prompts.shape
    max_seq = max_seq or (S0 + max_new_tokens)
    extras = extras or {}
    dtype = jnp.dtype(model.cfg.dtype)
    cache = model.init_cache(B, max_seq, dtype if dtype != jnp.int32 else jnp.float32)

    prefill = make_prefill_step(model, mesh)
    decode = make_decode_step(model, mesh)

    logits, cache = prefill(params, {"tokens": prompts, **extras}, cache)
    last = logits[:, -1]

    decode_extras = {k: v for k, v in extras.items() if k != "frames"}

    def sample(logits_1, k):
        if temperature <= 0.0:
            return jnp.argmax(logits_1, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits_1 / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.key(0)
    out = [prompts]
    tok = sample(last, key)[:, None]
    done = jnp.zeros((B,), bool)
    for i in range(max_new_tokens):
        out.append(tok)
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
        if i == max_new_tokens - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = decode(params, tok, cache, decode_extras)
        tok = sample(logits[:, -1], sub)[:, None]
        if eos_id is not None:
            tok = jnp.where(done[:, None], eos_id, tok)
    return jnp.concatenate(out, axis=1)
