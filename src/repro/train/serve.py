"""Serving steps: prefill + decode with a sharded KV/SSM cache.

``serve_step`` for the dry-run lowers one decode token against a cache of
``seq_len`` (the assigned decode_*/long_* cells). ``generate`` is a small
batched greedy/temperature sampler driving the two jitted steps — the
"batched requests" server of deliverable (b).

The paged steps back the continuous-batching server (:mod:`repro.serve`):
``make_paged_decode_step`` is the GSPMD reference, and
``make_decode_step_explicit`` runs the same token forward inside ONE
``shard_map`` with every wire hop an explicit engine call — head-parallel
attention under ``decode.qkv``/``decode.out`` and MoE dispatch/combine
under ``decode.moe`` (:mod:`repro.comm.callsites`). Per-token payloads are
tiny, so these callsites resolve in the latency band of the cost model,
separately from the training-sized ``tp.*``/``moe.*`` entries.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.comm.callsites import DECODE_MOE
from repro.comm.engine import CollectiveEngine
from repro.compat import shard_map
from repro.models.model import Model


def make_prefill_step(model: Model, mesh: Optional[Mesh] = None) -> Callable:
    """(params, batch, cache) -> (logits, cache). Writes positions [0, S)."""
    shard = sh.make_shard_fn(mesh, sh.rules_for(mesh)) if mesh is not None \
        else (lambda x, _: x)

    def prefill(params, batch, cache):
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    return jax.jit(prefill, donate_argnums=(2,))


def make_decode_step(model: Model, mesh: Optional[Mesh] = None,
                     *, seq_shard: bool = False) -> Callable:
    """(params, tokens(B,1), cache, extras) -> (logits(B,1,V), cache)."""
    shard = sh.make_shard_fn(mesh, sh.rules_for(mesh, seq_shard=seq_shard)) \
        if mesh is not None else (lambda x, _: x)

    def decode(params, tokens, cache, extras):
        batch = {"tokens": tokens, **extras}
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    return jax.jit(decode, donate_argnums=(2,))


# ---------------------------------------------------------------------------
# paged decode: GSPMD reference + explicit engine-routed tensor-parallel
# ---------------------------------------------------------------------------


def make_paged_decode_step(model: Model, mesh: Optional[Mesh] = None
                           ) -> Callable:
    """GSPMD paged decode: ``(params, tokens(B,1), pages, block_table,
    lengths) -> (logits(B,1,V), pages)``.

    ``pages`` is :func:`repro.models.transformer.init_paged_cache` output;
    ``block_table`` (B, pmax) / ``lengths`` (B,) come from the host
    :class:`~repro.models.kvcache.PageAllocator`. Row b attends to its
    pages' positions ``<= lengths[b]`` (the new token is written at
    ``lengths[b]``); rows with a sentinel block-table row are inactive —
    their logits are garbage and their cache writes drop.
    """
    shard = sh.make_shard_fn(mesh, sh.rules_for(mesh)) if mesh is not None \
        else (lambda x, _: x)

    def decode(params, tokens, pages, block_table, lengths):
        cache = {"pos": lengths, "layers": pages["layers"]}
        page_table = {"block_table": block_table, "lengths": lengths}
        logits, new_cache, _ = model.apply(
            params, {"tokens": tokens}, cache=cache, shard=shard,
            page_table=page_table)
        return logits, {"layers": new_cache["layers"]}

    return jax.jit(decode, donate_argnums=(2,))


def make_decode_step_explicit(model: Model, mesh: Mesh, *, axis: str = "x",
                              engine: Optional[CollectiveEngine] = None,
                              schedule: Optional[str] = None,
                              nchunks=1) -> Callable:
    """Engine-routed paged decode: one token's forward inside ONE
    ``shard_map`` over ``axis``, signature-identical to
    :func:`make_paged_decode_step`.

    The residual stream stays batch-sharded; per layer the paged decode
    hook (:func:`repro.models.parallel.make_paged_decode_attention`)
    exchanges q and the token's k/v head-parallel (``@decode.qkv``), runs
    :func:`~repro.models.layers.decode_attention` against the rank-local
    page pool (KV heads sharded over ``axis``), and restores the layout
    (``@decode.out``); MoE layers dispatch/combine under ``@decode.moe``
    with experts sharded in the param tree. Requires batch (slot count),
    heads, kv heads — and experts, when present — divisible by the axis
    size. Matches the GSPMD step's logits and cache for every registered
    a2a schedule (tests/dist/test_serve.py).
    """
    from repro.models import moe as MOE
    from repro.models.parallel import make_paged_decode_attention
    from repro.train.step import whole_model_param_specs

    cfg = model.cfg
    engine = engine or CollectiveEngine.for_mesh(mesh, schedule="auto")
    attn_impl = make_paged_decode_attention(cfg, mesh, axis=axis,
                                            engine=engine, schedule=schedule)
    moe_impl = None
    if cfg.has_moe:
        moe_impl = MOE.make_moe_impl(cfg, mesh, axis=axis, engine=engine,
                                     schedule=schedule, nchunks=nchunks,
                                     dispatch_callsite=DECODE_MOE,
                                     combine_callsite=DECODE_MOE)

    def body(params, tokens, pages_layers, block_table, lengths, pos_loc):
        cache = {"pos": pos_loc, "layers": pages_layers}
        page_table = {"block_table": block_table, "lengths": lengths}
        logits, new_cache, _ = model.apply(
            params, {"tokens": tokens}, cache=cache, page_table=page_table,
            attn_impl=attn_impl, moe_impl=moe_impl)
        return logits, new_cache["layers"]

    def wrapped(params, tokens, pages, block_table, lengths):
        pspec = whole_model_param_specs(params, axis)
        # page pools shard the KV-head dim: (n_super, P, ps, KV, hd)
        pages_spec = jax.tree.map(
            lambda _: P(None, None, None, axis, None), pages["layers"])
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P(axis, None), pages_spec, P(), P(), P(axis)),
            out_specs=(P(axis, None, None), pages_spec),
            check_vma=False)
        logits, layers = fn(params, tokens, pages["layers"], block_table,
                            lengths, lengths)
        return logits, {"layers": layers}

    return jax.jit(wrapped, donate_argnums=(2,))


def generate(model: Model, params, prompts: jnp.ndarray, *,
             max_new_tokens: int = 32, max_seq: Optional[int] = None,
             temperature: float = 0.0, key=None, mesh: Optional[Mesh] = None,
             extras: Optional[Dict] = None,
             eos_id: Optional[int] = None) -> jnp.ndarray:
    """Batched generation. prompts: (B, S0) int32 -> (B, S0 + new)."""
    B, S0 = prompts.shape
    max_seq = max_seq or (S0 + max_new_tokens)
    extras = extras or {}
    dtype = jnp.dtype(model.cfg.dtype)
    cache = model.init_cache(B, max_seq, dtype if dtype != jnp.int32 else jnp.float32)

    prefill = make_prefill_step(model, mesh)
    decode = make_decode_step(model, mesh)

    logits, cache = prefill(params, {"tokens": prompts, **extras}, cache)
    last = logits[:, -1]

    decode_extras = {k: v for k, v in extras.items() if k != "frames"}

    def sample(logits_1, k):
        if temperature <= 0.0:
            return jnp.argmax(logits_1, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits_1 / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.key(0)
    out = [prompts]
    tok = sample(last, key)[:, None]
    done = jnp.zeros((B,), bool)
    for i in range(max_new_tokens):
        out.append(tok)
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
            if bool(done.all()):
                break  # every request hit EOS — stop decoding early
        if i == max_new_tokens - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = decode(params, tok, cache, decode_extras)
        tok = sample(logits[:, -1], sub)[:, None]
        if eos_id is not None:
            # finished rows are masked to EOS: their sampled continuations
            # never leak into the output
            tok = jnp.where(done[:, None], eos_id, tok)
    res = jnp.concatenate(out, axis=1)
    full = S0 + max_new_tokens
    if res.shape[1] < full:  # early EOS stop: pad to the fixed output shape
        pad = jnp.full((B, full - res.shape[1]), eos_id, res.dtype)
        res = jnp.concatenate([res, pad], axis=1)
    return res
