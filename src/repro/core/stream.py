"""STREAM — memory bandwidth benchmark (paper legacy suite, §3.4).

Embarrassingly parallel across devices (the paper's multi-FPGA extension
only coordinates measurement); per-device compute is the Pallas triad/add/
scale/copy kernels. Metric: aggregated GB/s, normalized per HBM stack in the
benchmark report (the paper normalizes per memory bank).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit
from repro.kernels import stream as sk


@register("stream")
def run_stream(mesh, comm=CommunicationType.ICI_DIRECT, *,
               elems_per_device: int = 1 << 20, reps: int = 3,
               interpret: bool = True) -> BenchResult:
    n_dev = mesh.devices.size
    n = elems_per_device * n_dev
    spec = NamedSharding(mesh, P("x"))
    key = jax.random.PRNGKey(0)
    a = jax.device_put(jax.random.normal(key, (n,), jnp.float32), spec)
    b = jax.device_put(jax.random.normal(key, (n,), jnp.float32), spec)
    alpha = 3.0

    smap = lambda fn, n_in: jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P("x"),) * n_in, out_specs=P("x"),
        check_vma=False))

    copy = smap(lambda x: sk.stream_copy(x, interpret=interpret), 1)
    scale = smap(lambda x: sk.stream_scale(x, alpha, interpret=interpret), 1)
    add = smap(lambda x, y: sk.stream_add(x, y, interpret=interpret), 2)
    triad = smap(lambda x, y: sk.stream_triad(x, y, alpha, interpret=interpret), 2)

    times = {}
    bw = {}
    _, times["copy"] = timeit(copy, a, reps=reps)
    _, times["scale"] = timeit(scale, a, reps=reps)
    _, times["add"] = timeit(add, a, b, reps=reps)
    out, times["triad"] = timeit(triad, a, b, reps=reps)
    bytes_per = {"copy": 2, "scale": 2, "add": 3, "triad": 3}
    for k, t in times.items():
        bw[k] = bytes_per[k] * 4.0 * n / t

    err = float(jnp.max(jnp.abs(out - (a + alpha * b))))
    return BenchResult(
        name="stream", metric_name="triad_B/s", metric=bw["triad"], error=err,
        times=times, details={"bandwidth": bw, "devices": n_dev,
                              "elems_per_device": elems_per_device})
