"""HPL / LINPACK — distributed blocked right-looking LU on a 2-D torus
(paper §2.3, Figs. 4-8). HPL-AI ruleset: diagonally-dominant A, no pivoting;
only the LU factorization runs on the accelerators, the triangular solves
run on the host as the CPU reference step, and the reported error is the
normalized residual ||Ax - b|| / (n * ||b|| * eps).

Per iteration k (paper Fig. 4):
  1. the (k%P, k%P) device factorizes the diagonal block   [kernels/lu.py]
  2. the packed LU block is broadcast along its grid row and column
     (the paper's "network kernels" forwarding through the torus — here
     ``CollectiveEngine.bcast`` with the ``chain`` store-and-forward,
     ``native``, or torus-aware ``ring2d`` scatter/all-gather schedule)
  3. grid row k%P solves the Top panel (U_kj), grid column k%P the Left
     panel (L_ik)                                          [trsm kernels]
  4. panels are broadcast down/across the torus
  5. every device applies the trailing rank-b GEMM update on its local
     blocks                                                 [gemm_update]

The masks that restrict panels to i,j > k are *multiplicative* (zeroed rows/
columns), so the trailing update needs no selects — a zeroed panel row
contributes nothing, exactly like the paper's "blocks left/above need no
further processing".

Lookahead (paper Fig. 5/7 overlap) — ``lookahead=d`` (``True`` == 1) keeps
``d`` panel pipelines in flight: per iteration k, only the row/column strips
that iteration k+d's panels read are updated first (2d thin GEMMs applying
the d pending in-flight updates restricted to that band — the strip-update
schedule skips every band already covered by earlier strip passes), then
iteration k+d's diagonal factorization and row/column broadcasts are issued,
and only then is the bulk trailing GEMM of iteration k applied. The k+d
broadcasts depend solely on the strips, so XLA can interleave the
``chain``/``ring2d`` hops of up to d iterations with the bulk updates —
covering the broadcast latency of small blocks on large tori. The bulk GEMM
still covers the full local matrix (the strip work is redundant compute,
~2db/m of the update FLOPs), which keeps the factorization bit-identical to
eager mode for every d: every matrix element takes its value from the same
full-GEMM arithmetic; the strip GEMM sequence applied to the k+d band is
per-element identical to the same d full GEMMs restricted to the band; and
the k+d panels never read global row/column <= k+d-1 (masked), the only
entries whose values the pending write-backs would change. The depth can be
resolved from the cost model (``lookahead="auto"`` in :func:`run_hpl` →
:func:`repro.comm.autotune.choose_hpl_depth`).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.callsites import HPL_BLOCK, HPL_PANEL
from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit
from repro.core.models import hpl_flops
from repro.core.ptrans import distribute_cyclic, undistribute_cyclic
from repro.kernels.ops import (gemm_update, lu_factor_block,
                               trsm_lower_left, trsm_upper_right)


# ---------------------------------------------------------------------------
# problem generation / validation (host side, like the paper)
# ---------------------------------------------------------------------------


def generate_system(n: int, seed: int = 7) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Diagonally dominant A (HPL-AI rule), x = ones, b = A @ x."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(-0.5, 0.5, (n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n
    x = np.ones((n,), np.float32)
    b = a @ x
    return a, x, b


def solve_from_lu(lu: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Host triangular solves L y = b, U x = y from the packed LU."""
    import jax.scipy.linalg as jsl
    l = np.tril(lu, -1) + np.eye(lu.shape[0], dtype=lu.dtype)
    u = np.triu(lu)
    y = np.asarray(jsl.solve_triangular(l, b, lower=True, unit_diagonal=True))
    return np.asarray(jsl.solve_triangular(u, y, lower=False))


def normalized_residual(a: np.ndarray, x: np.ndarray, b: np.ndarray) -> float:
    eps = np.finfo(np.float32).eps
    r = np.max(np.abs(a @ x - b))
    return float(r / (a.shape[0] * np.max(np.abs(b)) * eps))


# ---------------------------------------------------------------------------
# distributed factorization
# ---------------------------------------------------------------------------


def _panels(k, diag, row_panel, col_panel, *, pg: int, b: int,
            engine: CollectiveEngine, interpret, li_global, lj_global):
    """Factor the diagonal block and form + broadcast iteration ``k``'s U/L
    panels (paper Fig. 4 steps 1-4). ``diag``/``row_panel``/``col_panel`` are
    this device's local strips at local block index k // pg, already carrying
    the first k rank-b updates. Returns (lu_blk, u_panel, l_panel), all
    broadcast grid-wide."""
    pk = k % pg

    # 1. diagonal block (speculative on every device; selected by bcast)
    lu_local = lu_factor_block(diag, interpret=interpret)
    lu_blk = engine.bcast(lu_local, "cols", pk, callsite=HPL_BLOCK)
    lu_blk = engine.bcast(lu_blk, "rows", pk, callsite=HPL_BLOCK)

    # 2. Top panel: U_kj = L_kk^{-1} A_kj on grid row pk, cols j > k
    u_panel = trsm_lower_left(lu_blk, row_panel, interpret=interpret)
    colmask = jnp.repeat(lj_global > k, b)  # (m,)
    u_panel = u_panel * colmask[None, :]
    u_panel = engine.bcast(u_panel, "rows", pk, callsite=HPL_PANEL)

    # 3. Left panel: L_ik = A_ik U_kk^{-1} on grid col pk, rows i > k
    l_panel = trsm_upper_right(lu_blk, col_panel, interpret=interpret)
    rowmask = jnp.repeat(li_global > k, b)
    l_panel = l_panel * rowmask[:, None]
    l_panel = engine.bcast(l_panel, "cols", pk, callsite=HPL_PANEL)
    return lu_blk, u_panel, l_panel


def _update_writeback(k, a, lu_blk, u_panel, l_panel, *, pg: int, b: int,
                      lb: int, interpret, r, c, li_global, lj_global):
    """Apply iteration ``k``'s trailing rank-b GEMM over the full local
    matrix and write back the factored panels."""
    m = lb * b
    pk = k % pg
    lk = k // pg
    colmask = jnp.repeat(lj_global > k, b)
    rowmask = jnp.repeat(li_global > k, b)

    # 4. trailing update: masks zero the factored rows/cols
    a = gemm_update(a, l_panel, u_panel, alpha=-1.0, interpret=interpret)

    # 5. write back factored panels. The rank masks are folded INTO the
    # update values so every write is one slice-sized dynamic-update-slice —
    # a `where(r == pk, dus(a, ...), a)` select would touch the full local
    # matrix three times per iteration (measured as the second-largest HBM
    # term of the production HPL lowering, §Perf iteration C1).
    old_row = lax.dynamic_slice(a, (lk * b, 0), (b, m))
    new_row = jnp.where(colmask[None, :] & (r == pk), u_panel, old_row)
    a = lax.dynamic_update_slice(a, new_row, (lk * b, 0))
    old_col = lax.dynamic_slice(a, (0, lk * b), (m, b))
    new_col = jnp.where(rowmask[:, None] & (c == pk), l_panel, old_col)
    a = lax.dynamic_update_slice(a, new_col, (0, lk * b))
    old_diag = lax.dynamic_slice(a, (lk * b, lk * b), (b, b))
    new_diag = jnp.where((r == pk) & (c == pk), lu_blk, old_diag)
    a = lax.dynamic_update_slice(a, new_diag, (lk * b, lk * b))
    return a


def _iteration(k, a, *, pg: int, b: int, lb: int, engine: CollectiveEngine,
               interpret, r, c, li_global, lj_global):
    """Eager iteration: factor+broadcast panels for k, then update."""
    m = lb * b
    lk = k // pg
    diag = lax.dynamic_slice(a, (lk * b, lk * b), (b, b))
    row_panel = lax.dynamic_slice(a, (lk * b, 0), (b, m))
    col_panel = lax.dynamic_slice(a, (0, lk * b), (m, b))
    lu_blk, u_panel, l_panel = _panels(
        k, diag, row_panel, col_panel, pg=pg, b=b, engine=engine,
        interpret=interpret, li_global=li_global, lj_global=lj_global)
    return _update_writeback(k, a, lu_blk, u_panel, l_panel, pg=pg, b=b,
                             lb=lb, interpret=interpret, r=r, c=c,
                             li_global=li_global, lj_global=lj_global)


def _strip_panels(kidx, a, flight, *, pg: int, b: int, lb: int,
                  engine: CollectiveEngine, interpret, li_global, lj_global):
    """Form + broadcast iteration ``kidx``'s panels from thin strips of
    ``a``, first applying every pending in-flight update (the panel sets in
    ``flight``, oldest first) *restricted to the band* ``kidx`` reads — 2
    thin GEMMs per pending set. Bands of earlier in-flight iterations were
    strip-updated when their own panels were formed, so only this band's
    updates are (re)applied here — the strip-update schedule never revisits
    an already-updated band. ``kidx`` may be traced."""
    m = lb * b
    lk = kidx // pg
    row_strip = lax.dynamic_slice(a, (lk * b, 0), (b, m))
    col_strip = lax.dynamic_slice(a, (0, lk * b), (m, b))
    for lu_blk, u_panel, l_panel in flight:
        l_rows = lax.dynamic_slice(l_panel, (lk * b, 0), (b, b))
        row_strip = gemm_update(row_strip, l_rows, u_panel, alpha=-1.0,
                                interpret=interpret)
        u_cols = lax.dynamic_slice(u_panel, (0, lk * b), (b, b))
        col_strip = gemm_update(col_strip, l_panel, u_cols, alpha=-1.0,
                                interpret=interpret)
    diag = lax.dynamic_slice(col_strip, (lk * b, 0), (b, b))
    return _panels(kidx, diag, row_strip, col_strip, pg=pg, b=b,
                   engine=engine, interpret=interpret, li_global=li_global,
                   lj_global=lj_global)


def _iteration_lookahead(k, carry, *, pg: int, nb: int, b: int, lb: int,
                         depth: int, engine: CollectiveEngine, interpret,
                         r, c, li_global, lj_global):
    """Depth-d lookahead iteration (paper Fig. 5/7): the carry holds the
    ``depth`` in-flight panel sets for iterations k..k+d-1, already
    broadcast. Update only the strips iteration k+d reads (applying the d
    pending updates restricted to that band), issue k+d's factorization +
    broadcasts, THEN apply iteration k's bulk trailing GEMM — the broadcast
    hops depend only on the thin strip GEMMs, so XLA is free to overlap up
    to d iterations' broadcasts with the bulk updates.

    Bit-identity with eager mode, for every d: the bulk GEMM below still
    covers the full local matrix, so every element of ``a`` takes its value
    from exactly the eager arithmetic; the strip GEMM sequence is
    per-element identical to the same full GEMMs restricted to the strip
    (single k-block of b <= bk columns — asserted by
    tests/dist/test_overlap.py); and the k+d panels never read global
    row/column <= k+d-1 (masked multiplicatively), the only entries the
    pending write-backs would change."""
    a = carry[0]
    flight = tuple(carry[1:])  # depth triples (lu_blk, u_panel, l_panel)
    # iteration k+d's index, clamped near the end — the speculative panels
    # computed there are discarded with the carry
    kd = jnp.minimum(k + depth, nb - 1)

    # 1.-2. thin strip updates for the k+d band, then issue k+d's
    # factorization and row/column broadcasts now
    nxt = _strip_panels(kd, a, flight, pg=pg, b=b, lb=lb, engine=engine,
                        interpret=interpret, li_global=li_global,
                        lj_global=lj_global)

    # 3. bulk trailing update + write back iteration k's factored panels
    # (the oldest in-flight set)
    a = _update_writeback(k, a, *flight[0], pg=pg, b=b, lb=lb,
                          interpret=interpret, r=r, c=c,
                          li_global=li_global, lj_global=lj_global)
    return (a,) + flight[1:] + (nxt,)


def lookahead_depth(lookahead) -> int:
    """Normalize a ``lookahead`` argument to a pipeline depth: False/0 ->
    eager, True -> 1, an int d -> d. Negative depths fail fast here instead
    of as an opaque IndexError inside the factorization loop."""
    if lookahead is True:
        return 1
    if lookahead is False or lookahead is None:
        return 0
    depth = int(lookahead)
    if depth < 0:
        raise ValueError(f"lookahead depth must be >= 0, got {lookahead!r}")
    return depth


def _hpl_body(a_loc, *, pg: int, nb: int, b: int, engine: CollectiveEngine,
              interpret: bool, lookahead=False):
    a = a_loc[0]
    lb = nb // pg
    r = lax.axis_index("rows")
    c = lax.axis_index("cols")
    li_global = jnp.arange(lb) * pg + r
    lj_global = jnp.arange(lb) * pg + c
    strip_kw = dict(pg=pg, b=b, lb=lb, engine=engine, interpret=interpret,
                    li_global=li_global, lj_global=lj_global)
    common = dict(r=r, c=c, **strip_kw)
    # no point carrying more panel sets than there are iterations
    depth = min(lookahead_depth(lookahead), nb)

    if depth:
        # prologue: fill the flight with iterations 0..d-1's panels, each
        # formed from strips carrying the pending earlier in-flight updates
        flight = []
        for j in range(depth):
            flight.append(_strip_panels(min(j, nb - 1), a, flight,
                                        **strip_kw))
        step = partial(_iteration_lookahead, nb=nb, depth=depth, **common)
        a = lax.fori_loop(0, nb, step, (a,) + tuple(flight))[0]
    else:
        step = partial(_iteration, **common)
        a = lax.fori_loop(0, nb, step, a)
    return a[None]


def make_factorize(mesh, *, pg: int, nb: int, b: int,
                   comm=CommunicationType.ICI_DIRECT, schedule: str = "auto",
                   interpret: bool = True, lookahead=False,
                   engine: CollectiveEngine = None):
    """``lookahead`` is a pipeline depth: False/0 eager, True/1 one panel
    set in flight, d >= 2 the depth-d pipeline."""
    engine = engine or CollectiveEngine.for_mesh(mesh, comm, schedule,
                                                 interpret=interpret)
    spec = P(("rows", "cols"), None, None)
    fn = shard_map(
        partial(_hpl_body, pg=pg, nb=nb, b=b, engine=engine,
                interpret=interpret, lookahead=lookahead),
        mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False)
    return jax.jit(fn)


@register("hpl")
def run_hpl(mesh, comm=CommunicationType.ICI_DIRECT, *, n: int = 512,
            b: int = 64, schedule: str = "auto", reps: int = 2,
            interpret: bool = True, validate: bool = True,
            lookahead=False) -> BenchResult:
    """mesh axes ('rows', 'cols'), P = Q (paper's quadratic torus).

    ``lookahead`` runs the overlapped factorization (paper Fig. 5/7):
    ``True``/1 keeps one panel set in flight, an int d >= 2 the depth-d
    pipeline, ``"auto"`` resolves the depth from the cost model
    (:func:`repro.comm.autotune.choose_hpl_depth`). The LU output is
    bit-identical to eager mode under every bcast schedule at every depth.
    """
    pg = mesh.shape["rows"]
    assert mesh.shape["cols"] == pg, "paper requires a quadratic torus"
    nb = n // b
    assert nb % pg == 0, (n, b, pg)
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule,
                                       interpret=interpret)

    m = (nb // pg) * b
    if lookahead == "auto":
        from repro.comm.autotune import choose_hpl_depth
        topo = engine.topology
        lookahead = choose_hpl_depth(
            b=b, m=m, axes=(topo.axis("rows"), topo.axis("cols")),
            model=engine.cost_model,
            # price the broadcasts on what THIS engine actually runs
            # (engine-wide overrides, HOST_STAGED forcing staged)
            resolve=lambda op, nbytes, ax, callsite: engine.schedule_for(
                op, nbytes=nbytes, axis=ax.name, callsite=callsite))
    depth = min(lookahead_depth(lookahead), nb)

    a, x_true, b_vec = generate_system(n)
    spec = NamedSharding(mesh, P(("rows", "cols"), None, None))
    a_sh = jax.device_put(distribute_cyclic(a, pg, b), spec)

    fact = make_factorize(mesh, pg=pg, nb=nb, b=b, engine=engine,
                          interpret=interpret, lookahead=depth)
    out, t = timeit(fact, a_sh, reps=reps)

    err = 0.0
    if validate:
        lu = undistribute_cyclic(np.asarray(out), pg, b)
        x = solve_from_lu(lu, b_vec)
        err = normalized_residual(a, x, b_vec)

    # resolved provenance: the *names the cost model picked* for both bcast
    # payloads — the b x b diagonal block and the dominant b x m row/column
    # panels — never the literal "auto"
    block_bytes = b * b * 4
    panel_bytes = b * m * 4
    resolved_block = engine.schedule_for("bcast", nbytes=block_bytes,
                                         axis="rows", callsite=HPL_BLOCK)
    resolved = engine.schedule_for("bcast", nbytes=panel_bytes, axis="rows",
                                   callsite=HPL_PANEL)
    return BenchResult(
        name="hpl", metric_name="GFLOP/s", metric=hpl_flops(n) / t / 1e9,
        error=err, times={"best": t},
        details={"n": n, "block": b, "grid": pg, "comm": engine.comm.value,
                 "schedule": resolved,
                 "schedule_block": resolved_block,
                 "schedule_panel": resolved,
                 "schedule_requested": engine.schedule,
                 "bcast_bytes": panel_bytes,
                 "block_bytes": block_bytes,
                 "lookahead": depth > 0,
                 "lookahead_depth": depth})
