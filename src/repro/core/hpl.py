"""HPL / LINPACK — distributed blocked right-looking LU on a 2-D torus
(paper §2.3, Figs. 4-8). HPL-AI ruleset: diagonally-dominant A, no pivoting;
only the LU factorization runs on the accelerators, the triangular solves
run on the host as the CPU reference step, and the reported error is the
normalized residual ||Ax - b|| / (n * ||b|| * eps).

Per iteration k (paper Fig. 4):
  1. the (k%P, k%P) device factorizes the diagonal block   [kernels/lu.py]
  2. the packed LU block is broadcast along its grid row and column
     (the paper's "network kernels" forwarding through the torus — here
     ``CollectiveEngine.bcast`` with the ``chain`` store-and-forward,
     ``native``, or torus-aware ``ring2d`` scatter/all-gather schedule)
  3. grid row k%P solves the Top panel (U_kj), grid column k%P the Left
     panel (L_ik)                                          [trsm kernels]
  4. panels are broadcast down/across the torus
  5. every device applies the trailing rank-b GEMM update on its local
     blocks                                                 [gemm_update]

The masks that restrict panels to i,j > k are *multiplicative* (zeroed rows/
columns), so the trailing update needs no selects — a zeroed panel row
contributes nothing, exactly like the paper's "blocks left/above need no
further processing".

Lookahead (paper Fig. 5/7 overlap) — ``lookahead=True`` pipelines the panel
pipeline one iteration ahead: per iteration k, only the row/column strips
that iteration k+1's panels read are updated first (two thin GEMMs), then
iteration k+1's diagonal factorization and row/column broadcasts are issued,
and only then is the bulk trailing GEMM of iteration k applied. The k+1
broadcasts depend solely on the strips, so XLA can interleave the
``chain``/``ring2d`` hops with the bulk update. The bulk GEMM still covers
the full local matrix (the strip work is redundant compute, ~2b/m of the
update FLOPs), which keeps the factorization bit-identical to eager mode:
every matrix element takes its value from the same full-GEMM arithmetic,
and the k+1 panels never read global row/column <= k (masked), the only
entries whose values differ before the write-back.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit
from repro.core.models import hpl_flops
from repro.core.ptrans import distribute_cyclic, undistribute_cyclic
from repro.kernels.ops import (gemm_update, lu_factor_block,
                               trsm_lower_left, trsm_upper_right)


# ---------------------------------------------------------------------------
# problem generation / validation (host side, like the paper)
# ---------------------------------------------------------------------------


def generate_system(n: int, seed: int = 7) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Diagonally dominant A (HPL-AI rule), x = ones, b = A @ x."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(-0.5, 0.5, (n, n)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n
    x = np.ones((n,), np.float32)
    b = a @ x
    return a, x, b


def solve_from_lu(lu: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Host triangular solves L y = b, U x = y from the packed LU."""
    import jax.scipy.linalg as jsl
    l = np.tril(lu, -1) + np.eye(lu.shape[0], dtype=lu.dtype)
    u = np.triu(lu)
    y = np.asarray(jsl.solve_triangular(l, b, lower=True, unit_diagonal=True))
    return np.asarray(jsl.solve_triangular(u, y, lower=False))


def normalized_residual(a: np.ndarray, x: np.ndarray, b: np.ndarray) -> float:
    eps = np.finfo(np.float32).eps
    r = np.max(np.abs(a @ x - b))
    return float(r / (a.shape[0] * np.max(np.abs(b)) * eps))


# ---------------------------------------------------------------------------
# distributed factorization
# ---------------------------------------------------------------------------


def _panels(k, diag, row_panel, col_panel, *, pg: int, b: int,
            engine: CollectiveEngine, interpret, li_global, lj_global):
    """Factor the diagonal block and form + broadcast iteration ``k``'s U/L
    panels (paper Fig. 4 steps 1-4). ``diag``/``row_panel``/``col_panel`` are
    this device's local strips at local block index k // pg, already carrying
    the first k rank-b updates. Returns (lu_blk, u_panel, l_panel), all
    broadcast grid-wide."""
    pk = k % pg

    # 1. diagonal block (speculative on every device; selected by bcast)
    lu_local = lu_factor_block(diag, interpret=interpret)
    lu_blk = engine.bcast(lu_local, "cols", pk)
    lu_blk = engine.bcast(lu_blk, "rows", pk)

    # 2. Top panel: U_kj = L_kk^{-1} A_kj on grid row pk, cols j > k
    u_panel = trsm_lower_left(lu_blk, row_panel, interpret=interpret)
    colmask = jnp.repeat(lj_global > k, b)  # (m,)
    u_panel = u_panel * colmask[None, :]
    u_panel = engine.bcast(u_panel, "rows", pk)

    # 3. Left panel: L_ik = A_ik U_kk^{-1} on grid col pk, rows i > k
    l_panel = trsm_upper_right(lu_blk, col_panel, interpret=interpret)
    rowmask = jnp.repeat(li_global > k, b)
    l_panel = l_panel * rowmask[:, None]
    l_panel = engine.bcast(l_panel, "cols", pk)
    return lu_blk, u_panel, l_panel


def _update_writeback(k, a, lu_blk, u_panel, l_panel, *, pg: int, b: int,
                      lb: int, interpret, r, c, li_global, lj_global):
    """Apply iteration ``k``'s trailing rank-b GEMM over the full local
    matrix and write back the factored panels."""
    m = lb * b
    pk = k % pg
    lk = k // pg
    colmask = jnp.repeat(lj_global > k, b)
    rowmask = jnp.repeat(li_global > k, b)

    # 4. trailing update: masks zero the factored rows/cols
    a = gemm_update(a, l_panel, u_panel, alpha=-1.0, interpret=interpret)

    # 5. write back factored panels. The rank masks are folded INTO the
    # update values so every write is one slice-sized dynamic-update-slice —
    # a `where(r == pk, dus(a, ...), a)` select would touch the full local
    # matrix three times per iteration (measured as the second-largest HBM
    # term of the production HPL lowering, §Perf iteration C1).
    old_row = lax.dynamic_slice(a, (lk * b, 0), (b, m))
    new_row = jnp.where(colmask[None, :] & (r == pk), u_panel, old_row)
    a = lax.dynamic_update_slice(a, new_row, (lk * b, 0))
    old_col = lax.dynamic_slice(a, (0, lk * b), (m, b))
    new_col = jnp.where(rowmask[:, None] & (c == pk), l_panel, old_col)
    a = lax.dynamic_update_slice(a, new_col, (0, lk * b))
    old_diag = lax.dynamic_slice(a, (lk * b, lk * b), (b, b))
    new_diag = jnp.where((r == pk) & (c == pk), lu_blk, old_diag)
    a = lax.dynamic_update_slice(a, new_diag, (lk * b, lk * b))
    return a


def _iteration(k, a, *, pg: int, b: int, lb: int, engine: CollectiveEngine,
               interpret, r, c, li_global, lj_global):
    """Eager iteration: factor+broadcast panels for k, then update."""
    m = lb * b
    lk = k // pg
    diag = lax.dynamic_slice(a, (lk * b, lk * b), (b, b))
    row_panel = lax.dynamic_slice(a, (lk * b, 0), (b, m))
    col_panel = lax.dynamic_slice(a, (0, lk * b), (m, b))
    lu_blk, u_panel, l_panel = _panels(
        k, diag, row_panel, col_panel, pg=pg, b=b, engine=engine,
        interpret=interpret, li_global=li_global, lj_global=lj_global)
    return _update_writeback(k, a, lu_blk, u_panel, l_panel, pg=pg, b=b,
                             lb=lb, interpret=interpret, r=r, c=c,
                             li_global=li_global, lj_global=lj_global)


def _iteration_lookahead(k, carry, *, pg: int, nb: int, b: int, lb: int,
                         engine: CollectiveEngine, interpret, r, c,
                         li_global, lj_global):
    """Lookahead iteration (paper Fig. 5/7): the carry holds iteration k's
    already-broadcast panels. Update only the strips iteration k+1 reads,
    issue k+1's factorization + broadcasts, THEN apply the bulk trailing
    GEMM — the broadcast hops depend only on the thin strip GEMMs, so XLA is
    free to overlap them with the bulk update.

    Bit-identity with eager mode: the bulk GEMM below still covers the full
    local matrix, so every element of ``a`` takes its value from exactly the
    eager arithmetic; the strip GEMMs are per-element identical to the full
    GEMM restricted to the strip (single k-block of b <= bk columns —
    asserted by tests/dist/test_overlap.py); and the k+1 panels never read
    global row/column <= k (masked multiplicatively), the only entries the
    pending write-back of iteration k would change."""
    a, lu_blk, u_panel, l_panel = carry
    m = lb * b
    # iteration k+1's local panel index, clamped on the final iteration —
    # the speculative panels computed there are discarded with the carry
    kn = jnp.minimum(k + 1, nb - 1)
    lkn = kn // pg

    # 1. thin strip updates: just the row/column band feeding k+1's panels
    row_strip = lax.dynamic_slice(a, (lkn * b, 0), (b, m))
    l_rows = lax.dynamic_slice(l_panel, (lkn * b, 0), (b, b))
    row_strip = gemm_update(row_strip, l_rows, u_panel, alpha=-1.0,
                            interpret=interpret)
    col_strip = lax.dynamic_slice(a, (0, lkn * b), (m, b))
    u_cols = lax.dynamic_slice(u_panel, (0, lkn * b), (b, b))
    col_strip = gemm_update(col_strip, l_panel, u_cols, alpha=-1.0,
                            interpret=interpret)
    diag = lax.dynamic_slice(col_strip, (lkn * b, 0), (b, b))

    # 2. issue iteration k+1's factorization and row/column broadcasts now
    nxt = _panels(kn, diag, row_strip, col_strip, pg=pg, b=b, engine=engine,
                  interpret=interpret, li_global=li_global,
                  lj_global=lj_global)

    # 3. bulk trailing update + write back iteration k's factored panels
    a = _update_writeback(k, a, lu_blk, u_panel, l_panel, pg=pg, b=b, lb=lb,
                          interpret=interpret, r=r, c=c,
                          li_global=li_global, lj_global=lj_global)
    return (a,) + nxt


def _hpl_body(a_loc, *, pg: int, nb: int, b: int, engine: CollectiveEngine,
              interpret: bool, lookahead: bool = False):
    a = a_loc[0]
    lb = nb // pg
    r = lax.axis_index("rows")
    c = lax.axis_index("cols")
    li_global = jnp.arange(lb) * pg + r
    lj_global = jnp.arange(lb) * pg + c
    common = dict(pg=pg, b=b, lb=lb, engine=engine, interpret=interpret,
                  r=r, c=c, li_global=li_global, lj_global=lj_global)

    if lookahead:
        # prologue: iteration 0's panels from the untouched matrix
        first = _panels(0, a[:b, :b], a[:b, :], a[:, :b], pg=pg, b=b,
                        engine=engine, interpret=interpret,
                        li_global=li_global, lj_global=lj_global)
        step = partial(_iteration_lookahead, nb=nb, **common)
        a = lax.fori_loop(0, nb, step, (a,) + first)[0]
    else:
        step = partial(_iteration, **common)
        a = lax.fori_loop(0, nb, step, a)
    return a[None]


def make_factorize(mesh, *, pg: int, nb: int, b: int,
                   comm=CommunicationType.ICI_DIRECT, schedule: str = "chain",
                   interpret: bool = True, lookahead: bool = False,
                   engine: CollectiveEngine = None):
    engine = engine or CollectiveEngine.for_mesh(mesh, comm, schedule,
                                                 interpret=interpret)
    spec = P(("rows", "cols"), None, None)
    fn = shard_map(
        partial(_hpl_body, pg=pg, nb=nb, b=b, engine=engine,
                interpret=interpret, lookahead=lookahead),
        mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False)
    return jax.jit(fn)


@register("hpl")
def run_hpl(mesh, comm=CommunicationType.ICI_DIRECT, *, n: int = 512,
            b: int = 64, schedule: str = "chain", reps: int = 2,
            interpret: bool = True, validate: bool = True,
            lookahead: bool = False) -> BenchResult:
    """mesh axes ('rows', 'cols'), P = Q (paper's quadratic torus).

    ``lookahead=True`` runs the overlapped factorization (paper Fig. 5/7);
    the LU output is bit-identical to eager mode under every bcast schedule.
    """
    pg = mesh.shape["rows"]
    assert mesh.shape["cols"] == pg, "paper requires a quadratic torus"
    nb = n // b
    assert nb % pg == 0, (n, b, pg)
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule,
                                       interpret=interpret)

    a, x_true, b_vec = generate_system(n)
    spec = NamedSharding(mesh, P(("rows", "cols"), None, None))
    a_sh = jax.device_put(distribute_cyclic(a, pg, b), spec)

    fact = make_factorize(mesh, pg=pg, nb=nb, b=b, engine=engine,
                          interpret=interpret, lookahead=lookahead)
    out, t = timeit(fact, a_sh, reps=reps)

    err = 0.0
    if validate:
        lu = undistribute_cyclic(np.asarray(out), pg, b)
        x = solve_from_lu(lu, b_vec)
        err = normalized_residual(a, x, b_vec)

    # resolved provenance: the *name the cost model picked* for the dominant
    # payload (the b x m row/column panels), never the literal "auto"
    panel_bytes = b * (nb // pg) * b * 4
    resolved = engine.schedule_for("bcast", nbytes=panel_bytes, axis="rows")
    return BenchResult(
        name="hpl", metric_name="GFLOP/s", metric=hpl_flops(n) / t / 1e9,
        error=err, times={"best": t},
        details={"n": n, "block": b, "grid": pg, "comm": engine.comm.value,
                 "schedule": resolved,
                 "schedule_requested": engine.schedule,
                 "bcast_bytes": panel_bytes,
                 "lookahead": lookahead})
