"""FFT — batched 1-D FFTs (paper legacy suite).

Embarrassingly parallel over devices; uses XLA's FFT (the paper's FFT kernel
is a legacy single-device design it did not modify; DESIGN.md §9 records why
no Pallas radix kernel is warranted). Metric: 5 N log2 N FLOPs per 1-D FFT.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit


@register("fft")
def run_fft(mesh, comm=CommunicationType.ICI_DIRECT, *, log_size: int = 12,
            batch_per_device: int = 64, reps: int = 3) -> BenchResult:
    n_dev = mesh.devices.size
    n = 1 << log_size
    batch = batch_per_device * n_dev
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (batch, n), jnp.float32)
         + 1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n), jnp.float32))
    x = jax.device_put(x.astype(jnp.complex64), NamedSharding(mesh, P("x", None)))

    fn = jax.jit(shard_map(lambda a: jnp.fft.fft(a, axis=-1), mesh=mesh,
                           in_specs=P("x", None), out_specs=P("x", None)))
    out, t = timeit(fn, x, reps=reps)

    ref = np.fft.fft(np.asarray(x[:2]), axis=-1)
    err = float(np.max(np.abs(np.asarray(out[:2]) - ref)) / np.max(np.abs(ref)))

    flops = 5.0 * n * math.log2(n) * batch
    return BenchResult(
        name="fft", metric_name="GFLOP/s", metric=flops / t / 1e9, error=err,
        times={"best": t},
        details={"log_size": log_size, "batch": batch, "devices": n_dev})
