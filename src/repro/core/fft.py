"""FFT — batched 1-D FFTs, local (legacy) and distributed (engine-routed).

**Local (legacy reference).** Embarrassingly parallel over devices; uses
XLA's FFT (the paper's FFT kernel is a legacy single-device design it did
not modify; DESIGN.md §9 records why no Pallas radix kernel is warranted).
Metric: 5 N log2 N FLOPs per 1-D FFT.

**Distributed (pencil decomposition).** The HPCC-adaptation work (Meyer et
al., arXiv:2004.11059) frames FFT as the all-to-all-bandwidth corner of the
suite: a signal too large for one device is pencil-decomposed and the
global transpose dominates. Here the input batch is sharded along the
*signal* axis (each device holds an ``(batch, n/P)`` pencil) and the
transform rides the :class:`~repro.comm.engine.CollectiveEngine`:

1. ``all_to_all_tiles`` under the ``fft.transpose`` tag re-lays the pencils
   out so each device holds ``batch/P`` *complete* signals;
2. the local transform is literally ``jnp.fft.fft`` over those full
   signals — which is what makes the distributed output **bitwise equal**
   to ``jnp.fft.fft`` applied at the same per-rank block shape, for every
   schedule × chunking (XLA's FFT is shape-deterministic but not
   row-independent across batch sizes, so the monolithic full-batch
   transform agrees to float32 FFT accuracy rather than in final bits);
3. the inverse exchange (tile axes swapped — the engine's a2a round-trip
   guarantee) restores the signal-sharded layout.

Why ``all_to_all_tiles`` and not ``grid_transpose``: the PTRANS-style
``grid_transpose`` partner exchange is only defined on square P=Q rank
grids (4 of 8 devices idle on the benchmark ring) and any 2-D block layout
shards *both* axes, so no rank ever holds a complete signal and the local
compute could not be ``jnp.fft.fft`` — bit-equivalence would be lost to
twiddle-factor reassociation. The layout-shuffle transpose above is the
1-D ring sibling of PTRANS's 2-D exchange; ``engine.pipelined`` strips the
per-signal frequency axis so chunk i's local FFT input lands while chunk
i+1 is on the wire.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.callsites import FFT_TRANSPOSE
from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit

CALLSITE = FFT_TRANSPOSE  # tuning-table tag for both pencil exchanges


@register("fft")
def run_fft(mesh, comm=CommunicationType.ICI_DIRECT, *, log_size: int = 12,
            batch_per_device: int = 64, reps: int = 3) -> BenchResult:
    n_dev = mesh.devices.size
    n = 1 << log_size
    batch = batch_per_device * n_dev
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (batch, n), jnp.float32)
         + 1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n), jnp.float32))
    x = jax.device_put(x.astype(jnp.complex64), NamedSharding(mesh, P("x", None)))

    fn = jax.jit(shard_map(lambda a: jnp.fft.fft(a, axis=-1), mesh=mesh,
                           in_specs=P("x", None), out_specs=P("x", None)))
    out, t = timeit(fn, x, reps=reps)

    # validate the FULL output (an earlier revision checked only the first
    # two rows — a sharding bug on any later device shard went unseen)
    ref = np.fft.fft(np.asarray(x), axis=-1)
    err = float(np.max(np.abs(np.asarray(out) - ref)) / np.max(np.abs(ref)))

    flops = 5.0 * n * math.log2(n) * batch
    return BenchResult(
        name="fft", metric_name="GFLOP/s", metric=flops / t / 1e9, error=err,
        times={"best": t},
        details={"log_size": log_size, "batch": batch, "devices": n_dev})


# ---------------------------------------------------------------------------
# distributed pencil FFT (engine-routed global transpose)
# ---------------------------------------------------------------------------


def _fft_dist_body(x_loc, *, engine: CollectiveEngine, nchunks: int = 1):
    # x_loc (B, ns): all batch rows, this rank's signal pencil
    buf = x_loc[:, None, :]  # (B, 1, ns) — tile dim for the exchange

    def exchange(b, tile_split, tile_concat):
        # gather (0 -> 1): rank r's batch-tile j -> rank j, concat over
        # sources = (B/P, P, ns): B/P complete signals in P pencil segments.
        # scatter (1 -> 0): tile axes swapped — the engine's exact-inverse
        # round-trip guarantee. nchunks > 1 strips the per-signal frequency
        # axis (axis 2), which rides through untouched, so chunking is
        # bitwise-free.
        if nchunks <= 1:
            return engine.all_to_all_tiles(b, "x", split_axis=tile_split,
                                           concat_axis=tile_concat,
                                           callsite=CALLSITE)
        return engine.pipelined("all_to_all_tiles", b, "x", nchunks=nchunks,
                                split_axis=2, concat_axis=2,
                                tile_split_axis=tile_split,
                                tile_concat_axis=tile_concat,
                                callsite=CALLSITE)

    gathered = exchange(buf, 0, 1)             # (B/P, P, ns)
    full = gathered.reshape(gathered.shape[0], -1)  # (B/P, n) full signals
    spec = jnp.fft.fft(full, axis=-1)          # the reference transform
    spec = spec.reshape(gathered.shape)
    out = exchange(spec, 1, 0)                 # (B, 1, ns)
    return out[:, 0, :]


def make_dist_step(mesh, engine: CollectiveEngine, *, nchunks: int = 1):
    """Jitted pencil FFT: input/output sharded along the signal axis
    (``P(None, 'x')``); both global transposes ride ``fft.transpose``."""
    fn = shard_map(
        partial(_fft_dist_body, engine=engine, nchunks=nchunks),
        mesh=mesh, in_specs=P(None, "x"), out_specs=P(None, "x"),
        check_vma=False)
    return jax.jit(fn)


@register("fft_dist")
def run_fft_dist(mesh, comm=CommunicationType.ICI_DIRECT, *,
                 log_size: int = 12, batch_per_device: int = 64,
                 reps: int = 3, schedule: str = "auto",
                 nchunks="auto") -> BenchResult:
    """Pencil-decomposed distributed FFT over the ``x`` ring. The signal
    axis is sharded; the engine's ``fft.transpose`` exchanges localize full
    signals, so the output is bitwise equal to ``jnp.fft.fft`` at the
    per-rank block shape on every schedule × chunking (``error`` is the
    full-output relative error vs ``np.fft.fft``)."""
    n_dev = mesh.devices.size
    n = 1 << log_size
    batch = batch_per_device * n_dev
    if batch % n_dev:
        raise ValueError(f"batch {batch} not divisible by {n_dev} devices")
    if n % n_dev:
        raise ValueError(
            f"signal length 2**{log_size} = {n} not divisible by "
            f"{n_dev} devices (pencil decomposition)")
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule)

    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (batch, n), jnp.float32)
         + 1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n),
                                  jnp.float32))
    x = x.astype(jnp.complex64)
    x_sh = jax.device_put(x, NamedSharding(mesh, P(None, "x")))

    payload = batch * (n // n_dev) * 8  # per-rank (B, 1, ns) complex64
    nchunks_requested = nchunks
    if nchunks == "auto":
        nchunks = engine.pipeline_chunks("all_to_all_tiles", nbytes=payload,
                                         axis="x", callsite=CALLSITE)
    nchunks = max(int(nchunks), 1)

    step = make_dist_step(mesh, engine, nchunks=nchunks)
    out, t = timeit(step, x_sh, reps=reps)

    ref = np.fft.fft(np.asarray(x), axis=-1)
    err = float(np.max(np.abs(np.asarray(out) - ref)) / np.max(np.abs(ref)))

    flops = 5.0 * n * math.log2(n) * batch
    resolved = engine.schedule_for("all_to_all_tiles", nbytes=payload,
                                   axis="x", callsite=CALLSITE)
    return BenchResult(
        name="fft_dist", metric_name="GFLOP/s", metric=flops / t / 1e9,
        error=err, times={"best": t},
        details={"log_size": log_size, "batch": batch, "devices": n_dev,
                 "comm": engine.comm.value, "schedule": resolved,
                 "schedule_requested": engine.schedule,
                 "nchunks": nchunks,
                 "nchunks_requested": nchunks_requested,
                 "exchange_bytes": payload})
