"""Analytical performance models — paper Eqs. 1-6 with pluggable hardware.

These are the paper's contribution on the modeling side; we keep them exact
for the 520N constants (validating our reproduction against the paper's own
Fig. 10 curves) and instantiate them with TPU v5e constants for the roofline
overlays in benchmarks/.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable

from repro.comm.types import (
    CHANNEL_FREQ_520N,
    CHANNEL_WIDTH_520N,
    HardwareModel,
    TPU_V5E,
)


def effective_bandwidth(bw_by_size: Dict[int, float]) -> float:
    """Paper Eq. 1: b_eff = sum_L max_rep b(L, rep) / #sizes. The caller
    passes the per-size best bandwidth."""
    return sum(bw_by_size.values()) / len(bw_by_size)


def beff_host_staged_model(L: int, hw: HardwareModel = TPU_V5E) -> float:
    """Paper Eq. 2: b_L = 2L / (pcie_write + mpi + pcie_read); sequential."""
    pcie = L / hw.pcie_bw
    mpi = L / hw.dcn_bw + hw.mpi_latency
    return 2 * L / (pcie + mpi + pcie)


def beff_csn_model_520n(L: int, channels_per_pair: int = 2) -> float:
    """Paper Eq. 3/4 with Table 2 constants: one send/recv kernel pair of the
    520N (b_L = 2L / (ceil(L / 64B) * 6.4 ns + 520 ns))."""
    cw = channels_per_pair * CHANNEL_WIDTH_520N  # bytes per cycle
    t = math.ceil(L / cw) / CHANNEL_FREQ_520N + 520e-9
    return 2 * L / t


def beff_ici_model(L: int, hw: HardwareModel = TPU_V5E) -> float:
    """TPU instantiation of Eq. 3: message streamed over one ICI link each
    direction, one hop of latency."""
    t = L / hw.ici_link_bw + hw.ici_latency
    return 2 * L / t


def ptrans_block_time(b: int, elem_bytes: int, hw: HardwareModel = TPU_V5E,
                      staged: bool = False) -> float:
    """Paper Eq. 5: t = t_comm + 3 * b^2 / (c_w * c_f). On TPU the '3x global
    memory traffic' term (Eq. 6) is b^2 * elem_bytes * 3 / hbm_bw."""
    block_bytes = b * b * elem_bytes
    if staged:
        t_comm = 2 * block_bytes / hw.pcie_bw + block_bytes / hw.dcn_bw \
            + hw.mpi_latency
    else:
        t_comm = block_bytes / hw.ici_link_bw + hw.ici_latency
    t_mem = 3 * block_bytes / hw.hbm_bw
    return t_comm + t_mem


def ptrans_required_hbm_bw(net_bw: float) -> float:
    """Paper Eq. 6: global-memory bandwidth must be 3x the network bandwidth
    for PTRANS to stay network-bound."""
    return 3.0 * net_bw


def hpl_flops(n: int) -> float:
    """HPL-AI rule: LU factorization work = 2/3 n^3."""
    return 2.0 * n ** 3 / 3.0


def hpl_strong_scaling_model(perf_per_dev_by_local_n: Dict[int, float],
                             n_global: int, devices: Iterable[int]) -> Dict[int, float]:
    """Paper Fig. 15 extrapolation: aggregate perf = d * perf(single device at
    local size n_global/sqrt(d)), interpolating the measured single-device
    curve."""
    import numpy as np
    xs = np.array(sorted(perf_per_dev_by_local_n))
    ys = np.array([perf_per_dev_by_local_n[x] for x in xs])
    out = {}
    for d in devices:
        n_local = n_global / math.sqrt(d)
        out[d] = float(d * np.interp(n_local, xs, ys))
    return out
