"""HPCC-JAX suite registry — the paper's Fig. 1 host architecture.

Every benchmark registers a ``run_*`` entry point that accepts a
``CommunicationType`` (and where meaningful a ``schedule``) and returns a
:class:`BenchResult`. The suite mirrors HPCC FPGA v0.5.1 + this paper's
additions: STREAM, RandomAccess, FFT, GEMM (legacy, multi-device), and
b_eff, PTRANS, LINPACK (new, communication-centric).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax


@dataclass
class BenchResult:
    name: str
    metric_name: str
    metric: float
    error: float = 0.0
    times: Dict[str, float] = field(default_factory=dict)
    details: Dict[str, object] = field(default_factory=dict)

    def row(self) -> str:
        return (f"{self.name},{self.metric_name},{self.metric:.6g},"
                f"err={self.error:.3g}")


_REGISTRY: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_benchmark(name: str) -> Callable:
    return _REGISTRY[name]


def list_benchmarks():
    return sorted(_REGISTRY)


def timeit(fn, *args, reps: int = 3, warmup: int = 1, **kw) -> tuple:
    """Best-of-reps wall time (paper: slowest rank per rep via barrier, best
    rep for the metric; single-process here, so plain best-of)."""
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return out, best
