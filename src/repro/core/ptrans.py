"""PTRANS — distributed matrix transposition C = B + A^T (paper §2.2).

Blocks are distributed block-cyclically over a P x P grid (the paper's PQ
scheme, Fig. 3, with P = Q as the circuit-switched implementation requires).
Each device stores its local blocks packed into one (lb*b, lb*b) matrix;
because the distribution is symmetric, the *entire* communication is a
single exchange with the grid-transpose partner, and the local compute is
one full-matrix transpose-add — tile(lj,li)^T lands at (li,lj) for both the
block index level and the within-block level at once.

The exchange routes through the :class:`~repro.comm.engine.CollectiveEngine`
``grid_transpose`` op:
* ``direct`` schedule under ICI_DIRECT — one ``ppermute`` over
  ('rows','cols') with the transpose permutation: a pure point-to-point
  circuit-switched exchange (paper §2.2.2).
* ``ring2d`` — dimension-ordered two-phase torus route (paper Fig. 8):
  row hops to the diagonal relay rank, then column hops to the transpose
  partner, using only physical torus links. Select with
  ``run_ptrans(..., schedule="ring2d")`` or ``--schedule ring2d`` /
  ``--sweep-schedules`` in the benchmark driver.
* ``staged`` (forced by HOST_STAGED) — all_gather over the full grid + local
  selection: every block transits the staging domain (paper §2.2.1 via
  PCIe+MPI).

Chunked (pipelined) exchange: ``run_ptrans(..., nchunks=S)`` splits the
local matrix into S row strips routed through
:meth:`~repro.comm.engine.CollectiveEngine.pipelined`, so the
``transpose_add`` of strip i overlaps the wire hops of strip i+1 — the
in-flight chunk pipeline the circuit-switched results rely on.
``nchunks="auto"`` (default) resolves S from the alpha-beta fill-cost model
(:func:`repro.comm.autotune.best_nchunks`); the result is bit-identical to
the monolithic exchange for every S (chunk boundaries only partition the
payload, and the transpose-add is elementwise).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.callsites import PTRANS_EXCHANGE
from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit
from repro.kernels.ops import transpose_add


# ---------------------------------------------------------------------------
# block-cyclic (de)distribution — shared with HPL
# ---------------------------------------------------------------------------


def distribute_cyclic(mat: np.ndarray, pg: int, b: int) -> np.ndarray:
    """(n, n) -> (pg*pg, m, m) stack of per-device local matrices. Global
    block (I, J) -> device (I%P, J%P), local tile (I//P, J//P)."""
    n = mat.shape[0]
    nb = n // b
    lb = nb // pg
    m = lb * b
    out = np.empty((pg * pg, m, m), mat.dtype)
    for gi in range(nb):
        for gj in range(nb):
            dev = (gi % pg) * pg + (gj % pg)
            li, lj = gi // pg, gj // pg
            out[dev, li * b:(li + 1) * b, lj * b:(lj + 1) * b] = \
                mat[gi * b:(gi + 1) * b, gj * b:(gj + 1) * b]
    return out


def undistribute_cyclic(shards: np.ndarray, pg: int, b: int) -> np.ndarray:
    nshards, m, _ = shards.shape
    lb = m // b
    nb = lb * pg
    n = nb * b
    out = np.empty((n, n), shards.dtype)
    for gi in range(nb):
        for gj in range(nb):
            dev = (gi % pg) * pg + (gj % pg)
            li, lj = gi // pg, gj // pg
            out[gi * b:(gi + 1) * b, gj * b:(gj + 1) * b] = \
                shards[dev, li * b:(li + 1) * b, lj * b:(lj + 1) * b]
    return out


# ---------------------------------------------------------------------------
# step
# ---------------------------------------------------------------------------


CALLSITE = PTRANS_EXCHANGE  # tuning-table tag for the partner exchange


def _ptrans_body(a_loc, b_loc, *, pg: int, engine: CollectiveEngine,
                 interpret: bool, nchunks: int = 1):
    a_loc, b_loc = a_loc[0], b_loc[0]
    if nchunks <= 1:
        recv = engine.grid_transpose(a_loc, ("rows", "cols"), pg,
                                     callsite=CALLSITE)
        out = transpose_add(recv, b_loc, interpret=interpret)
        return out[None]

    # strip-wise pipeline: row strip i of A lands, its transpose-add writes
    # column strip i of C while strip i+1 is still on the wire
    def consume(strip, start):
        b_cols = lax.slice_in_dim(b_loc, start, start + strip.shape[0],
                                  axis=1)
        return transpose_add(strip, b_cols, interpret=interpret)

    out = engine.pipelined("grid_transpose", a_loc, ("rows", "cols"),
                           pg=pg, nchunks=nchunks, split_axis=0,
                           concat_axis=1, consume=consume,
                           callsite=CALLSITE)
    return out[None]


def make_step(mesh, pg: int, engine: CollectiveEngine, interpret: bool = True,
              nchunks: int = 1):
    spec = P(("rows", "cols"), None, None)
    fn = shard_map(
        partial(_ptrans_body, pg=pg, engine=engine, interpret=interpret,
                nchunks=nchunks),
        mesh=mesh, in_specs=(spec, spec), out_specs=spec, check_vma=False)
    return jax.jit(fn)


@register("ptrans")
def run_ptrans(mesh, comm=CommunicationType.ICI_DIRECT, *, n: int = 1024,
               b: int = 128, reps: int = 3, interpret: bool = True,
               validate: bool = True, schedule: str = "auto",
               nchunks="auto") -> BenchResult:
    """mesh must have axes ('rows', 'cols') with equal sizes (P = Q).

    ``nchunks`` pipelines the exchange into that many row strips (1 =
    monolithic); ``"auto"`` resolves the chunk count from the alpha-beta
    fill-cost model. Bit-identical output for every value."""
    pg = mesh.shape["rows"]
    assert mesh.shape["cols"] == pg, "paper requires P = Q"
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule,
                                       interpret=interpret)
    rng = np.random.default_rng(42)
    a = rng.standard_normal((n, n), dtype=np.float32)
    bm = rng.standard_normal((n, n), dtype=np.float32)

    local_bytes = (n // pg) * (n // pg) * 4
    nchunks_requested = nchunks
    if nchunks == "auto":
        nchunks = engine.pipeline_chunks("grid_transpose",
                                         nbytes=local_bytes,
                                         axis=("rows", "cols"),
                                         callsite=CALLSITE)
    nchunks = max(int(nchunks), 1)

    spec = NamedSharding(mesh, P(("rows", "cols"), None, None))
    a_sh = jax.device_put(distribute_cyclic(a, pg, b), spec)
    b_sh = jax.device_put(distribute_cyclic(bm, pg, b), spec)

    step = make_step(mesh, pg, engine, interpret, nchunks=nchunks)
    out, t = timeit(step, a_sh, b_sh, reps=reps)

    err = 0.0
    if validate:
        c = undistribute_cyclic(np.asarray(out), pg, b)
        ref = bm + a.T
        err = float(np.max(np.abs(c - ref)))

    flops = float(n) * n  # paper: n^2 additions
    # resolved provenance: the cost model's pick for the actual per-device
    # exchange payload (the packed local matrix), never the literal "auto"
    resolved = engine.schedule_for("grid_transpose", nbytes=local_bytes,
                                   axis=("rows", "cols"), callsite=CALLSITE)
    return BenchResult(
        name="ptrans", metric_name="GFLOP/s", metric=flops / t / 1e9,
        error=err, times={"best": t},
        details={"n": n, "block": b, "grid": pg, "comm": engine.comm.value,
                 "schedule": resolved,
                 "schedule_requested": engine.schedule,
                 "nchunks": nchunks,
                 "nchunks_requested": nchunks_requested,
                 "exchange_bytes": local_bytes,
                 "bytes_exchanged": float(n) * n * 4})
