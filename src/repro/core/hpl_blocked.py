"""Single-device blocked right-looking LU (paper Fig. 13's per-FPGA sweep).

Same kernels as the distributed HPL, no communication: used for the
matrix-size performance sweep, for unit tests, and as the measured
single-device curve that feeds the strong-scaling extrapolation model
(paper Fig. 15)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hpcc import BenchResult, register, timeit
from repro.core.hpl import generate_system, normalized_residual, solve_from_lu
from repro.core.models import hpl_flops
from repro.kernels.ops import (gemm_update, lu_factor_block,
                               trsm_lower_left, trsm_upper_right)


def lu_blocked(a: jnp.ndarray, b: int, *, interpret: bool = True) -> jnp.ndarray:
    """In-place style blocked LU of (n, n) ``a`` with block size ``b``;
    returns packed L\\U. Python loop over diagonal blocks (static unroll)."""
    n = a.shape[0]
    nb = n // b
    for k in range(nb):
        o = k * b
        lu = lu_factor_block(jax.lax.dynamic_slice(a, (o, o), (b, b)),
                             interpret=interpret)
        a = jax.lax.dynamic_update_slice(a, lu, (o, o))
        rest = n - o - b
        if rest:
            row = jax.lax.dynamic_slice(a, (o, o + b), (b, rest))
            u = trsm_lower_left(lu, row, interpret=interpret)
            a = jax.lax.dynamic_update_slice(a, u, (o, o + b))
            col = jax.lax.dynamic_slice(a, (o + b, o), (rest, b))
            l = trsm_upper_right(lu, col, interpret=interpret)
            a = jax.lax.dynamic_update_slice(a, l, (o + b, o))
            trail = jax.lax.dynamic_slice(a, (o + b, o + b), (rest, rest))
            trail = gemm_update(trail, l, u, alpha=-1.0, interpret=interpret)
            a = jax.lax.dynamic_update_slice(a, trail, (o + b, o + b))
    return a


@register("hpl_single")
def run_hpl_single(mesh=None, comm=None, *, n: int = 512, b: int = 64,
                   reps: int = 2, interpret: bool = True,
                   validate: bool = True, schedule: str = "auto") -> BenchResult:
    # single device: no communication — ``schedule`` is accepted so the
    # drivers can pass one flag suite-wide; recorded as "local" in results.
    a, x_true, b_vec = generate_system(n)
    a_dev = jnp.asarray(a)
    fn = jax.jit(partial(lu_blocked, b=b, interpret=interpret))
    out, t = timeit(fn, a_dev, reps=reps)

    err = 0.0
    if validate:
        x = solve_from_lu(np.asarray(out), b_vec)
        err = normalized_residual(a, x, b_vec)

    return BenchResult(
        name="hpl_single", metric_name="GFLOP/s", metric=hpl_flops(n) / t / 1e9,
        error=err, times={"best": t},
        details={"n": n, "block": b, "schedule": "local"})
