"""Single-device blocked LU (paper Fig. 13's per-FPGA sweep).

Folded into a thin wrapper over the distributed factorization
(:mod:`repro.core.hpl`) on a 1 x 1 grid: one code path owns the blocked
right-looking algorithm, and the single-device sweep exercises exactly the
kernels and iteration structure the torus runs (all collective schedules
degenerate to identity on 1-rank axes). Used for the matrix-size
performance sweep, for unit tests, and as the measured single-device curve
that feeds the strong-scaling extrapolation model (paper Fig. 15).

Note the fold changes the *measured compute profile*: the distributed
trailing update is a masked full-matrix GEMM every iteration (the
block-cyclic layout interleaves the trailing submatrix, so slicing it out
is impossible for pg > 1 and the 1 x 1 grid inherits that), ~3x the
shrinking-submatrix FLOPs of the old standalone loop. Reported GFLOP/s
(still normalized by ``hpl_flops(n)``) drops accordingly versus pre-fold
artifacts, and the Fig. 15 curve is consistent with how the *distributed*
per-device work actually scales — which is what the extrapolation predicts."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core.hpcc import BenchResult, register, timeit
from repro.core.hpl import (generate_system, make_factorize,
                            normalized_residual, solve_from_lu)
from repro.core.models import hpl_flops


def lu_blocked(a: jnp.ndarray, b: int, *, interpret: bool = True) -> jnp.ndarray:
    """Blocked LU of (n, n) ``a`` with block size ``b``; returns packed
    L\\U. Thin wrapper: the distributed right-looking factorization on a
    1 x 1 grid (every broadcast is an identity, the trailing update's
    row/column masks reproduce the shrinking-submatrix sweep)."""
    n = a.shape[0]
    mesh = make_mesh((1, 1), ("rows", "cols"))
    fact = make_factorize(mesh, pg=1, nb=n // b, b=b, interpret=interpret)
    return fact(a[None])[0]


@register("hpl_single")
def run_hpl_single(mesh=None, comm=None, *, n: int = 512, b: int = 64,
                   reps: int = 2, interpret: bool = True,
                   validate: bool = True, schedule: str = "auto") -> BenchResult:
    # single device: no communication — ``schedule`` is accepted so the
    # drivers can pass one flag suite-wide; recorded as "local" in results.
    a, x_true, b_vec = generate_system(n)
    a_dev = jnp.asarray(a)
    fn = jax.jit(partial(lu_blocked, b=b, interpret=interpret))
    out, t = timeit(fn, a_dev, reps=reps)

    err = 0.0
    if validate:
        x = solve_from_lu(np.asarray(out), b_vec)
        err = normalized_residual(a, x, b_vec)

    return BenchResult(
        name="hpl_single", metric_name="GFLOP/s", metric=hpl_flops(n) / t / 1e9,
        error=err, times={"best": t},
        details={"n": n, "block": b, "schedule": "local"})
