"""HPCC-JAX: the paper's benchmark suite. Importing registers all benchmarks."""
from repro.core import beff, fft, gemm, hpl, hpl_blocked, ptrans  # noqa: F401
from repro.core import randomaccess, stream  # noqa: F401
from repro.core.hpcc import BenchResult, get_benchmark, list_benchmarks  # noqa: F401
