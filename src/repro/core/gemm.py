"""GEMM — C = alpha A B + beta C per device (paper legacy suite).

Embarrassingly parallel; per-device compute is the Pallas blocked matmul.
The paper normalizes to one kernel replication at 100 MHz with an 8x8x8
register tile (102.4 GFLOP/s theoretical); the TPU report normalizes to one
MXU at the roofline constants instead (benchmarks/legacy_suite.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit
from repro.kernels.ops import matmul


@register("gemm")
def run_gemm(mesh, comm=CommunicationType.ICI_DIRECT, *, m: int = 512,
             reps: int = 3, interpret: bool = True) -> BenchResult:
    n_dev = mesh.devices.size
    key = jax.random.PRNGKey(0)
    spec = NamedSharding(mesh, P("x", None, None))
    a = jax.device_put(
        jax.random.normal(key, (n_dev, m, m), jnp.float32) / np.sqrt(m), spec)
    b = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (n_dev, m, m), jnp.float32)
        / np.sqrt(m), spec)

    fn = jax.jit(shard_map(
        lambda x, y: matmul(x[0], y[0], bm=128, bn=128, bk=128,
                            interpret=interpret)[None],
        mesh=mesh, in_specs=(P("x", None, None),) * 2,
        out_specs=P("x", None, None), check_vma=False))
    out, t = timeit(fn, a, b, reps=reps)

    ref = np.asarray(a[0]) @ np.asarray(b[0])
    err = float(np.max(np.abs(np.asarray(out[0]) - ref)))

    flops = 2.0 * m ** 3 * n_dev
    return BenchResult(
        name="gemm", metric_name="GFLOP/s", metric=flops / t / 1e9, error=err,
        times={"best": t}, details={"m": m, "devices": n_dev})
