"""b_eff — effective bandwidth benchmark (paper §2.1).

Ring topology over all devices; message sizes 2^0 .. 2^max_log bytes are
exchanged with both ring neighbors simultaneously; the derived metric is
Eq. 1's effective bandwidth. The neighbor exchange routes through the
:class:`~repro.comm.engine.CollectiveEngine`:

* ``direct`` schedule under ICI_DIRECT — ``ppermute`` neighbor streams (the
  IEC/CSN implementation, paper Fig. 2: message chunks streamed to the
  neighbor, receive buffer forwarded to the send side for the next round via
  the carried state).
* ``staged`` (forced by HOST_STAGED) — every message transits the staging
  domain (PCIe+MPI path).

Verification follows the paper: the message is filled with byte value
``log2(size) mod 256`` and checked after the timed run.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core import models
from repro.core.hpcc import BenchResult, register, timeit


def _exchange_step(bufs, axis: str, engine: CollectiveEngine, rounds: int):
    """``rounds`` back-to-back bidirectional ring exchanges; the received
    buffers become the next round's send buffers (paper's internal-channel
    forwarding)."""
    def body(carry, _):
        fwd, bwd = carry
        recv_l, recv_r = engine.ring_exchange(fwd, bwd, axis)
        return (recv_l, recv_r), ()

    (fwd, bwd), _ = jax.lax.scan(body, bufs, None, length=rounds)
    return fwd, bwd


def make_step(mesh, engine: CollectiveEngine, rounds: int = 1):
    spec = P("x", None)
    fn = shard_map(
        partial(_exchange_step, axis="x", engine=engine, rounds=rounds),
        mesh=mesh, in_specs=((spec, spec),), out_specs=(spec, spec))
    return jax.jit(fn)


@register("b_eff")
def run_beff(mesh, comm=CommunicationType.ICI_DIRECT, *, max_log: int = 20,
             reps: int = 3, rounds: int = 4,
             schedule: str = "auto") -> BenchResult:
    """Measured b_eff over the devices of ``mesh`` (axis 'x')."""
    n = mesh.devices.size
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule)
    bw: Dict[int, float] = {}
    times: Dict[str, float] = {}
    error = 0.0
    step = make_step(mesh, engine, rounds)
    for lg in range(max_log + 1):
        L = 2 ** lg
        fill = np.uint8(lg % 256)
        host = np.full((n, L), fill, np.uint8)
        fwd = jax.device_put(jnp.asarray(host), jax.NamedSharding(mesh, P("x", None)))
        bwd = jax.device_put(jnp.asarray(host), jax.NamedSharding(mesh, P("x", None)))
        (ofwd, obwd), t = timeit(step, (fwd, bwd), reps=reps)
        # bytes on the wire per round: every rank sends L fwd + L bwd
        total = 2.0 * L * n * rounds
        bw[L] = total / t
        times[f"L={L}"] = t
        ok = bool(jnp.all(ofwd == fill) & jnp.all(obwd == fill))
        error += 0.0 if ok else 1.0
    beff = models.effective_bandwidth(bw)
    # resolved provenance at the largest message (the bandwidth-defining
    # regime), never the literal "auto"
    resolved = engine.schedule_for("ring_exchange", nbytes=2 ** max_log,
                                   axis="x")
    return BenchResult(
        name="b_eff", metric_name="effective_bandwidth_B/s", metric=beff,
        error=error, times=times,
        details={"bandwidth_by_size": bw, "devices": n,
                 "comm": engine.comm.value,
                 "schedule": resolved,
                 "schedule_requested": engine.schedule,
                 "rounds": rounds})
