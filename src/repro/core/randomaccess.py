"""RandomAccess (GUPS) — paper §2.4's scalable redesign, two ways.

**Drop-local (legacy reference).** The paper replicates the RNG so every
FPGA generates (a partition of) the full update sequence and a
shift-register filter applies only the updates whose addresses fall into
the local shard. Reproduced here: every device runs ``rngs_per_device``
xorshift streams covering a disjoint slice of the global sequence, computes
all addresses, and scatters only in-range updates into its table shard
(out-of-range lanes are dropped — zero communication, like the paper).

**Engine-routed (distributed GUPS).** The HPCC-adaptation work (Meyer et
al., arXiv:2004.11059) treats RandomAccess as the latency corner of the
suite: real GUPS forwards every update to the rank that owns its address.
:func:`make_routed_step` does that through the
:class:`~repro.comm.engine.CollectiveEngine`: each rank buckets its
generated updates by owning rank into a fixed-capacity ``(n_dev, C, 2)``
int32 buffer of ``(local_index, value)`` pairs (unused lanes carry the
out-of-range sentinel, so nothing is ever dropped), one
``all_to_all_tiles`` exchange under the ``ra.updates`` callsite tag routes
bucket ``d`` to rank ``d``, and a single scatter-add applies everything
that arrived. ``nchunks > 1`` strips the capacity axis through
``engine.pipelined`` so the scatter of strip i overlaps strip i+1's wire
hops — bit-identical to the monolithic exchange for every chunking.

Deviation: HPCC uses XOR updates; JAX scatter has no XOR combinator, so we
use additive updates and validate by applying the inverse sequence
(int32 addition wraps but still commutes and inverts exactly, so collisions
cancel) — equivalent error semantics, stricter validation than the paper's
1% tolerance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.callsites import RA_UPDATES
from repro.comm.engine import CollectiveEngine
from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit

# 32-bit variant of the HPCC LCG (JAX default disables x64; the generator is
# the same shift-xor structure on uint32 — period is shorter but far exceeds
# any benchmark run here). Documented deviation; table_log must be < 32.
POLY = np.uint32(0x7)

CALLSITE = RA_UPDATES  # tuning-table tag for the update-routing exchange


def _xorshift_step(x):
    """HPCC-style LCG: x_{i+1} = (x << 1) ^ (msb(x) ? POLY : 0)."""
    x = x.astype(jnp.uint32)
    shifted = x << jnp.uint32(1)
    high = (x >> jnp.uint32(31)) & jnp.uint32(1)
    return shifted ^ (high * jnp.uint32(POLY))


def _gen_updates(seed: jnp.ndarray, count: int) -> jnp.ndarray:
    def body(x, _):
        x = _xorshift_step(x)
        return x, x
    _, xs = lax.scan(body, seed, None, length=count)
    return xs


def _ra_body(table, seeds, *, updates_per_rng: int, table_log: int,
             sign: int):
    seeds = seeds[0]  # (rngs,) — leading device dim from P('x', None)
    local_size = table.shape[0]
    idx = lax.axis_index("x")
    lo = idx.astype(jnp.uint32) * jnp.uint32(local_size)

    vals = jax.vmap(lambda s: _gen_updates(s, updates_per_rng))(seeds)
    vals = vals.reshape(-1)
    addr = vals & jnp.uint32((1 << table_log) - 1)
    local = (addr - lo).astype(jnp.int32)
    in_range = (addr >= lo) & (addr < lo + jnp.uint32(local_size))
    local = jnp.where(in_range, local, local_size)  # dropped lane
    upd = jnp.where(in_range, vals.astype(jnp.int32) * sign, 0)
    table = table.at[local].add(upd, mode="drop")
    return table


def make_step(mesh, *, updates_per_rng: int, table_log: int, sign: int = 1):
    fn = shard_map(
        partial(_ra_body, updates_per_rng=updates_per_rng,
                table_log=table_log, sign=sign),
        mesh=mesh, in_specs=(P("x"), P("x", None)), out_specs=P("x"))
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# engine-routed distributed GUPS
# ---------------------------------------------------------------------------


def _bucket_updates(vals, *, table_log: int, local_size: int, n_dev: int,
                    sign: int):
    """Bucket a rank's raw xorshift values by owning rank.

    Returns an ``(n_dev, C, 2)`` int32 buffer (C = number of values): row
    ``d`` holds the ``(local_index, signed_value)`` pairs destined for rank
    ``d``, densely packed from slot 0; unused slots carry the sentinel
    local index ``local_size`` (out of range — the receiver's
    ``mode="drop"`` scatter ignores them) and value 0. C is the worst-case
    capacity (every update could target one rank), so no update is ever
    dropped — the routed path is exact.
    """
    c = vals.shape[0]
    addr = (vals & jnp.uint32((1 << table_log) - 1)).astype(jnp.int32)
    dest = addr // local_size
    local_idx = addr % local_size
    upd = vals.astype(jnp.int32) * sign

    def bucket(d):
        m = dest == d
        # dense slot within bucket d; non-members park at index C (dropped)
        slot = jnp.where(m, jnp.cumsum(m) - 1, c)
        loc = jnp.full((c,), local_size, jnp.int32).at[slot].set(
            local_idx, mode="drop")
        val = jnp.zeros((c,), jnp.int32).at[slot].set(upd, mode="drop")
        return loc, val

    locs, vals_out = jax.vmap(bucket)(jnp.arange(n_dev))
    return jnp.stack([locs, vals_out], axis=-1)


def _ra_routed_body(table, seeds, *, updates_per_rng: int, table_log: int,
                    n_dev: int, sign: int, engine: CollectiveEngine,
                    nchunks: int = 1):
    seeds = seeds[0]
    local_size = table.shape[0]

    vals = jax.vmap(lambda s: _gen_updates(s, updates_per_rng))(seeds)
    buf = _bucket_updates(vals.reshape(-1), table_log=table_log,
                          local_size=local_size, n_dev=n_dev, sign=sign)
    if nchunks <= 1:
        recv = engine.all_to_all_tiles(buf, "x", split_axis=0,
                                       concat_axis=0, callsite=CALLSITE)
    else:
        # strip the capacity axis: each landed strip's scatter could overlap
        # the next strip's wire hops; tile axes (0 -> 0) stay the exchange's
        recv = engine.pipelined("all_to_all_tiles", buf, "x",
                                nchunks=nchunks, split_axis=1,
                                concat_axis=1, tile_split_axis=0,
                                tile_concat_axis=0, callsite=CALLSITE)
    table = table.at[recv[..., 0].reshape(-1)].add(
        recv[..., 1].reshape(-1), mode="drop")
    return table


def make_routed_step(mesh, engine: CollectiveEngine, *,
                     updates_per_rng: int, table_log: int, sign: int = 1,
                     nchunks: int = 1):
    """Jitted engine-routed GUPS step: generate, bucket, exchange under
    ``ra.updates``, scatter-add. Unlike :func:`make_step` every generated
    update is applied (on its owning rank) — the distributed benchmark."""
    n_dev = mesh.devices.size
    fn = shard_map(
        partial(_ra_routed_body, updates_per_rng=updates_per_rng,
                table_log=table_log, n_dev=n_dev, sign=sign, engine=engine,
                nchunks=nchunks),
        mesh=mesh, in_specs=(P("x"), P("x", None)), out_specs=P("x"),
        check_vma=False)
    return jax.jit(fn)


def _make_table_and_seeds(mesh, *, table_log: int, rngs_per_device: int):
    n_dev = mesh.devices.size
    size = 1 << table_log
    if size % n_dev:
        raise ValueError(
            f"table size 2**{table_log} = {size} not divisible by "
            f"{n_dev} devices")
    rng = np.random.default_rng(3)
    init = rng.integers(1, 2 ** 30, size, dtype=np.int32)
    table = jax.device_put(jnp.asarray(init), NamedSharding(mesh, P("x")))
    # disjoint RNG seeds per (device, rng) — the paper's "sub-part of the
    # random number sequence" per replication
    seeds = rng.integers(1, 2 ** 30, (n_dev, rngs_per_device),
                         dtype=np.uint32)
    seeds_sh = jax.device_put(jnp.asarray(seeds),
                              NamedSharding(mesh, P("x", None)))
    return table, seeds_sh


@register("randomaccess")
def run_randomaccess(mesh, comm=CommunicationType.ICI_DIRECT, *,
                     table_log: int = 20, rngs_per_device: int = 4,
                     updates_per_rng: int = 4096, reps: int = 2) -> BenchResult:
    n_dev = mesh.devices.size
    size = 1 << table_log
    table, seeds_sh = _make_table_and_seeds(
        mesh, table_log=table_log, rngs_per_device=rngs_per_device)

    fwd = make_step(mesh, updates_per_rng=updates_per_rng,
                    table_log=table_log, sign=+1)
    inv = make_step(mesh, updates_per_rng=updates_per_rng,
                    table_log=table_log, sign=-1)

    out, t = timeit(fwd, table, seeds_sh, reps=reps)
    restored = inv(out, seeds_sh)
    err = float(jnp.sum(restored != table)) / size

    total_updates = float(n_dev * rngs_per_device * updates_per_rng)
    return BenchResult(
        name="randomaccess", metric_name="GUPS", metric=total_updates / t / 1e9,
        error=err, times={"best": t},
        details={"table_log": table_log, "devices": n_dev,
                 "rngs_per_device": rngs_per_device,
                 "updates": total_updates})


@register("randomaccess_dist")
def run_randomaccess_dist(mesh, comm=CommunicationType.ICI_DIRECT, *,
                          table_log: int = 20, rngs_per_device: int = 4,
                          updates_per_rng: int = 4096, reps: int = 2,
                          schedule: str = "auto",
                          nchunks="auto") -> BenchResult:
    """Engine-routed GUPS over the mesh's ``x`` ring: every update is
    forwarded to its owning rank through ``all_to_all_tiles`` under the
    ``ra.updates`` tag. Validated by exact inverse-sequence restore
    (``error`` is the fraction of mismatched table words — 0.0 on every
    schedule × chunking)."""
    n_dev = mesh.devices.size
    size = 1 << table_log
    engine = CollectiveEngine.for_mesh(mesh, comm, schedule)
    table, seeds_sh = _make_table_and_seeds(
        mesh, table_log=table_log, rngs_per_device=rngs_per_device)

    cap = rngs_per_device * updates_per_rng
    payload = n_dev * cap * 2 * 4  # (n_dev, C, 2) int32 per rank
    nchunks_requested = nchunks
    if nchunks == "auto":
        nchunks = engine.pipeline_chunks("all_to_all_tiles", nbytes=payload,
                                         axis="x", callsite=CALLSITE)
    nchunks = max(int(nchunks), 1)

    fwd = make_routed_step(mesh, engine, updates_per_rng=updates_per_rng,
                           table_log=table_log, sign=+1, nchunks=nchunks)
    inv = make_routed_step(mesh, engine, updates_per_rng=updates_per_rng,
                           table_log=table_log, sign=-1, nchunks=nchunks)

    out, t = timeit(fwd, table, seeds_sh, reps=reps)
    restored = inv(out, seeds_sh)
    err = float(jnp.sum(restored != table)) / size

    total_updates = float(n_dev * rngs_per_device * updates_per_rng)
    resolved = engine.schedule_for("all_to_all_tiles", nbytes=payload,
                                   axis="x", callsite=CALLSITE)
    return BenchResult(
        name="randomaccess_dist", metric_name="GUPS",
        metric=total_updates / t / 1e9, error=err, times={"best": t},
        details={"table_log": table_log, "devices": n_dev,
                 "rngs_per_device": rngs_per_device,
                 "updates": total_updates, "comm": engine.comm.value,
                 "schedule": resolved,
                 "schedule_requested": engine.schedule,
                 "nchunks": nchunks,
                 "nchunks_requested": nchunks_requested,
                 "exchange_bytes": payload})
