"""RandomAccess (GUPS) — paper §2.4's scalable redesign.

The paper replicates the RNG so every FPGA generates (a partition of) the
full update sequence and a shift-register filter applies only the updates
whose addresses fall into the local shard. Reproduced here: every device
runs ``rngs_per_device`` xorshift streams covering a disjoint slice of the
global sequence, computes all addresses, and scatters only in-range updates
into its table shard (out-of-range lanes are dropped — zero communication,
like the paper).

Deviation: HPCC uses XOR updates; JAX scatter has no XOR combinator, so we
use additive updates and validate by applying the inverse sequence
(addition commutes, so collisions cancel exactly) — equivalent error
semantics, stricter validation than the paper's 1% tolerance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.types import CommunicationType
from repro.compat import shard_map
from repro.core.hpcc import BenchResult, register, timeit

# 32-bit variant of the HPCC LCG (JAX default disables x64; the generator is
# the same shift-xor structure on uint32 — period is shorter but far exceeds
# any benchmark run here). Documented deviation; table_log must be < 32.
POLY = np.uint32(0x7)


def _xorshift_step(x):
    """HPCC-style LCG: x_{i+1} = (x << 1) ^ (msb(x) ? POLY : 0)."""
    x = x.astype(jnp.uint32)
    shifted = x << jnp.uint32(1)
    high = (x >> jnp.uint32(31)) & jnp.uint32(1)
    return shifted ^ (high * jnp.uint32(POLY))


def _gen_updates(seed: jnp.ndarray, count: int) -> jnp.ndarray:
    def body(x, _):
        x = _xorshift_step(x)
        return x, x
    _, xs = lax.scan(body, seed, None, length=count)
    return xs


def _ra_body(table, seeds, *, updates_per_rng: int, table_log: int,
             n_dev: int, sign: int):
    seeds = seeds[0]  # (rngs,) — leading device dim from P('x', None)
    local_size = table.shape[0]
    idx = lax.axis_index("x")
    lo = idx.astype(jnp.uint32) * jnp.uint32(local_size)

    vals = jax.vmap(lambda s: _gen_updates(s, updates_per_rng))(seeds)
    vals = vals.reshape(-1)
    addr = vals & jnp.uint32((1 << table_log) - 1)
    local = (addr - lo).astype(jnp.int32)
    in_range = (addr >= lo) & (addr < lo + jnp.uint32(local_size))
    local = jnp.where(in_range, local, local_size)  # dropped lane
    upd = jnp.where(in_range, vals.astype(jnp.int32) * sign, 0)
    table = table.at[local].add(upd, mode="drop")
    return table


def make_step(mesh, *, updates_per_rng: int, table_log: int, sign: int = 1):
    n_dev = mesh.devices.size
    fn = shard_map(
        partial(_ra_body, updates_per_rng=updates_per_rng,
                table_log=table_log, n_dev=n_dev, sign=sign),
        mesh=mesh, in_specs=(P("x"), P("x", None)), out_specs=P("x"))
    return jax.jit(fn)


@register("randomaccess")
def run_randomaccess(mesh, comm=CommunicationType.ICI_DIRECT, *,
                     table_log: int = 20, rngs_per_device: int = 4,
                     updates_per_rng: int = 4096, reps: int = 2) -> BenchResult:
    n_dev = mesh.devices.size
    size = 1 << table_log
    assert size % n_dev == 0
    rng = np.random.default_rng(3)
    init = rng.integers(1, 2 ** 30, size, dtype=np.int32)
    spec = NamedSharding(mesh, P("x"))
    table = jax.device_put(jnp.asarray(init), spec)

    # disjoint RNG seeds per (device, rng) — the paper's "sub-part of the
    # random number sequence" per replication
    seeds = rng.integers(1, 2 ** 30, (n_dev, rngs_per_device), dtype=np.uint32)
    seeds_sh = jax.device_put(jnp.asarray(seeds),
                              NamedSharding(mesh, P("x", None)))

    fwd = make_step(mesh, updates_per_rng=updates_per_rng,
                    table_log=table_log, sign=+1)
    inv = make_step(mesh, updates_per_rng=updates_per_rng,
                    table_log=table_log, sign=-1)

    out, t = timeit(fwd, table, seeds_sh, reps=reps)
    restored = inv(out, seeds_sh)
    err = float(jnp.sum(restored != table)) / size

    total_updates = float(n_dev * rngs_per_device * updates_per_rng)
    return BenchResult(
        name="randomaccess", metric_name="GUPS", metric=total_updates / t / 1e9,
        error=err, times={"best": t},
        details={"table_log": table_log, "devices": n_dev,
                 "rngs_per_device": rngs_per_device,
                 "updates": total_updates})
