"""Synthetic sharded LM data pipeline — deterministic, resumable, elastic.

Design for 1000+ nodes (DESIGN.md §7): every batch is a pure function of
``(seed, step, shard_index, num_shards)`` via counter-based RNG (numpy
Philox). No data files, no coordination: a restarted or re-sharded worker
regenerates exactly its shard of any step. The iterator's only state is the
integer step — checkpointing data-state is trivially the step counter.

The token stream is a *learnable* synthetic language: a fixed random Markov
chain (per seed) over the vocab with a skewed transition table, plus periodic
copy motifs. Cross-entropy under this distribution is well below uniform, so
training examples show a real, visibly decreasing loss curve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    branching: int = 8          # out-degree of the Markov chain
    motif_len: int = 16         # copy-motif period (0 disables)


class SyntheticLMDataset:
    """Deterministic synthetic LM token stream, shardable by batch row."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # the "language": each token has `branching` likely successors with
        # Zipf-ish weights; built once per seed, identical on every worker.
        rng = np.random.default_rng(np.random.PCG64(cfg.seed))
        V = cfg.vocab_size
        self._succ = rng.integers(0, V, size=(V, cfg.branching), dtype=np.int32)
        w = 1.0 / np.arange(1, cfg.branching + 1)
        self._w = (w / w.sum()).astype(np.float64)

    # -- core: batch as a pure function of (step, shard) ---------------------
    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0, (cfg.global_batch, num_shards)
        rows = cfg.global_batch // num_shards
        # counter-based: key = (seed, step, shard); no sequential state
        rng = np.random.default_rng(
            np.random.Philox(key=cfg.seed, counter=[step, shard, 0, 0]))
        B, S, V = rows, cfg.seq_len, cfg.vocab_size

        tokens = np.empty((B, S), np.int32)
        tokens[:, 0] = rng.integers(0, V, size=B)
        choices = rng.choice(cfg.branching, size=(B, S), p=self._w)
        for t in range(1, S):
            tokens[:, t] = self._succ[tokens[:, t - 1], choices[:, t]]
        if cfg.motif_len and S >= 2 * cfg.motif_len:
            # splice copy motifs: second half of each motif window repeats the
            # first half -> learnable induction pattern
            m = cfg.motif_len
            for start in range(0, S - 2 * m + 1, 4 * m):
                tokens[:, start + m:start + 2 * m] = tokens[:, start:start + m]
        return {"tokens": tokens}

    def entropy_floor(self) -> float:
        """Cross-entropy of the true chain (nats) — the loss floor."""
        return float(-(self._w * np.log(self._w)).sum())


def make_batch_iterator(cfg: DataConfig, *, start_step: int = 0,
                        shard: int = 0, num_shards: int = 1) -> Iterator:
    """Resumable iterator: yields (step, batch) from ``start_step``."""
    ds = SyntheticLMDataset(cfg)
    step = start_step
    while True:
        yield step, ds.batch(step, shard, num_shards)
        step += 1
