"""Mesh construction. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 (one v5e pod, 256 chips) or
    2x16x16 (two pods, 512 chips; the 'pod' axis is the DCN/host-staged
    domain — the paper's PCIe+MPI network)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    return _make_mesh(shape, axes)


def make_local_mesh(axes=("data", "model")):
    """Best-effort mesh over however many local devices exist (tests/benches)."""
    n = len(jax.devices())
    if len(axes) == 1:
        return _make_mesh((n,), tuple(axes))
    # squarest 2-way factorization
    a = int(np.floor(np.sqrt(n)))
    while n % a:
        a -= 1
    return _make_mesh((n // a, a), tuple(axes))


def make_ring_mesh(name: str = "x"):
    n = len(jax.devices())
    return _make_mesh((n,), (name,))


def make_torus_mesh(pg: int, names=("rows", "cols")):
    return _make_mesh((pg, pg), tuple(names))
