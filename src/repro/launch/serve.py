"""Serving launcher: batched generation with prefill + decode steps.

``python -m repro.launch.serve --arch llama3-8b --requests 8``

Serves the reduced config on local devices: builds a request batch, runs one
prefill, then streams decode steps — the same two jitted functions the
decode_* dry-run cells lower at production shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced
from repro.models.model import build_model
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.requests, args.prompt_len)),
                          jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.requests, cfg.num_patches,
                                 cfg.vision_dim)), jnp.float32)
    if cfg.is_encoder_decoder:
        extras["frames"] = jnp.asarray(
            rng.standard_normal((args.requests, cfg.audio_ctx, cfg.d_model)),
            jnp.float32)

    t0 = time.perf_counter()
    out = generate(model, params, prompts, max_new_tokens=args.max_new,
                   temperature=args.temperature, extras=extras)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    new_tokens = args.requests * args.max_new
    print(f"arch={args.arch} batch={args.requests} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(out[0])[:args.prompt_len + 8])


if __name__ == "__main__":
    main()
