"""Serving launcher: continuous-batching generation over the paged cache.

``python -m repro.launch.serve --arch llama3-8b --requests 8``

Serves the reduced config on local devices through
:class:`repro.serve.ServeEngine`: requests with mixed prompt lengths are
queued, admitted under a per-step prefill-token budget, prefilled into the
paged KV cache, and decoded as one continuously-batched stream with slots
recycled on EOS / max-new. ``--mode explicit`` routes the per-token
collectives through the engine (``decode.*`` callsites) on an explicit
``shard_map`` decode; ``--legacy`` keeps the old whole-batch
``generate`` loop (and is the fallback for model families the paged cache
does not cover).
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced
from repro.models.model import build_model
from repro.train.serve import generate


def _legacy(model, params, cfg, args):
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.requests, args.prompt_len)),
                          jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.requests, cfg.num_patches,
                                 cfg.vision_dim)), jnp.float32)
    if cfg.is_encoder_decoder:
        extras["frames"] = jnp.asarray(
            rng.standard_normal((args.requests, cfg.audio_ctx, cfg.d_model)),
            jnp.float32)

    t0 = time.perf_counter()
    out = generate(model, params, prompts, max_new_tokens=args.max_new,
                   temperature=args.temperature, extras=extras)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    new_tokens = args.requests * args.max_new
    print(f"arch={args.arch} batch={args.requests} prompt={args.prompt_len} "
          f"new={args.max_new} [legacy generate]")
    print(f"generated {new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(out[0])[:args.prompt_len + 8])


def _paged(model, params, cfg, args):
    from repro.compat import make_mesh
    from repro.launch.train import parse_fault_args
    from repro.models.kvcache import PagedCacheConfig
    from repro.serve import ServeEngine

    fault = parse_fault_args(args.fault_schedule, args.fail_rank)

    max_seq = args.prompt_len + args.max_new
    slots = max(min(args.requests, len(jax.devices()) * 2), 1)
    mesh = None
    if args.mode == "explicit":
        # The head/expert exchange needs the axis size to divide every
        # exchanged dimension, so shrink the mesh to the largest divisor
        # the reduced config supports.
        n = math.gcd(len(jax.devices()), cfg.num_heads)
        n = math.gcd(n, cfg.num_kv_heads)
        if getattr(cfg, "num_experts", 0):
            n = math.gcd(n, cfg.num_experts)
        mesh = make_mesh((n,), ("x",))
        slots = max(slots // n, 1) * n
    pcfg = PagedCacheConfig(
        page_size=args.page_size,
        num_pages=slots * (-(-max_seq // args.page_size)) * 2,
        max_slots=slots, max_seq=max_seq)
    eng = ServeEngine(model, params, pcfg, mode=args.mode, mesh=mesh,
                      schedule=args.schedule,
                      prefill_token_budget=args.prefill_budget,
                      eos_id=args.eos_id, temperature=args.temperature,
                      preempt=args.preempt,
                      admission_retries=args.admission_retries,
                      fault_schedule=fault)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=(int(rng.integers(args.prompt_len // 2,
                                                   args.prompt_len + 1)),)
                            ).astype(np.int32)
               for _ in range(args.requests)]
    for p in prompts:
        eng.submit(p, args.max_new, deadline_s=args.deadline_s)
    t0 = time.perf_counter()
    out, stats = eng.run(collect_stats=True)
    dt = time.perf_counter() - t0
    new_tokens = sum(out[r].shape[0] - p.shape[0]
                     for r, p in enumerate(prompts))
    decode_steps = [s["decode_s"] for s in stats if s["decode_tokens"]]
    print(f"arch={args.arch} mode={args.mode} requests={args.requests} "
          f"slots={pcfg.max_slots} pages={pcfg.num_pages}x{pcfg.page_size}")
    print(f"generated {new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens / dt:.1f} tok/s incl. compile) over "
          f"{len(stats)} steps ({len(decode_steps)} decode batches)")
    if decode_steps:
        lat = np.sort(decode_steps)
        print(f"decode-step latency p50={lat[len(lat) // 2] * 1e3:.2f}ms "
              f"p99={lat[min(int(len(lat) * 0.99), len(lat) - 1)] * 1e3:.2f}ms")
    degraded = {k: sum(s.get(k, 0) for s in stats)
                for k in ("preempted", "timeouts", "rejected", "drained")}
    if any(degraded.values()):
        print("degradation: " + " ".join(f"{k}={v}"
                                         for k, v in degraded.items()))
    print("first sequence:", out[0][:prompts[0].shape[0] + 8])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mode", choices=("gspmd", "explicit"), default="gspmd")
    ap.add_argument("--schedule", default=None,
                    help="override the decode collectives' schedule "
                         "(explicit mode)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=512)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--preempt", action="store_true",
                    help="evict the youngest active request (tokens kept, "
                         "re-prefilled) when the head cannot get pages")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline; expired requests "
                         "finish with reason 'timeout'")
    ap.add_argument("--admission-retries", type=int, default=256,
                    help="failed admission attempts before the queue head "
                         "is rejected")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="scripted fault timeline applied per serve step "
                         "(repro.comm.faults.FaultSchedule.parse), e.g. "
                         "'delay@5-20:seconds=0.05,callsite=serve.step'")
    ap.add_argument("--fail-rank", default=None, metavar="RANK@STEP",
                    help="shorthand: lose device RANK at serve step STEP — "
                         "requests with KV pages on it drain and re-prefill "
                         "on surviving pages")
    ap.add_argument("--legacy", action="store_true",
                    help="whole-batch generate loop instead of the "
                         "continuous-batching engine")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    paged_ok = (not cfg.is_encoder_decoder
                and all(k == "attn" for k in cfg.layer_kinds()))
    if args.legacy or not paged_ok:
        _legacy(model, params, cfg, args)
    else:
        _paged(model, params, cfg, args)


if __name__ == "__main__":
    main()
