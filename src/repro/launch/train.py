"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant loop (repro.train.loop) on the local devices with a
reduced or full config. On a real cluster each host runs this same entry
point under ``jax.distributed.initialize`` (the mesh helper and data pipeline
are already multi-host safe: batches are pure functions of (seed, step,
shard) and checkpoint writes are per-shard).
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import RunConfig, get_config, list_archs, reduced
from repro.data import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.train.loop import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced same-family config (CPU-sized)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--comm", default="ici_direct",
                    choices=["ici_direct", "host_staged"])
    ap.add_argument("--mesh", action="store_true",
                    help="use a (data, model) mesh over local devices")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    run = RunConfig(comm_type=args.comm, microbatches=args.microbatches,
                    remat=args.remat, learning_rate=args.lr,
                    warmup_steps=max(args.steps // 10, 1),
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch,
                      seq_len=args.seq)
    mesh = make_local_mesh() if args.mesh else None

    hist = train_loop(cfg, run, data, TrainLoopConfig(steps=args.steps),
                      mesh=mesh)
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(first {hist['loss'][0]:.4f}); "
          f"median step {sorted(hist['step_time'])[len(hist['step_time'])//2]:.3f}s")
    print("straggler summary:", hist["straggler"])


if __name__ == "__main__":
    main()
