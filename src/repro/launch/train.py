"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant loop (repro.train.loop) on the local devices with a
reduced or full config. On a real cluster each host runs this same entry
point under ``jax.distributed.initialize`` (the mesh helper and data pipeline
are already multi-host safe: batches are pure functions of (seed, step,
shard) and checkpoint writes are per-shard).
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import RunConfig, get_config, list_archs, reduced
from repro.data import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.train.loop import (TrainLoopConfig, train_loop,
                              train_loop_elastic)


def parse_fault_args(fault_schedule, fail_rank):
    """Build the FaultSchedule a launcher's fault flags describe.

    ``fault_schedule`` is the :meth:`FaultSchedule.parse` spec string
    (``action@start[-end]:k=v,...`` separated by ``;``); ``fail_rank`` is
    the ``RANK@STEP`` shorthand appended to it as a rank-loss event.
    Returns None when neither flag is set.
    """
    if not fault_schedule and not fail_rank:
        return None
    from repro.comm.faults import FaultInjector, FaultSchedule
    spec = fault_schedule or ""
    if fail_rank:
        try:
            rank, at = fail_rank.split("@")
            part = f"fail_rank@{int(at)}:rank={int(rank)}"
        except ValueError:
            raise SystemExit(f"--fail-rank wants RANK@STEP, got "
                             f"{fail_rank!r}") from None
        spec = f"{spec};{part}" if spec else part
    return FaultSchedule.parse(FaultInjector(), spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced same-family config (CPU-sized)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--comm", default="ici_direct",
                    choices=["ici_direct", "host_staged"])
    ap.add_argument("--mesh", action="store_true",
                    help="use a (data, model) mesh over local devices")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="scripted fault timeline "
                         "(repro.comm.faults.FaultSchedule.parse), e.g. "
                         "'degrade@5-20:axis=x,hop=1,beta_scale=64' or "
                         "'down@5-20:axis=x,hop=3;fail_rank@12:rank=3'")
    ap.add_argument("--fail-rank", default=None, metavar="RANK@STEP",
                    help="shorthand: lose device RANK at step STEP and "
                         "resume elastically on the survivors (needs "
                         "--mesh and --checkpoint-dir)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    run = RunConfig(comm_type=args.comm, microbatches=args.microbatches,
                    remat=args.remat, learning_rate=args.lr,
                    warmup_steps=max(args.steps // 10, 1),
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every)
    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch,
                      seq_len=args.seq)
    fault = parse_fault_args(args.fault_schedule, args.fail_rank)
    elastic = fault is not None and any(e.action == "fail_rank"
                                        for e in fault.events)
    # elastic recovery rebuilds a 1-D mesh on the survivors, so a rank-loss
    # timeline runs on the ring layout rather than the 2-D (data, model) one
    mesh = (make_local_mesh(("x",)) if elastic else make_local_mesh()) \
        if args.mesh else None

    lcfg = TrainLoopConfig(steps=args.steps, fault_schedule=fault)
    if elastic:
        if mesh is None or not args.checkpoint_dir:
            raise SystemExit("a fail_rank timeline needs --mesh and "
                             "--checkpoint-dir (elastic resume restores "
                             "the resharded checkpoint)")
        hist, recovery = train_loop_elastic(cfg, run, data, lcfg, mesh=mesh)
        if recovery is not None:
            print(f"recovered from rank loss {recovery['lost_ranks']} at "
                  f"step {recovery['fail_step']}: resumed on "
                  f"{recovery['new_size']}/{recovery['old_size']} devices "
                  f"from step {recovery['resume_step']} in "
                  f"{recovery['recovery_s']:.2f}s")
    else:
        hist = train_loop(cfg, run, data, lcfg, mesh=mesh)
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(first {hist['loss'][0]:.4f}); "
          f"median step {sorted(hist['step_time'])[len(hist['step_time'])//2]:.3f}s")
    print("straggler summary:", hist["straggler"])


if __name__ == "__main__":
    main()
