import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run profiler: attribute roofline terms to HLO instructions.

``python -m repro.launch.analyze --arch qwen1.5-32b --shape decode_32k``

Prints the top memory/collective/flop contributors with their loop
multipliers and the ``op_name`` metadata (which names the jax source op) —
this is the "profile" the §Perf hypothesis loop reads, in lieu of a
wall-clock trace on real hardware.
"""
import argparse
import re
from typing import List

import jax

from repro import roofline as rl


def attribute(hlo_text: str, top: int = 25):
    comps = rl._split_computations(hlo_text)
    instrs = {}
    for cname, lines in comps.items():
        t = {}
        for line in lines:
            ins = rl._parse_instr(line)
            if ins:
                t[ins.name] = ins
        instrs[cname] = t

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else list(comps)[-1]

    mem_contrib: List = []
    coll_contrib: List = []
    flop_contrib: List = []
    stack = []

    def op_meta(ins):
        mm = re.search(r'op_name="([^"]+)"', ins.raw)
        return mm.group(1)[-80:] if mm else ""

    def operand_bytes(ins, table):
        return sum(rl.shape_bytes(table[o].type_str)
                   for o in ins.operands if o in table)

    def visit(cname, mult, mem_level):
        if cname not in instrs or cname in stack:
            return
        stack.append(cname)
        table = instrs[cname]
        for ins in table.values():
            op = ins.opcode
            base = op.replace("-start", "")
            if op == "dot":
                f = rl._dot_flops(ins, table)
                flop_contrib.append((f * mult, mult, cname, ins.type_str[:44],
                                     op_meta(ins)))
                if mem_level:
                    sz = rl.shape_bytes(ins.type_str) + operand_bytes(ins, table)
                    mem_contrib.append((sz * mult, mult, "dot",
                                        ins.type_str[:44], op_meta(ins)))
            elif base in rl.COLLECTIVE_OPS and not op.endswith("-done"):
                sz = operand_bytes(ins, table) or rl.shape_bytes(ins.type_str)
                n = rl._group_size(ins.raw)
                wire = sz * rl._wire_factor(base, max(n, 2))
                coll_contrib.append((wire * mult, mult, base,
                                     ins.type_str[:44], op_meta(ins)))
            elif op == "while":
                mm2 = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.raw)
                trips = int(mm2.group(1)) if mm2 else 1
                b = re.search(r"body=%?([\w.\-]+)", ins.raw)
                if b:
                    visit(b.group(1), mult * trips, mem_level)
            elif op == "fusion":
                mm2 = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                if mem_level and mm2:
                    fused = instrs.get(mm2.group(1), {})
                    dus_b = None
                    for i2 in fused.values():
                        if i2.opcode == "dynamic-update-slice" and len(i2.operands) >= 2:
                            upd = fused.get(i2.operands[1])
                            if upd is not None:
                                b2 = 2 * rl.shape_bytes(upd.type_str)
                                dus_b = b2 if dus_b is None else max(dus_b, b2)
                    conv_only = bool(fused) and all(
                        i2.opcode in ("parameter", "convert", "copy", "bitcast",
                                      "tuple", "get-tuple-element")
                        for i2 in fused.values())
                    if dus_b is not None:
                        mem_contrib.append((dus_b * mult, mult, "fusion(dus)",
                                            ins.type_str[:44], op_meta(ins)))
                    elif not conv_only:
                        sz = rl.shape_bytes(ins.type_str) + operand_bytes(ins, table)
                        mem_contrib.append((sz * mult, mult, "fusion",
                                            ins.type_str[:44], op_meta(ins)))
                if mm2:
                    visit(mm2.group(1), mult, False)
            elif op in ("call", "conditional", "async-start"):
                for attr in ("to_apply", "calls", "called_computations",
                             "true_computation", "false_computation"):
                    for mm2 in re.finditer(attr + r"=%?([\w.\-]+)", ins.raw):
                        visit(mm2.group(1), mult, mem_level)
            elif mem_level and op not in rl._TRAFFIC_SKIP:
                if op in ("dynamic-slice", "gather"):
                    sz = 2 * rl.shape_bytes(ins.type_str)
                elif op == "dynamic-update-slice" and len(ins.operands) >= 2:
                    upd = table.get(ins.operands[1])
                    sz = 2 * rl.shape_bytes(upd.type_str) if upd else 0
                elif op == "scatter" and len(ins.operands) >= 3:
                    upd = table.get(ins.operands[2])
                    sz = 2 * rl.shape_bytes(upd.type_str) if upd else 0
                else:
                    sz = rl.shape_bytes(ins.type_str) + operand_bytes(ins, table)
                mem_contrib.append((sz * mult, mult, op, ins.type_str[:44],
                                    op_meta(ins)))
        stack.pop()

    visit(entry, 1.0, True)

    def show(title, contrib, unit, scale):
        contrib.sort(reverse=True)
        total = sum(c[0] for c in contrib)
        print(f"\n=== {title}: total {total:.4g} {unit} "
              f"({total/scale:.4g} s) ===")
        for c in contrib[:top]:
            print(f"  {c[0]:.3g}\tx{c[1]:<6.0f} {c[2]:<12s} {c[3]:<46s} {c[4]}")

    show("HBM traffic", mem_contrib, "B", 819e9)
    show("collective wire", coll_contrib, "B", 50e9)
    show("dot FLOPs", flop_contrib, "FLOP", 197e12)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--dump", default=None, help="also write HLO text here")
    args = ap.parse_args()

    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    kw = {}
    if args.fsdp is not None:
        kw["fsdp"] = args.fsdp == "on"
    fn, cell_args, in_sh, out_sh, donate = build_cell(args.arch, args.shape,
                                                      mesh, **kw)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*cell_args).compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    attribute(text, top=args.top)


if __name__ == "__main__":
    main()
