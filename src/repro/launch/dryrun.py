import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the scale proof without hardware: ``jax.jit(step).lower(**specs)
.compile()`` against the production mesh (16x16 single-pod / 2x16x16
multi-pod on 512 placeholder CPU devices). A sharding mismatch, compile-time
OOM, or unsupported collective here is a bug in the system, not in the
hardware. The compiled artifact also yields the roofline inputs
(cost_analysis + optimized-HLO collective bytes) recorded per cell under
``results/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import roofline as rl
from repro import sharding as sh
from repro.configs import (SHAPES, cell_is_applicable, get_config, list_archs,
                           shape_for)
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, build_model, input_specs
from repro.train.step import (TrainState, init_train_state,
                              make_train_step_fn, state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _named(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _cast_tree(tree, from_dtype, to_dtype):
    def leaf(x):
        if x.dtype == from_dtype:
            return jax.ShapeDtypeStruct(x.shape, to_dtype)
        return x
    return jax.tree.map(leaf, tree)


def abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def abstract_state(model: Model):
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0)))


def abstract_cache(model: Model, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: model.init_cache(batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# cell builders: (fn, example_args, in_shardings, out_shardings, donate)
# ---------------------------------------------------------------------------


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     run_cfg: Optional[RunConfig] = None, *, fsdp: bool = True):
    model = build_model(cfg)
    # microbatched gradient accumulation is the production default: it
    # bounds the remat-saved activation working set (B_loc/8 per microbatch)
    # so every train cell fits 16 GiB/device (llama3-8b: 61 -> 6.6 GiB temp)
    run_cfg = run_cfg or RunConfig(remat="full", microbatches=8)
    rules = sh.rules_for(mesh, fsdp=fsdp)

    step = make_train_step_fn(model, run_cfg, mesh, fsdp=fsdp)
    state = abstract_state(model)
    batch = input_specs(cfg, shape.seq_len, shape.global_batch, "train")

    st_specs = state_specs(state, rules, mesh, zero1=True)
    b_specs = sh.batch_specs(batch, rules, mesh)
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}

    in_sh = (_named(st_specs, mesh), _named(b_specs, mesh))
    out_sh = (_named(st_specs, mesh), _named(metrics_specs, mesh))
    return step, (state, batch), in_sh, out_sh, (0,)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       *, fsdp: bool = True):
    model = build_model(cfg)
    rules = sh.rules_for(mesh, fsdp=fsdp)
    shard = sh.make_shard_fn(mesh, rules)

    def prefill(params, batch, cache):
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    params = _cast_tree(abstract_params(model), jnp.float32, jnp.bfloat16)
    batch = input_specs(cfg, shape.seq_len, shape.global_batch, "prefill")
    cache = abstract_cache(model, shape.global_batch, shape.seq_len)

    p_specs = sh.param_specs(params, rules, mesh)
    b_specs = sh.batch_specs(batch, rules, mesh)
    c_specs = sh.cache_specs(cache, rules, mesh)
    V = cfg.padded_vocab()
    logits_spec = P(rules.dp_spec, None,
                    rules.tp if rules.tp and V % mesh.shape[rules.tp] == 0 else None)

    in_sh = (_named(p_specs, mesh), _named(b_specs, mesh), _named(c_specs, mesh))
    out_sh = (NamedSharding(mesh, logits_spec), _named(c_specs, mesh))
    return prefill, (params, batch, cache), in_sh, out_sh, (2,)


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      *, fsdp: bool = True):
    model = build_model(cfg)
    seq_shard = shape.global_batch == 1
    rules = sh.rules_for(mesh, seq_shard=seq_shard, fsdp=fsdp)
    shard = sh.make_shard_fn(mesh, rules)

    def decode(params, batch, cache):
        logits, cache, _ = model.apply(params, batch, cache=cache, shard=shard)
        return logits, cache

    params = _cast_tree(abstract_params(model), jnp.float32, jnp.bfloat16)
    batch = input_specs(cfg, shape.seq_len, shape.global_batch, "decode")
    cache = abstract_cache(model, shape.global_batch, shape.seq_len)
    # decode enters with a full cache: pos = seq_len - 1
    B = shape.global_batch

    p_specs = sh.param_specs(params, rules, mesh)
    b_specs = sh.batch_specs(batch, rules, mesh)
    c_specs = sh.cache_specs(cache, rules, mesh, seq_shard=seq_shard)
    V = cfg.padded_vocab()
    dp_ok = B % sh._axsize(mesh, rules.dp_spec) == 0
    logits_spec = P(rules.dp_spec if dp_ok else None, None,
                    rules.tp if rules.tp and V % mesh.shape[rules.tp] == 0 else None)

    in_sh = (_named(p_specs, mesh), _named(b_specs, mesh), _named(c_specs, mesh))
    out_sh = (NamedSharding(mesh, logits_spec), _named(c_specs, mesh))
    return decode, (params, batch, cache), in_sh, out_sh, (2,)


def build_cell(arch: str, shape_name: str, mesh, **kw):
    cfg = get_config(arch)
    shape = shape_for(shape_name)
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, **kw)
    return build_decode_cell(cfg, shape, mesh, **kw)


class SkipCell(Exception):
    pass


# ---------------------------------------------------------------------------
# lower + compile + analyse
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             fsdp: Optional[bool] = None, verbose: bool = True,
             mesh_shape: Optional[Tuple[int, int]] = None) -> Dict:
    """``mesh_shape`` overrides the (data, model) split of the 256-chip pod —
    the serving-topology knob (paper: 'the network topology is set up before
    running the benchmarks')."""
    if mesh_shape is not None:
        import jax as _jax
        mesh = _jax.make_mesh(tuple(mesh_shape), ("data", "model"),
                              axis_types=(_jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    cfg = get_config(arch)
    shape = shape_for(shape_name)
    kw = {}
    if fsdp is not None:
        kw["fsdp"] = fsdp

    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh, **kw)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)

    mflops = rl.model_flops_for(cfg, shape.kind, shape.global_batch,
                                shape.seq_len)
    hlo_text = compiled.as_text()
    terms = rl.from_compiled(compiled, chips=chips, model_flops=mflops,
                             hlo_text=hlo_text)

    # bytes-per-device of the step's resident state (args are sharded)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "kind": shape.kind, "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "flops_per_device": terms.flops,
        "hbm_bytes_per_device": terms.hbm_bytes,
        "collective_operand_bytes": terms.coll_operand_bytes,
        "collective_wire_bytes": terms.coll_wire_bytes,
        "per_op_bytes": terms.details["per_op_bytes"],
        "collective_count": terms.details["collective_count"],
        "unresolved_loops": terms.details["unresolved_loops"],
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "model_flops": mflops,
        "useful_ratio": terms.useful_ratio,
        "step_s": terms.step_s,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}] compiled in "
              f"{t_compile:.1f}s -> {terms.row()}")
        if mem_rec:
            print("  memory:", {k: f"{v/2**30:.2f}GiB" for k, v in mem_rec.items()
                                if "size" in k})
    return record


def cell_list(mesh_kind: str):
    for arch in list_archs():
        for shape_name in SHAPES:
            yield arch, shape_name, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = [(a, s, m) for m in meshes for a, s, _ in cell_list(m)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape_name, mesh_kind in cells:
        tag = f"{arch}__{shape_name}__{mesh_kind}".replace("/", "_")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {tag}")
            continue
        try:
            fsdp = None if args.fsdp is None else (args.fsdp == "on")
            rec = run_cell(arch, shape_name, mesh_kind, fsdp=fsdp)
        except SkipCell as e:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "skipped", "reason": str(e)}
            print(f"[skipped] {tag}: {e}")
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[FAILED] {tag}: {type(e).__name__}: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
