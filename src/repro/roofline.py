"""Three-term roofline analysis from compiled XLA artifacts.

    compute_s    = HLO_FLOPs(per device) / peak_FLOP/s
    memory_s     = HLO_bytes(per device) / HBM_bw
    collective_s = collective_wire_bytes(per device) / ICI_link_bw

``compiled.cost_analysis()`` reports the per-device SPMD program (XLA
compiles ONE program that every device runs), so terms divide by per-chip
peaks — algebraically identical to the assignment's
``total / (chips x peak)`` form.

**Why a custom HLO parser instead of cost_analysis alone:** XLA's
cost_analysis counts a ``while`` body *once*, but every step function here
scans over layers (and fori_loops over HPL iterations), so FLOPs/bytes would
be undercounted by ~num_layers x. :func:`analyze_hlo` walks the optimized
HLO (``compiled.as_text()``), recovers loop trip counts from the canonical
XLA counter pattern in loop conditions, and multiplies through. It
computes:

* **flops** — 2 x result_elems x contracted_size for every ``dot`` (matmul
  FLOPs dominate every workload here; elementwise flops are ignored and the
  convention is recorded in EXPERIMENTS.md);
* **hbm traffic** — operand + result bytes of every *memory-level* op
  (top-level in ENTRY / loop bodies / branches; fusion internals live in
  registers/VMEM and are not HBM traffic);
* **collective bytes** — operand sizes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute / collective-broadcast
  (and async -start forms), plus ring-factor wire-byte estimates
  (all-reduce 2(n-1)/n, gather/scatter/all-to-all (n-1)/n, permute 1x)
  using the replica-group size of each op.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.comm.types import TPU_V5E, HardwareModel

# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*[a-z0-9]*)\[([\d,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_TRAFFIC_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call",
    # dtype converts: XLA:CPU materializes them, XLA:TPU feeds the MXU/VPU
    # datapath directly — consumers count the (converted) operand reads.
    "convert",
}

# Interpret-mode Pallas kernels appear as plain HLO loops whose op_name
# metadata carries the jitted wrapper name (repro/kernels/ops.py). Inside a
# kernel region only the BlockSpec-level block fetches (dynamic-slice) and
# commits (dynamic-update-slice) are HBM traffic — everything else lives in
# VMEM on the real TPU. This is a conservative model: interpret mode
# re-fetches blocks that real Pallas pipelining would keep resident.
_KERNEL_REGION_RE = re.compile(
    r"jit\((?:flash_attention|matmul|gemm_update|transpose_add|"
    r"lu_factor_block|trsm_lower_left|trsm_upper_right|stream_[a-z]+)\)/")


def _in_kernel_region(raw: str) -> bool:
    m = re.search(r'op_name="([^"]+)"', raw)
    return bool(m and _KERNEL_REGION_RE.search(m.group(1)))


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string (tuples sum)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return int(total)


def shape_dims(type_str: str) -> List[int]:
    """Dims of the FIRST array shape in a type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    raw: str
    is_root: bool = False


_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> instruction lines. A header is a top-level line
    ending in '{' whose name is followed by a parameter list (which may
    itself contain tuple parens)."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
                m = _HEADER_RE.match(line)
                if m:
                    current = m.group(1)
                    comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        comps[current].append(line)
    return comps


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_instr(line: str) -> Optional[_Instr]:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i >= n:
        return None
    # --- type: either a (tuple, ...) with balanced parens or an array type
    if line[i] == "(":
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    j += 1
                    break
            j += 1
        type_str = line[i:j]
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
    # --- opcode: token between type and the '(' of the operand list
    k = line.find("(", j)
    if k < 0:
        return None
    opcode = line[j:k].strip()
    if not opcode or not re.fullmatch(r"[a-z][\w\-]*", opcode):
        return None
    # --- operands: comma-split at depth 1 inside the call parens
    depth = 1
    args: List[str] = []
    buf = ""
    for ch in line[k + 1:]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args.append(buf)
                break
        if depth >= 1 and ch != ")":
            if ch == "," and depth == 1:
                args.append(buf)
                buf = ""
            else:
                buf += ch
    operands = []
    for a in args:
        mm = re.search(r"%([\w.\-]+)", a)
        if mm:
            operands.append(mm.group(1))
    return _Instr(name=name, type_str=type_str.strip(), opcode=opcode,
                  operands=operands, raw=line,
                  is_root=line.lstrip().startswith("ROOT "))


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    if "source_target_pairs=" in line:
        return 2
    return 0


def _wire_factor(opcode: str, n: int) -> float:
    if n <= 1:
        return 0.0 if not opcode.startswith("collective-permute") else 1.0
    if opcode.startswith("all-reduce"):
        return 2.0 * (n - 1) / n
    if opcode.startswith(("all-gather", "reduce-scatter", "all-to-all")):
        return (n - 1) / n
    return 1.0


def _dot_flops(ins: _Instr, table: Dict[str, _Instr]) -> float:
    """2 x result_elems x contracted_size for a dot instruction."""
    out_dims = shape_dims(ins.type_str)
    out_elems = math.prod(out_dims) if out_dims else 0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    csize = 1
    if m and ins.operands:
        lhs = table.get(ins.operands[0])
        if lhs is not None:
            ldims = shape_dims(lhs.type_str)
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    csize *= ldims[int(idx)]
    return 2.0 * out_elems * csize


@dataclass
class HloStats:
    flops: float = 0.0                  # dot flops, loop-expanded, per device
    hbm_bytes: float = 0.0              # memory-level op traffic, loop-expanded
    operand_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: float = 0.0
    collective_count: int = 0
    unresolved_loops: int = 0

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())


def analyze_hlo(hlo_text: str) -> HloStats:
    comps = _split_computations(hlo_text)
    instrs: Dict[str, Dict[str, _Instr]] = {}
    for cname, lines in comps.items():
        table = {}
        for line in lines:
            ins = _parse_instr(line)
            if ins:
                table[ins.name] = ins
        instrs[cname] = table

    def trip_count(cond_comp: str) -> Optional[int]:
        table = instrs.get(cond_comp, {})
        for ins in table.values():
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", ins.raw)
                if m:
                    return int(m.group(1))
        return None

    stats = HloStats()
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else (list(comps)[-1] if comps else None)
    if entry not in comps:
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return stats

    stack: List[str] = []
    _kernel_comp_cache: Dict[str, bool] = {}

    def kernel_comp(cname: str) -> bool:
        """A computation is kernel-internal if any instruction carries a
        Pallas-kernel op_name (the interpret-mode grid loop's own copies and
        slices don't carry it, but the kernel body ops do)."""
        if cname not in _kernel_comp_cache:
            _kernel_comp_cache[cname] = any(
                _in_kernel_region(i.raw) for i in instrs.get(cname, {}).values())
        return _kernel_comp_cache[cname]

    def root_of(cname: str) -> Optional[_Instr]:
        for ins in instrs.get(cname, {}).values():
            if ins.is_root:
                return ins
        return None

    def operand_bytes_of(ins: _Instr, table) -> int:
        size = 0
        for o in ins.operands:
            src = table.get(o)
            if src is not None:
                size += shape_bytes(src.type_str)
        return size

    _CONVERT_ONLY = {"parameter", "convert", "copy", "bitcast", "tuple",
                     "get-tuple-element"}

    def fusion_dus_bytes(fused: str, fusion_type: str) -> Optional[int]:
        """If the fused computation updates a buffer of the fusion's own
        result type via dynamic-update-slice (the scan-carry / KV-cache
        write pattern — in-place on TPU), return 2 x update-slice bytes."""
        best = None
        for ins in instrs.get(fused, {}).values():
            if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
                upd = instrs[fused].get(ins.operands[1])
                if upd is not None:
                    b = 2 * shape_bytes(upd.type_str)
                    best = b if best is None else max(best, b)
        return best

    def is_pure_convert(fused: str) -> bool:
        """kLoop fusions that only change dtype/layout-free copy: on TPU the
        convert happens in the consumer's datapath (MXU eats bf16), so this
        is not HBM traffic — XLA:CPU materializes it, XLA:TPU fuses it."""
        table = instrs.get(fused, {})
        return bool(table) and all(i.opcode in _CONVERT_ONLY
                                   for i in table.values())

    def visit(cname: str, mult: float, memory_level: bool,
              in_kernel: bool = False):
        if cname not in instrs or cname in stack:
            return
        stack.append(cname)
        table = instrs[cname]
        in_kernel = in_kernel or kernel_comp(cname)
        for ins in table.values():
            op = ins.opcode
            base = op.replace("-start", "")
            if op == "dot":
                stats.flops += _dot_flops(ins, table) * mult
                if memory_level and not in_kernel:
                    stats.hbm_bytes += (shape_bytes(ins.type_str)
                                        + operand_bytes_of(ins, table)) * mult
                continue
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                size = 0
                for o in ins.operands:
                    src = table.get(o)
                    if src is not None:
                        size += shape_bytes(src.type_str)
                if size == 0:
                    size = shape_bytes(ins.type_str)
                stats.operand_bytes[base] = stats.operand_bytes.get(base, 0.0) \
                    + size * mult
                n = _group_size(ins.raw)
                stats.wire_bytes += size * mult * _wire_factor(base, max(n, 2))
                stats.collective_count += 1
                continue
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.raw)
                # XLA records the analyzed trip count in backend_config
                mm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.raw)
                trips = int(mm.group(1)) if mm else None
                if trips is None:  # fall back to the condition constant
                    cond = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                    trips = trip_count(cond.group(1)) if cond else None
                if trips is None:
                    trips = 1
                    stats.unresolved_loops += 1
                if body:
                    visit(body.group(1), mult * trips, memory_level, in_kernel)
                continue
            if op in ("call", "conditional", "async-start"):
                for attr in ("to_apply", "calls", "called_computations",
                             "true_computation", "false_computation",
                             "branch_computations"):
                    for mm in re.finditer(attr + r"=%?([\w.\-]+)", ins.raw):
                        visit(mm.group(1), mult, memory_level, in_kernel)
                continue
            if op == "fusion":
                # HBM traffic at the fusion boundary; dots inside still count.
                # In-place fusions (containing a dynamic-update-slice on a
                # buffer of the fusion's result type — KV-cache / scan-carry /
                # grad-accumulation writes) touch only the updated slice, not
                # the full aliased buffer. Pure-convert fusions are an
                # XLA:CPU artifact (TPU converts in the consumer datapath).
                mm = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                ik = in_kernel or _in_kernel_region(ins.raw)
                if memory_level and mm:
                    dus_b = fusion_dus_bytes(mm.group(1), ins.type_str)
                    if dus_b is not None:
                        stats.hbm_bytes += (dus_b // (2 if ik else 1)) * mult
                    elif is_pure_convert(mm.group(1)) or ik:
                        pass
                    else:
                        stats.hbm_bytes += (shape_bytes(ins.type_str)
                                            + operand_bytes_of(ins, table)) * mult
                elif memory_level and not ik:
                    stats.hbm_bytes += (shape_bytes(ins.type_str)
                                        + operand_bytes_of(ins, table)) * mult
                if mm:
                    visit(mm.group(1), mult, False, ik)
                continue
            if memory_level and op not in _TRAFFIC_SKIP:
                ik = in_kernel or _in_kernel_region(ins.raw)
                if op == "dynamic-slice":
                    factor = 1 if ik else 2  # kernel: HBM read only
                    stats.hbm_bytes += factor * shape_bytes(ins.type_str) * mult
                elif op == "dynamic-update-slice" and len(ins.operands) >= 2:
                    upd = table.get(ins.operands[1])
                    if upd is not None:
                        factor = 1 if ik else 2
                        stats.hbm_bytes += factor * shape_bytes(upd.type_str) \
                            * mult
                elif ik:
                    pass  # VMEM-resident kernel body op
                elif op == "gather":
                    stats.hbm_bytes += 2 * shape_bytes(ins.type_str) * mult
                elif op == "scatter" and len(ins.operands) >= 3:
                    upd = table.get(ins.operands[2])
                    if upd is not None:
                        stats.hbm_bytes += 2 * shape_bytes(upd.type_str) * mult
                else:
                    stats.hbm_bytes += (shape_bytes(ins.type_str)
                                        + operand_bytes_of(ins, table)) * mult
        stack.pop()

    visit(entry, 1.0, True)
    return stats


def collective_bytes(hlo_text: str) -> HloStats:
    """Collective payload summary (subset view of :func:`analyze_hlo`)."""
    return analyze_hlo(hlo_text)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def alpha_beta_time(hops: float, wire_bytes: float,
                    hw: HardwareModel = TPU_V5E, *,
                    staged: bool = False) -> float:
    """Link-level alpha-beta term: ``hops x per-hop latency + bytes / bw``.

    The ``collective_s`` roofline term above prices wire bytes only; schedule
    *selection* (repro.comm.autotune) also needs the latency side, because
    small-message collectives are hop-count-bound. ``staged=True`` prices the
    host-staged domain (MPI small-message latency, PCIe/DCN bandwidth — the
    paper's Eq. 2 path) instead of the circuit-switched links.
    """
    if staged:
        return hops * hw.mpi_latency + wire_bytes / min(hw.pcie_bw, hw.dcn_bw)
    return hops * hw.ici_latency + wire_bytes / hw.ici_link_bw


def pipelined_alpha_beta_time(hops: float, wire_bytes: float, nchunks: int,
                              hw: HardwareModel = TPU_V5E, *,
                              staged: bool = False) -> float:
    """Alpha-beta term for a software-pipelined collective.

    The payload is split into ``nchunks`` chunks that stream through the
    ``hops``-stage pipe, so the transfer takes ``hops + nchunks - 1`` stages
    of one per-chunk hop each::

        T(S) = (H + S - 1) x (alpha + W / (H * S * beta))

    ``S = 1`` reduces exactly to :func:`alpha_beta_time`. More chunks shrink
    the per-stage wire term (better overlap with the consumer's compute) but
    add ``S - 1`` stages of fill/drain latency — the trade
    :func:`repro.comm.autotune.best_nchunks` optimizes.
    """
    h = float(hops)
    if h < 1.0:
        # nothing to pipeline (1-rank axis / degenerate segment): keep the
        # S=1 == monolithic contract exact instead of clamping to one hop
        return alpha_beta_time(hops, wire_bytes, hw, staged=staged)
    s = max(int(nchunks), 1)
    alpha = hw.mpi_latency if staged else hw.ici_latency
    beta = min(hw.pcie_bw, hw.dcn_bw) if staged else hw.ici_link_bw
    return (h + s - 1) * (alpha + wire_bytes / (h * s) / beta)


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops (parsed, loop-expanded)
    hbm_bytes: float             # per-device HBM traffic
    coll_operand_bytes: float
    coll_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0    # MODEL_FLOPS / (HLO_FLOPs * chips)
    step_s: float = 0.0          # max of the three terms (no-overlap bound)
    details: Dict = field(default_factory=dict)

    def row(self) -> str:
        return (f"compute {self.compute_s:.4g}s | memory {self.memory_s:.4g}s"
                f" | collective {self.collective_s:.4g}s -> {self.dominant}"
                f" (useful {self.useful_ratio:.2%})")


def from_compiled(compiled, *, chips: int, hw: HardwareModel = TPU_V5E,
                  model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from a compiled executable (per-device convention)."""
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = analyze_hlo(text)

    cost = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception:  # backend without cost analysis
        cost = {}

    return from_stats(stats, chips=chips, hw=hw, model_flops=model_flops,
                      cost=cost)


def from_stats(stats: HloStats, *, chips: int, hw: HardwareModel = TPU_V5E,
               model_flops: float = 0.0, cost: Optional[dict] = None) -> Roofline:
    compute_s = stats.flops / hw.peak_flops
    memory_s = stats.hbm_bytes / hw.hbm_bw
    collective_s = stats.wire_bytes / hw.ici_link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / (stats.flops * chips) if stats.flops else 0.0
    details = {
        "per_op_bytes": stats.operand_bytes,
        "collective_count": stats.collective_count,
        "unresolved_loops": stats.unresolved_loops,
    }
    if cost:
        details["cost_analysis_flops"] = float(cost.get("flops", 0.0))
        details["cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        flops=stats.flops, hbm_bytes=stats.hbm_bytes,
        coll_operand_bytes=stats.total_operand_bytes,
        coll_wire_bytes=stats.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        step_s=max(terms.values()), details=details)


def model_flops_for(cfg, kind: str, global_batch: int, seq_len: int) -> float:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (forward-only), N = active params."""
    n_active = cfg.param_count(active_only=True)
    if kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * global_batch * seq_len
    return 2.0 * n_active * global_batch
