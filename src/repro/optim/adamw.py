"""AdamW with fp32 master moments, decoupled weight decay, global-norm clip.

Written against plain pytrees (no optax dependency). The moments inherit the
parameter sharding and — under ZeRO-1 (see :func:`repro.sharding.
opt_state_specs`) — are additionally sharded over the data-parallel axes,
so optimizer memory scales down with DP size like the paper's per-device
matrix blocks scale with the torus size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[object, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: Dict, params, cfg: AdamWConfig,
                 lr: jnp.ndarray) -> Tuple[object, Dict]:
    """Returns (new_params, new_state). grads/params fp32 leaves."""
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def moment1(m, g):
        return cfg.b1 * m + (1 - cfg.b1) * g.astype(jnp.float32)

    def moment2(v, g):
        g = g.astype(jnp.float32)
        return cfg.b2 * v + (1 - cfg.b2) * g * g

    mu = jax.tree.map(moment1, state["mu"], grads)
    nu = jax.tree.map(moment2, state["nu"], grads)

    def step(p, m, v):
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(step, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}


def make_lr_schedule(base_lr: float, warmup_steps: int,
                     total_steps: int = 10_000, min_ratio: float = 0.1) -> Callable:
    """Linear warmup + cosine decay to min_ratio * base_lr."""
    def schedule(step) -> jnp.ndarray:
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps)
                            / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)
    return schedule
