from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    CheckpointMismatchError,
    latest_step,
    restore,
    save,
)
