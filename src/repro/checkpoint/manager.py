"""Atomic, elastic checkpointing.

Fault-tolerance contract (DESIGN.md §7):

* **Atomicity** — a checkpoint is written to ``step_<k>.tmp/`` and renamed to
  ``step_<k>/`` only after every array and the manifest are on disk; a crash
  mid-write leaves at most a ``.tmp`` directory that restore ignores and the
  next save garbage-collects.
* **Elasticity** — arrays are stored by *logical* tree path with their global
  shape; restore re-shards onto whatever mesh/sharding the new job provides
  (tested: save under mesh A, restore under differently-shaped mesh B).
  On a real multi-host cluster each host writes only its addressable shards;
  in this single-process container the process owns all shards, so files hold
  full arrays — the layout and manifest format already carry the per-shard
  metadata (``sharding`` entries) a multi-host writer needs.
* **Retention** — ``keep`` newest checkpoints survive; older are deleted
  after a successful save (never before).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


_SEP = "/"


class CheckpointMismatchError(ValueError):
    """A checkpoint does not fit the requested structure.

    Raised by :func:`restore` with the *complete* diagnosis — every
    missing leaf (in ``like`` but not on disk), unexpected leaf (on disk
    but not in ``like``), and shape mismatch across all trees — instead
    of a bare ``KeyError`` on the first absent array, so elastic-resume
    failures (restoring onto a differently-structured model) are
    diagnosable from the message alone.
    """

    def __init__(self, missing, unexpected, shape_mismatches):
        self.missing = tuple(missing)
        self.unexpected = tuple(unexpected)
        self.shape_mismatches = tuple(shape_mismatches)
        parts = []
        if self.missing:
            parts.append("missing from checkpoint: "
                         + ", ".join(self.missing))
        if self.unexpected:
            parts.append("unexpected in checkpoint: "
                         + ", ".join(self.unexpected))
        if self.shape_mismatches:
            parts.append("shape mismatches: " + ", ".join(
                f"{key} saved {tuple(got)} != expected {tuple(want)}"
                for key, got, want in self.shape_mismatches))
        super().__init__("checkpoint does not match the requested "
                         "structure — " + "; ".join(parts))


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            keys.append(str(e.key) if hasattr(e, "key") else str(getattr(e, "idx", e)))
        flat[_SEP.join(keys)] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, trees: Dict[str, object], *,
         keep: int = 3, extra: Optional[dict] = None) -> str:
    """Atomically write ``trees`` (name -> pytree) as checkpoint ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "trees": {}, "extra": extra or {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"),
                 **{k: v for k, v in flat.items()})
        manifest["trees"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # the atomic commit point

    # retention + stale-tmp garbage collection (only after a good save)
    steps = sorted(all_steps(directory))
    for old in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{old:010d}"),
                      ignore_errors=True)
    for entry in os.listdir(directory):
        if entry.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, entry), ignore_errors=True)
    return final


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for entry in os.listdir(directory):
        if entry.startswith("step_") and not entry.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, entry, "manifest.json")):
            out.append(int(entry[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _reshard_shardings(like: Dict[str, object], mesh, axis: str
                       ) -> Dict[str, object]:
    """Per-tree NamedSharding pytrees for restoring onto ``mesh``: a
    TrainState gets the explicit whole-model layout (params through
    :func:`repro.train.step.whole_model_param_specs`, opt moments
    mirroring the params, scalars replicated — the same spec construction
    the explicit step's shard_map uses), a bare params dict the param
    specs alone, anything else fully replicated."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.train.step import TrainState, whole_model_param_specs

    def is_spec(x):
        return isinstance(x, P)

    out = {}
    for name, tree in like.items():
        if isinstance(tree, TrainState):
            pspec = whole_model_param_specs(tree.params, axis)
            spec = TrainState(
                params=pspec,
                opt={"mu": jax.tree.map(lambda s: s, pspec, is_leaf=is_spec),
                     "nu": jax.tree.map(lambda s: s, pspec, is_leaf=is_spec),
                     "count": P()},
                step=P(),
                error=(jax.tree.map(lambda _: P(), tree.error)
                       if tree.error is not None else None))
        elif isinstance(tree, dict) and "blocks" in tree:
            spec = whole_model_param_specs(tree, axis)
        else:
            spec = jax.tree.map(lambda _: P(), tree)
        out[name] = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                 is_leaf=is_spec)
    return out


def restore(directory: str, like: Dict[str, object], *, step: Optional[int] = None,
            shardings: Optional[Dict[str, object]] = None,
            reshard_to=None, axis: str = "x") -> Tuple[int, Dict[str, object], dict]:
    """Restore (step, trees, extra). ``like`` gives the pytree structure;
    ``shardings`` optionally maps tree names to sharding pytrees — this is the
    elastic path: the stored global arrays are ``device_put`` onto the *new*
    mesh regardless of the mesh they were saved under.

    ``reshard_to`` (a jax Mesh) derives those shardings automatically via
    :func:`_reshard_shardings` — the rank-loss recovery path: the survivor
    mesh differs from the one the checkpoint was saved under, and the
    restored state must land sharded for the explicit whole-model step
    (MoE expert leaves over ``axis``, everything else replicated).
    Explicit ``shardings`` win when both are given.

    A structure mismatch raises :class:`CheckpointMismatchError` with the
    complete list of missing / unexpected / shape-mismatched leaves.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if shardings is None and reshard_to is not None:
        shardings = _reshard_shardings(like, reshard_to, axis)

    out = {}
    missing: List[str] = []
    unexpected: List[str] = []
    mismatched: List[Tuple[str, tuple, tuple]] = []
    for name, tree in like.items():
        data = np.load(os.path.join(d, f"{name}.npz"))
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(tree)
        new_leaves = []
        want = set()
        ok = True
        for path, leaf in leaves_like:
            keys = []
            for e in path:
                keys.append(str(e.key) if hasattr(e, "key") else str(getattr(e, "idx", e)))
            key = _SEP.join(keys)
            want.add(key)
            if key not in data:
                missing.append(f"{name}:{key}")
                ok = False
                continue
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                mismatched.append((f"{name}:{key}", tuple(arr.shape),
                                   tuple(leaf.shape)))
                ok = False
                continue
            new_leaves.append(arr)
        unexpected += sorted(f"{name}:{k}" for k in data.files
                             if k not in want)
        if not ok:
            continue
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), new_leaves)
        if shardings and name in shardings:
            restored = jax.device_put(restored, shardings[name])
        out[name] = restored
    if missing or mismatched:
        raise CheckpointMismatchError(missing, unexpected, mismatched)
    return step, out, manifest.get("extra", {})


class CheckpointManager:
    """Policy wrapper: save every ``every`` steps, keep ``keep`` newest."""

    def __init__(self, directory: str, *, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = max(every, 1)
        self.keep = keep

    def save(self, step: int, trees: Dict[str, object], *,
             extra: Optional[dict] = None, force: bool = False
             ) -> Optional[str]:
        """Write checkpoint ``step`` through the retention policy.

        ``force=True`` ignores the cadence — the straggler-policy forced
        checkpoint and the end-of-run save both route here, so every write
        honors ``keep`` and the stale-tmp garbage collection."""
        if not force and step % self.every:
            return None
        return save(self.directory, step, trees, keep=self.keep, extra=extra)

    def maybe_save(self, step: int, trees: Dict[str, object],
                   extra: Optional[dict] = None) -> Optional[str]:
        return self.save(step, trees, extra=extra)

    def restore_latest(self, like, shardings=None, *, reshard_to=None,
                       axis: str = "x"):
        return restore(self.directory, like, shardings=shardings,
                       reshard_to=reshard_to, axis=axis)

    @property
    def has_checkpoint(self) -> bool:
        return latest_step(self.directory) is not None
