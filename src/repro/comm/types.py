"""Communication types and hardware model constants.

``CommunicationType`` is the paper's Fig. 1 selector: every distributed
primitive in :mod:`repro.comm.collectives` has one implementation per type,
and benchmarks/trainers pick the implementation at run time — exactly the
paper's ``ExecutionImplementation`` architecture.

``HardwareModel`` carries the constants for the analytical performance models
(paper Eqs. 2-6) and the roofline terms. Defaults are TPU v5e (the assigned
target), with the paper's BittWare 520N given for cross-checking the
reproduction against the paper's own numbers.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class CommunicationType(enum.Enum):
    # Direct device-to-device over the circuit-switched interconnect
    # (paper: Intel External Channels / CSN; here: TPU ICI).
    ICI_DIRECT = "ici_direct"
    # Staged through the hosts (paper: PCIe + MPI over the inter-CPU network;
    # here: DCN across pods / store-and-forward emulation intra-pod).
    HOST_STAGED = "host_staged"


def comm_type(name) -> CommunicationType:
    if isinstance(name, CommunicationType):
        return name
    return CommunicationType(name)


@dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float          # peak matmul FLOP/s per chip (bf16 for v5e)
    hbm_bw: float              # HBM bytes/s per chip
    ici_link_bw: float         # bytes/s per ICI link (per direction)
    ici_links: int             # torus links per chip
    ici_latency: float         # seconds per hop
    pcie_bw: float             # bytes/s device<->host
    dcn_bw: float              # bytes/s per host over the data-center network
    mpi_latency: float         # host-network small-message latency (s)
    vmem_bytes: int = 0        # per-core fast memory (VMEM / BRAM analogue)
    hbm_bytes: int = 0


TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops=197e12,         # bf16
    hbm_bw=819e9,
    ici_link_bw=50e9,          # ~50 GB/s per link per assignment
    ici_links=4,               # 2-D torus
    ici_latency=1e-6,
    pcie_bw=15.75e9,           # PCIe 4.0 x8 host staging
    dcn_bw=25e9,
    mpi_latency=10e-6,
    vmem_bytes=16 * 2**20,
    hbm_bytes=16 * 2**30,
)

# The paper's evaluation hardware, for validating the reproduction's
# analytical models against the paper's own measurements (Fig. 10, Eq. 4).
BITTWARE_520N = HardwareModel(
    name="bittware_520n",
    peak_flops=8.6e12,         # fp32 DSP peak-ish (not used by models)
    hbm_bw=76.8e9,             # 4x DDR4 banks, 19.2 GB/s each
    ici_link_bw=5e9,           # 40 Gbit/s serial channel
    ici_links=4,
    ici_latency=520e-9,        # Table 2: c_l
    pcie_bw=7.88e9,            # PCIe 3.0 x8
    dcn_bw=12.5e9,             # Omni-Path 100 Gbit/s
    mpi_latency=1.5e-6,
)

# External-channel IP parameters of the 520N (paper Table 2) for Eq. 3/4.
CHANNEL_FREQ_520N = 156.25e6   # c_f
CHANNEL_WIDTH_520N = 32        # c_w bytes
CHANNELS_520N = 4              # c_n
