"""Gradient compression for the all-reduce path (beyond-paper optimization).

int8 block-quantized all-reduce with error feedback: the quantization residual
is carried across steps so the compressed reduction stays unbiased in the
long run (Seide et al. 2014 1-bit SGD lineage; here 8-bit with per-block
scales, which is the practical TPU variant — int8 moves 4x fewer ICI bytes
than fp32, 2x fewer than bf16).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

BLOCK = 256  # elements per quantization block


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 -> (int8 values, fp32 per-block scales)."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, size: int) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return x.reshape(shape)


def quantize_ef(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                         jnp.ndarray, jnp.ndarray]:
    """fp32 -> (q, scale, qr, rscale): the quantized payload plus the
    quantized *requantization residual* carried alongside it.

    The residual chunk is what a bare :func:`quantize` drops on the floor at
    every hop; sending it (itself int8-quantized — its own residual is
    second-order, O(1/127^2) of the payload) tightens a hop-by-hop lossy
    ring from O(hops/127) to O(hops/127^2) relative error at 2x the int8
    wire bytes — still half of fp32."""
    flat = x.astype(jnp.float32)
    q, scale = quantize(flat)
    r = flat - dequantize(q, scale, flat.shape, flat.size)
    qr, rscale = quantize(r)
    return q, scale, qr, rscale


def dequantize_ef(q: jnp.ndarray, scale: jnp.ndarray, qr: jnp.ndarray,
                  rscale: jnp.ndarray, shape, size: int) -> jnp.ndarray:
    """Reconstruct payload + residual from the :func:`quantize_ef` wire."""
    return (dequantize(q, scale, shape, size)
            + dequantize(qr, rscale, shape, size))


def compressed_psum(x: jnp.ndarray, axis: str, error: jnp.ndarray, *,
                    engine=None, schedule: Optional[str] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce ``x`` (fp32) over ``axis`` with int8 payload + error feedback.

    Returns (reduced, new_error). ``error`` has the same shape as ``x``.
    Payload on the wire: 1 byte/elem + 4/BLOCK bytes/elem of scales, vs 4
    bytes/elem uncompressed.

    With ``engine`` (a :class:`~repro.comm.engine.CollectiveEngine`), the
    wire payload rides the engine's registered allreduce schedule — error
    feedback composed with the ``chain``/``rs_ag``/``ring2d`` rings instead
    of a hard-wired ``lax.psum``. ``schedule`` overrides the engine's choice;
    ``int8_ef`` (this transform registered as a stateless engine schedule)
    is remapped to its ``rs_ag`` transport to avoid double quantization.
    """
    target = x.astype(jnp.float32) + error.astype(jnp.float32)
    q, scale = quantize(target)
    sent = dequantize(q, scale, x.shape, x.size)
    new_error = target - sent
    # int8 values cannot be summed in int8 without overflow across ranks;
    # reduce the dequantized representation (the *wire* payload is what the
    # roofline counts; see roofline.collective_bytes notes).
    if engine is None:
        reduced = lax.psum(sent, axis)
    else:
        inner = schedule or engine.schedule_for(
            "allreduce", nbytes=sent.size * sent.dtype.itemsize, axis=axis)
        if inner == "int8_ef":
            inner = "rs_ag"
        reduced = engine.allreduce(sent, axis, schedule=inner)
    return reduced, new_error


def init_error_tree(params) -> object:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
