from repro.comm.types import (  # noqa: F401
    BITTWARE_520N,
    CommunicationType,
    HardwareModel,
    TPU_V5E,
    comm_type,
)
from repro.comm.topology import AxisTopology, MeshTopology  # noqa: F401
from repro.comm.engine import (  # noqa: F401
    CollectiveEngine,
    UnknownScheduleError,
    known_schedules,
    register_schedule,
    schedules_for,
)
from repro.comm.collectives import (  # noqa: F401
    all_to_all_tiles,
    psum_schedule,
    ring_bcast,
    ring_exchange_bidir,
    ring_shift,
)
from repro.comm.faults import (  # noqa: F401
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    LinkFault,
    RankLostError,
)
from repro.comm.retune import (  # noqa: F401
    RetuneController,
    RetuneEvent,
    Watched,
)
