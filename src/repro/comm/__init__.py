from repro.comm.types import (  # noqa: F401
    BITTWARE_520N,
    CommunicationType,
    HardwareModel,
    TPU_V5E,
    comm_type,
)
from repro.comm.collectives import (  # noqa: F401
    all_to_all_tiles,
    psum_schedule,
    ring_bcast,
    ring_exchange_bidir,
    ring_shift,
)
