"""Cost-model-driven schedule autotuning for the collective engine.

The paper's core finding is that the best communication path is workload- and
topology-dependent: circuit-switched inter-FPGA routes beat the host-staged
MPI path for the communication-bound benchmarks, but the winner flips with
message size and node count (Figs. 4-7). This module makes that selection a
first-class subsystem instead of a static per-op default:

* **Analytic mode** — an alpha-beta model prices every registered schedule
  per ``(op, message bytes, axis topology)``. Each schedule is reduced to
  hop count and per-link wire bytes on the :class:`AxisTopology` it runs
  over, and priced with :func:`repro.roofline.alpha_beta_time` using the
  :class:`HardwareModel` link numbers (per-hop latency ``alpha``, link
  bandwidth ``beta``; the staging domain uses MPI latency and PCIe/DCN
  bandwidth, the paper's Eq. 2 path).

* **Measured mode** — :func:`autotune_mesh` microbenchmarks the registered
  schedules on the live mesh across a ladder of message sizes, derives
  per-size winners, and persists a :class:`TuningTable` to
  ``results/tuning.json`` (``benchmarks/run.py --autotune``). The table is
  loaded on startup by :func:`default_cost_model` and overrides the analytic
  ranking wherever it has an entry, turning the ``--sweep-schedules``
  infrastructure into a feedback loop.

``CollectiveEngine`` resolves ``schedule="auto"`` through
:meth:`CostModel.choose` per callsite (cached by op/size/axis signature);
:func:`derive_bucket_bytes` replaces the fixed 32 MiB ``allreduce_tree``
bucket with pipeline depth x per-hop latency-bandwidth product.

Model (single ring axis of n ranks, message of S bytes; ``sync`` is the XLA
collective dispatch/rendezvous overhead in hop units):

====================  =====================================================
op / schedule         hops x alpha                +  wire bytes / beta
====================  =====================================================
bcast/chain           (n-1)                          (n-1) S
bcast/native          sync + n/2                     (n-1) S / 2
bcast/ring2d          2(n-1)                         2 S (n-1)/n
bcast/chain_rooted    2(n-1)                         2(n-1) S
allreduce/chain       (n-1)                          (n-1) S
allreduce/chain_rooted  2(n-1)                       2(n-1) S
allreduce/native      sync + (n-1)                   (n-1)/n S
allreduce/rs_ag       2(n-1)                         2 S (n-1)/n
allreduce/ring2d      sum over torus dims of the per-dim rs_ag ring
allreduce/int8_ef     rs_ag hops                     rs_ag wire x ~0.27
a2a/native            sync + n/2                     (n-1)/n S / 2
a2a/chain             n(n-1)/2                       (n-1) S / 2
ring_exchange/direct  1                              S
transpose/direct      pg                             S
transpose/ring2d      2(pg-1)                        (pg-1)(1+pg) S
* /staged             2 (MPI latency)                (ranks+1) S (PCIe/DCN)
====================  =====================================================

``native`` rides both ring directions (XLA uses all torus links) but pays a
fixed dispatch/rendezvous overhead; the explicit ``chain`` pipeline has no
such overhead, so it wins the latency-bound small-message regime — exactly
the paper's CSN-vs-MPI flip. Lossy schedules (``int8_ef``) are priced but
never *chosen* by ``auto``: compression changes numerics and must stay an
explicit opt-in.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.comm.topology import AxisTopology
from repro.comm.types import TPU_V5E, HardwareModel
from repro.roofline import alpha_beta_time, pipelined_alpha_beta_time

# XLA-native collectives pay a fixed dispatch/rendezvous cost that the
# hand-written ppermute pipelines do not; expressed in per-hop latency units
# so it scales with the hardware model.
NATIVE_SYNC_HOPS = 6.0

# int8_ef wire ratio vs its f32 payload: the quantized chunk plus the
# quantized requantization residual carried alongside on every hop
# (repro.comm.compression quantize_ef, BLOCK=256) =>
# 2 x (1 byte/elem + 4/BLOCK scale bytes) = 2 x (0.25 + 1/256) of f32.
INT8_WIRE_RATIO = 2.0 * (0.25 + 1.0 / 256.0)

# schedules auto must never select: they change numerics (explicit opt-in)
LOSSY_SCHEDULES = frozenset({"int8_ef"})

# software pipelining (engine.pipelined / chunked PTRANS / depth-d HPL):
# chunk-count search ceiling and lookahead-depth ceiling for the resolvers
MAX_PIPELINE_CHUNKS = 16
MAX_LOOKAHEAD_DEPTH = 3

# allreduce_tree pipelining: how many buckets should be in flight so bucket
# k+1's backward compute hides bucket k's ring hops (paper Fig. 5/7 depth)
PIPELINE_DEPTH = 4
MIN_BUCKET_BYTES = 1 << 18   # 256 KiB — below this, per-collective overhead
MAX_BUCKET_BYTES = 32 << 20  # the former fixed default, now the ceiling

_REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_TABLE_PATH = _REPO_ROOT / "results" / "tuning.json"


def default_table_path() -> Path:
    """``results/tuning.json``, overridable via ``REPRO_TUNING_TABLE``."""
    return Path(os.environ.get("REPRO_TUNING_TABLE", DEFAULT_TABLE_PATH))


def axis_signature(axes: Sequence[AxisTopology]) -> str:
    """Canonical topology key, e.g. ``ring[8]`` or
    ``torus_row[2]+torus_col[2]`` — what tuning-table entries are keyed by."""
    return "+".join(f"{a.kind}[{a.size}]" for a in axes)


def _ranks(axes: Sequence[AxisTopology]) -> int:
    n = 1
    for a in axes:
        n *= a.size
    return n


# ---------------------------------------------------------------------------
# per-(op, schedule) analytic shapes
#
# Every schedule is decomposed into *segments* — ``(hops, wire_bytes, kind)``
# triples with kind in {"ici", "staged", "sync"} — priced either monolithic
# (:meth:`CostModel.cost`) or software-pipelined into S chunks
# (:func:`pipelined_cost`). "sync" segments are pure latency (the XLA-native
# dispatch/rendezvous); under pipelining every chunk's collective pays them.
# ---------------------------------------------------------------------------

Segment = Tuple[float, float, str]


def _sync_seg(hw: HardwareModel) -> Segment:
    return (NATIVE_SYNC_HOPS, 0.0, "sync")


def _staged_segs(nbytes: float, axes, hw) -> List[Segment]:
    # every byte transits the staging domain: up to the host network once,
    # back fanned out to all ranks (paper Eq. 2's PCIe+MPI route)
    n = _ranks(axes)
    return [(2, (n + 1) * nbytes, "staged")]


def _ring_rs_ag_segs(nbytes: float, n: int) -> List[Segment]:
    if n <= 1:
        return []
    return [(2 * (n - 1), 2 * (n - 1) / n * nbytes, "ici")]


def _segs_bcast_chain(S, axes, hw):
    n = _ranks(axes)
    return [(n - 1, (n - 1) * S, "ici")]


def _segs_chain_rooted(S, axes, hw):
    # bidirectional rooted chain away from a ring break: both arms relay
    # from the source, worst-case n-1 hops each way, every surviving wire
    # carrying S once per direction. Priced above plain chain (2x hops and
    # wire) so it never wins on a healthy ring — it exists to stay finite
    # when one link is down.
    n = _ranks(axes)
    return [(2 * (n - 1), 2 * (n - 1) * S, "ici")]


def _segs_bcast_native(S, axes, hw):
    # bidirectional all-gather + select: half the hops, both link directions
    n = _ranks(axes)
    return [_sync_seg(hw), (math.ceil(n / 2), (n - 1) * S / 2, "ici")]


def _segs_bcast_ring2d(S, axes, hw):
    # scatter + ring all-gather: 2(n-1) hops of S/n chunks
    return _ring_rs_ag_segs(S, _ranks(axes))


def _segs_allreduce_chain(S, axes, hw):
    n = _ranks(axes)
    return [(n - 1, (n - 1) * S, "ici")]


def _segs_allreduce_native(S, axes, hw):
    # XLA ring reduce-scatter/all-gather over both directions
    n = _ranks(axes)
    return [_sync_seg(hw), (n - 1, (n - 1) / n * S, "ici")]


def _segs_allreduce_rs_ag(S, axes, hw):
    return _ring_rs_ag_segs(S, _ranks(axes))


def _segs_allreduce_ring2d(S, axes, hw):
    # one unidirectional ring pass per torus dimension
    out = []
    for a in axes:
        out += _ring_rs_ag_segs(S, a.size)
    return out


def _segs_allreduce_int8_ef(S, axes, hw):
    return _ring_rs_ag_segs(S * INT8_WIRE_RATIO, _ranks(axes))


def _segs_a2a_native(S, axes, hw):
    n = _ranks(axes)
    return [_sync_seg(hw), (math.ceil(n / 2), (n - 1) / n * S / 2, "ici")]


def _segs_a2a_chain(S, axes, hw):
    # tile at ring distance d travels d hops: sum d = n(n-1)/2 hops of S/n
    n = _ranks(axes)
    return [(n * (n - 1) / 2, (n - 1) / 2 * S, "ici")]


def _segs_exchange_direct(S, axes, hw):
    return [(1, S, "ici")]


def _pg(axes) -> int:
    # grid_transpose runs on a pg x pg torus; a single flattened axis entry
    # (or explicit pair) both reduce to sqrt(total ranks)
    return max(int(round(math.sqrt(_ranks(axes)))), 1)


def _segs_transpose_direct(S, axes, hw):
    # dimension-ordered route to the (r,c)<->(c,r) partner: <= pg links
    pg = _pg(axes)
    if pg <= 1:
        return []  # no exchange on a 1x1 grid
    return [(pg, S, "ici")]


def _segs_transpose_ring2d(S, axes, hw):
    # row-phase ring all-gather (pg-1 unit-block hops) + column-phase chain
    # of the pg-block relay stack (paper Fig. 8 two-phase route)
    pg = _pg(axes)
    if pg <= 1:
        return []
    return [(pg - 1, (pg - 1) * S, "ici"),
            (pg - 1, (pg - 1) * pg * S, "ici")]


_SEGS: Dict[Tuple[str, str], Callable] = {
    ("bcast", "chain"): _segs_bcast_chain,
    ("bcast", "chain_rooted"): _segs_chain_rooted,
    ("bcast", "native"): _segs_bcast_native,
    ("bcast", "ring2d"): _segs_bcast_ring2d,
    ("bcast", "staged"): _staged_segs,
    ("allreduce", "chain"): _segs_allreduce_chain,
    ("allreduce", "chain_rooted"): _segs_chain_rooted,
    ("allreduce", "native"): _segs_allreduce_native,
    ("allreduce", "rs_ag"): _segs_allreduce_rs_ag,
    ("allreduce", "ring2d"): _segs_allreduce_ring2d,
    ("allreduce", "int8_ef"): _segs_allreduce_int8_ef,
    ("allreduce", "staged"): _staged_segs,
    ("all_to_all_tiles", "native"): _segs_a2a_native,
    ("all_to_all_tiles", "chain"): _segs_a2a_chain,
    ("all_to_all_tiles", "staged"): _staged_segs,
    ("ring_exchange", "direct"): _segs_exchange_direct,
    ("ring_exchange", "chain"): _segs_exchange_direct,
    ("ring_exchange", "staged"): _staged_segs,
    ("grid_transpose", "direct"): _segs_transpose_direct,
    ("grid_transpose", "chain"): _segs_transpose_direct,
    ("grid_transpose", "ring2d"): _segs_transpose_ring2d,
    ("grid_transpose", "staged"): _staged_segs,
}


def segments(op: str, schedule: str, nbytes: float,
             axes: Sequence[AxisTopology],
             hw: HardwareModel = TPU_V5E) -> Optional[List[Segment]]:
    """The (hops, wire bytes, kind) decomposition of one schedule run, or
    None for schedules the model has no formula for."""
    fn = _SEGS.get((op, schedule))
    if fn is None:
        return None
    if any(a.kind == "staging" for a in axes):
        return _staged_segs(nbytes, axes, hw)
    return fn(float(nbytes), tuple(axes), hw)


def canonical_health(health: frozenset,
                     axes: Sequence[AxisTopology]) -> frozenset:
    """``health`` with every hop id mapped to its axis's canonical link id
    (:meth:`AxisTopology.canonical_hop`): on a size-2 ring hops 0 and 1
    name the same physical wire, so ``down_link(axis, 1)`` must exclude
    routes recorded as traversing hop 0 and vice versa. Entries naming
    axes outside ``axes`` pass through unchanged."""
    by_name = {a.name: a for a in axes}
    return frozenset(
        (nm, by_name[nm].canonical_hop(h)) if nm in by_name else (nm, h)
        for (nm, h) in health)


def route_links(op: str, schedule: str, axes: Sequence[AxisTopology], *,
                health: frozenset = frozenset()) -> Optional[frozenset]:
    """The set of ``(axis, hop)`` physical links one schedule run may
    traverse, or ``None`` for schedules the model has no formula for
    (nothing provable about their route).

    Links are canonical ids (:meth:`AxisTopology.links` — a size-2 ring
    has ONE wire, id 0, whichever hop name a fault used); ``health`` is
    canonicalized the same way before use.

    ``staged`` — and any run over a staging axis — touches no ICI link:
    its bytes ride PCIe + host MPI, the paper's escape-hatch network.
    ``chain_rooted`` cuts the ring at the down hop named in ``health``
    (the wraparound hop ``size-1`` when clean) and provably never crosses
    it; additional down hops on the same axis stay in its route, so a
    doubly-broken ring still prices as infinite. On a size-2 axis the
    rooted chain has nothing to cut away — every exchange rides the
    single wire — so that wire stays in its route and a down size-2 axis
    falls through to ``staged``. Every other priced ICI schedule is
    conservative: it may ride any link of its axes (XLA routes
    ``native``/``direct`` itself, and the ring pipelines touch every wire
    of the ring).
    """
    if (op, schedule) not in _SEGS:
        return None
    if schedule == "staged" or any(a.kind == "staging" for a in axes):
        return frozenset()
    health = canonical_health(health, axes)
    links = set()
    for a in axes:
        axis_links = set(a.links())
        if schedule == "chain_rooted" and a.n_links > 1:
            down = sorted(h for (nm, h) in health if nm == a.name)
            cut = down[0] if down else a.size - 1
            axis_links.discard((a.name, cut))
        links |= axis_links
    return frozenset(links)


def _seg_time(seg: Segment, hw: HardwareModel) -> float:
    hops, wire, kind = seg
    if kind == "sync":
        return hops * hw.ici_latency
    return alpha_beta_time(hops, wire, hw, staged=kind == "staged")


def _seg_time_pipelined(seg: Segment, nchunks: int, hw: HardwareModel) -> float:
    hops, wire, kind = seg
    if kind == "sync":
        # every chunk's collective pays the dispatch/rendezvous in full
        return nchunks * hops * hw.ici_latency
    return pipelined_alpha_beta_time(hops, wire, nchunks, hw,
                                     staged=kind == "staged")


def pipelined_cost(op: str, schedule: str, nbytes: float,
                   axes: Sequence[AxisTopology], nchunks: int,
                   hw: HardwareModel = TPU_V5E) -> float:
    """Predicted seconds for the schedule split into ``nchunks`` software-
    pipelined chunks (``nchunks=1`` equals :meth:`CostModel.cost`); ``inf``
    for schedules with no formula."""
    segs = segments(op, schedule, nbytes, axes, hw)
    if segs is None:
        return float("inf")
    return sum(_seg_time_pipelined(s, max(int(nchunks), 1), hw) for s in segs)


def best_nchunks(op: str, schedule: str, nbytes: float,
                 axes: Sequence[AxisTopology], hw: HardwareModel = TPU_V5E, *,
                 max_chunks: int = MAX_PIPELINE_CHUNKS) -> Tuple[int, float]:
    """The power-of-two chunk count minimizing :func:`pipelined_cost` —
    pipeline fill cost (S-1 extra stages of per-hop latency) against
    per-chunk wire time. Ties break toward fewer chunks. Returns
    ``(nchunks, predicted_seconds)``; (1, cost) when unpriceable."""
    best_s, best_c = 1, pipelined_cost(op, schedule, nbytes, axes, 1, hw)
    if not math.isfinite(best_c):
        return 1, best_c
    s = 2
    while s <= max_chunks:
        c = pipelined_cost(op, schedule, nbytes, axes, s, hw)
        if c < best_c:
            best_s, best_c = s, c
        s *= 2
    return best_s, best_c


def choose_hpl_depth(*, b: int, m: int, axes: Sequence[AxisTopology],
                     hw: HardwareModel = TPU_V5E, model=None, resolve=None,
                     max_depth: int = MAX_LOOKAHEAD_DEPTH) -> int:
    """Lookahead depth for HPL: how many panel pipelines to keep in flight.

    Per iteration the factorization broadcasts one b x b diagonal block along
    each torus dimension and one b x m panel along each; the bulk trailing
    GEMM offers ``2 m^2 b`` FLOPs of cover. Depth d hides d iterations'
    broadcast latency behind one bulk update, so::

        depth = clamp(ceil(T_bcast_iter / T_gemm_iter), 1, max_depth)

    — latency-bound small blocks on large tori go deep, compute-bound large
    local matrices stay at 1 (one iteration of cover already suffices).
    Each extra depth costs d thin strip GEMMs (~2b/m of the bulk FLOPs) and
    one more carried panel set, which is why the ceiling stays small.

    ``resolve(op, nbytes, ax, callsite)`` optionally names the schedule the
    *caller* will actually run per broadcast (an engine's
    ``schedule_for``, honoring engine-wide overrides and HOST_STAGED) —
    without it the broadcasts are priced on the model's own preferred
    schedule, which under-prices t_comm whenever a costlier schedule is
    forced (exactly the case deep lookahead exists for).
    """
    if model is None:
        model = default_cost_model()
    # keep both sides of the ratio on ONE hardware model: the model's, when
    # it carries one (an engine with a custom CostModel must not have its
    # comm side priced on that hw but its GEMM side on the v5e default)
    hw = getattr(model, "hw", None) or hw
    t_comm = 0.0
    for ax in tuple(axes):
        for nbytes, callsite in ((b * b * 4, "hpl.block"),
                                 (b * m * 4, "hpl.panel")):
            if resolve is not None:
                sched = resolve("bcast", nbytes, ax, callsite)
            else:
                sched = model.choose("bcast", nbytes, (ax,),
                                     callsite=callsite) or "chain"
            t_comm += model.cost("bcast", sched, nbytes, (ax,))
    t_gemm = 2.0 * float(m) * m * b / hw.peak_flops
    if t_gemm <= 0.0:
        return 1
    if not math.isfinite(t_comm):
        # an unpriceable (user-registered / measured-only) schedule: the
        # model can't size the ratio, but infinite comm is comm-bound —
        # clamp to the ceiling instead of overflowing on ceil(inf)
        return max_depth
    return max(1, min(int(math.ceil(t_comm / t_gemm)), max_depth))


# ---------------------------------------------------------------------------
# tuning table (measured mode)
# ---------------------------------------------------------------------------


@dataclass
class TuningTable:
    """Measured per-(op, topology) winners, bucketed by message size.

    ``entries[op][axis_sig]`` is an ascending list of ``[max_bytes, name]``
    pairs; a ``None`` max_bytes entry is the open-ended tail. Lookup returns
    the first entry whose bound covers ``nbytes``.

    The op key may carry a **callsite tag** — ``"bcast@hpl.panel"`` — for
    winners measured in a callsite-specific pattern (e.g. HPL's panel bcast
    issued back-to-back with the block bcast, vs an isolated bcast). Lookup
    with a callsite consults the tagged entry first and falls back to the
    untagged op.
    """
    hw: str = TPU_V5E.name
    entries: Dict[str, Dict[str, List[Tuple[Optional[int], str]]]] = \
        field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)

    def lookup(self, op: str, sig: str, nbytes: int,
               callsite: Optional[str] = None) -> Optional[str]:
        keys = ([f"{op}@{callsite}", op] if callsite else [op])
        for key in keys:
            for bound, name in self.entries.get(key, {}).get(sig, ()):
                if bound is None or nbytes <= bound:
                    return name
        return None

    def set(self, op: str, sig: str,
            bounds: List[Tuple[Optional[int], str]]) -> None:
        self.entries.setdefault(op, {})[sig] = list(bounds)

    def to_json(self) -> Dict:
        return {"hw": self.hw, "meta": self.meta,
                "entries": {op: {sig: [[b, n] for b, n in rows]
                                 for sig, rows in sigs.items()}
                            for op, sigs in self.entries.items()}}

    @classmethod
    def from_json(cls, data: Dict) -> "TuningTable":
        entries = {
            op: {sig: [(None if b is None else int(b), str(n))
                       for b, n in rows]
                 for sig, rows in sigs.items()}
            for op, sigs in data.get("entries", {}).items()}
        return cls(hw=data.get("hw", TPU_V5E.name), entries=entries,
                   meta=data.get("meta", {}))

    def merge(self, other: "TuningTable") -> "TuningTable":
        """A new table with ``other``'s bands overlaid on this one's —
        ``other`` wins wherever both cover an ``(op, signature)`` pair. The
        in-run retune path (:mod:`repro.comm.retune`) merges its narrow
        re-measurement over the persisted full table this way, so cold
        callsites keep their winners."""
        out = TuningTable(
            hw=other.hw or self.hw,
            entries={op: {sig: list(rows) for sig, rows in sigs.items()}
                     for op, sigs in self.entries.items()},
            meta={**self.meta, **other.meta})
        for op, sigs in other.entries.items():
            for sig, rows in sigs.items():
                out.set(op, sig, rows)
        return out

    def save(self, path=None) -> Path:
        path = Path(path or default_table_path())
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def load(cls, path=None) -> Optional["TuningTable"]:
        path = Path(path or default_table_path())
        try:
            with open(path) as f:
                return cls.from_json(json.load(f))
        except (OSError, ValueError, KeyError, TypeError):
            return None


# ---------------------------------------------------------------------------
# the cost model
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class CostModel:
    """Prices registered schedules and picks one per (op, bytes, topology).

    A measured :class:`TuningTable` (when present) overrides the analytic
    alpha-beta ranking for the (op, axis signature) pairs it covers; the
    analytic model covers everything else, so ``auto`` always resolves.
    Choices are memoized by ``(op, nbytes, axis signature, callsite)`` —
    resolution is a pure function of static data, hence identical across
    processes.

    ``health`` is the link-health mask — ``(axis, hop)`` pairs that are
    hard-down (:meth:`repro.comm.faults.FaultInjector.down_links`). Any
    schedule whose provable route (:func:`route_links`) crosses a down
    link prices as infinite, so resolution excludes it; a down ring falls
    through to ``chain_rooted`` (finite away from the cut) and, failing
    that, the host-``staged`` path, which touches no ICI link at all.
    """
    hw: HardwareModel = TPU_V5E
    table: Optional[TuningTable] = None
    health: frozenset = frozenset()
    _cache: Dict[Tuple[str, int, str, Optional[str]], str] = \
        field(default_factory=dict, repr=False)

    def cost(self, op: str, schedule: str, nbytes: float,
             axes: Sequence[AxisTopology]) -> float:
        """Predicted seconds; ``inf`` for schedules the model cannot price
        (e.g. user-registered ones with no formula — never chosen by auto)
        and for any schedule whose route crosses a link in ``health``."""
        if self.health:
            health = canonical_health(self.health, axes)
            links = route_links(op, schedule, axes, health=health)
            if links is None or links & health:
                return float("inf")
        segs = segments(op, schedule, nbytes, axes, self.hw)
        if segs is None:
            return float("inf")
        return sum(_seg_time(s, self.hw) for s in segs)

    def pipelined_cost(self, op: str, schedule: str, nbytes: float,
                       axes: Sequence[AxisTopology], nchunks: int) -> float:
        """Predicted seconds with the payload split into ``nchunks``
        software-pipelined chunks (:func:`pipelined_cost`)."""
        return pipelined_cost(op, schedule, nbytes, axes, nchunks, self.hw)

    def best_nchunks(self, op: str, schedule: str, nbytes: float,
                     axes: Sequence[AxisTopology], *,
                     max_chunks: int = MAX_PIPELINE_CHUNKS
                     ) -> Tuple[int, float]:
        return best_nchunks(op, schedule, nbytes, axes, self.hw,
                            max_chunks=max_chunks)

    def rank(self, op: str, nbytes: float, axes: Sequence[AxisTopology], *,
             include_lossy: bool = False) -> List[Tuple[str, float]]:
        """Registered schedules for ``op`` sorted by predicted cost. Ties
        break toward the op's static default, then by name, so the ranking
        is deterministic (aliases like ``chain``-for-``direct`` price
        identically). Lossy schedules are excluded unless requested — auto
        must never change numerics."""
        from repro.comm.engine import _AUTO, schedules_for
        default = _AUTO.get(op)
        rows = []
        for name in schedules_for(op):
            if name in LOSSY_SCHEDULES and not include_lossy:
                continue
            c = self.cost(op, name, nbytes, axes)
            if math.isfinite(c):
                rows.append((name, c))
        return sorted(rows, key=lambda r: (r[1], r[0] != default, r[0]))

    def choose(self, op: str, nbytes: int, axes: Sequence[AxisTopology],
               callsite: Optional[str] = None) -> Optional[str]:
        """The schedule ``auto`` resolves to, or None if nothing is priced.

        ``callsite`` is an optional tag (``"hpl.panel"``, ``"ptrans.
        exchange"``) naming the call pattern; measured tuning-table entries
        keyed ``op@callsite`` override the untagged op entry for it. The
        analytic ranking is callsite-independent."""
        sig = axis_signature(axes)
        key = (op, int(nbytes), sig, callsite)
        if key in self._cache:
            return self._cache[key]
        name = None
        if self.table is not None:
            name = self.table.lookup(op, sig, int(nbytes), callsite)
            if name is not None:
                from repro.comm.engine import schedules_for
                if name not in schedules_for(op) or name in LOSSY_SCHEDULES:
                    name = None  # stale table entry: fall back to analytic
            if name is not None and self.health and not math.isfinite(
                    self.cost(op, name, nbytes, axes)):
                name = None  # measured winner routes through a down link
        if name is None:
            ranked = self.rank(op, nbytes, axes)
            name = ranked[0][0] if ranked else None
        self._cache[key] = name
        return name


_DEFAULT_MODEL: Optional[CostModel] = None


def _table_matches_runtime(table: Optional[TuningTable]) -> bool:
    """A measured table only applies to the backend it was measured on —
    a tuning.json produced on the simulated CPU mesh (e.g. the CI artifact)
    must not override the analytic model on real TPU."""
    if table is None:
        return False
    recorded = table.meta.get("backend")
    if recorded is None:
        return True  # hand-written table: caller's responsibility
    import jax
    return recorded == jax.default_backend()


def default_cost_model(refresh: bool = False) -> CostModel:
    """Process-wide model the engine uses for ``schedule="auto"``: analytic
    alpha-beta on :data:`TPU_V5E`, overlaid with ``results/tuning.json``
    when a measured table exists *for this backend*. ``refresh=True``
    re-reads the table (after ``benchmarks/run.py --autotune``)."""
    global _DEFAULT_MODEL
    if _DEFAULT_MODEL is None or refresh:
        table = TuningTable.load()
        if not _table_matches_runtime(table):
            table = None
        _DEFAULT_MODEL = CostModel(hw=TPU_V5E, table=table)
    return _DEFAULT_MODEL


# ---------------------------------------------------------------------------
# derived bucket size for allreduce_tree
# ---------------------------------------------------------------------------


def derive_bucket_bytes(axes: Sequence[AxisTopology],
                        hw: HardwareModel = TPU_V5E, *,
                        depth: int = PIPELINE_DEPTH) -> int:
    """Bucket size for the bucketed tree reduction, from topology + link
    numbers instead of a fixed constant.

    A bucket's ring reduction occupies ``2(n-1)`` hops; with ``depth``
    buckets in flight the per-bucket payload should cover that hop latency
    at link bandwidth — ``depth x 2(n-1) x (alpha x beta)`` (the per-hop
    latency-bandwidth product). Rounded up to a power of two and clamped to
    [:data:`MIN_BUCKET_BYTES`, :data:`MAX_BUCKET_BYTES`] (the former fixed
    default is now the ceiling)."""
    n = _ranks(axes)
    if n <= 1:
        return MIN_BUCKET_BYTES
    raw = depth * 2 * (n - 1) * hw.ici_latency * hw.ici_link_bw
    raw = max(raw, MIN_BUCKET_BYTES)
    return int(min(1 << math.ceil(math.log2(raw)), MAX_BUCKET_BYTES))


# ---------------------------------------------------------------------------
# measured mode: microbenchmark the registered schedules on the live mesh
# ---------------------------------------------------------------------------


def _winner_bounds(sizes: Sequence[int],
                   winners: Sequence[str]) -> List[Tuple[Optional[int], str]]:
    """Collapse per-size winners into [max_bytes, name] bands; boundaries
    sit at the geometric mean of adjacent measured sizes."""
    bounds: List[Tuple[Optional[int], str]] = []
    for i, name in enumerate(winners):
        last = i == len(winners) - 1
        if bounds and bounds[-1][1] == name:
            bounds.pop()  # extend the previous band
        edge = None if last else int(math.sqrt(sizes[i] * sizes[i + 1]))
        bounds.append((edge, name))
    if bounds and bounds[-1][0] is not None:
        bounds[-1] = (None, bounds[-1][1])
    return bounds


def _measure_op(mesh, op: str, nbytes: int, schedule: str,
                reps: int) -> float:
    """Best-of-reps seconds for one (op, schedule, size) on the live mesh,
    plus the active fault injector's modeled delay for that exact run
    (:func:`repro.comm.faults.measured_extra_time`) — a degraded link
    perturbs the measured winners consistently with the analytic view."""
    t = _measure_op_clean(mesh, op, nbytes, schedule, reps)
    from repro.comm import faults
    if faults.active_injector() is not None:
        from repro.comm.topology import MeshTopology
        topo = MeshTopology.from_mesh(mesh)
        if "@" in op:
            # tagged patterns run along one axis (see autotune_mesh)
            axes = (topo.axis(topo.names()[0]),)
        else:
            axes = tuple(topo.axis(a) for a in topo.names())
        t += faults.measured_extra_time(op.split("@", 1)[0], schedule,
                                        nbytes, axes)
    return t


def _measure_op_clean(mesh, op: str, nbytes: int, schedule: str,
                      reps: int) -> float:
    """Best-of-reps seconds for one (op, schedule, size) on the live mesh."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.comm.engine import CollectiveEngine
    from repro.compat import shard_map
    from repro.core.hpcc import timeit

    engine = CollectiveEngine.for_mesh(mesh, schedule=schedule)
    names = tuple(mesh.shape)
    nranks = int(np.prod([mesh.shape[a] for a in names]))
    elems = max(nbytes // 4, 1)

    if op == "bcast@hpl.panel":
        # HPL's paired broadcasts on the torus row axis: a b x b diagonal
        # block bcast immediately followed by the dependent panel bcast being
        # measured — the callsite pattern an isolated bcast misses.
        rows = mesh.shape[names[0]]
        blk = jnp.asarray(np.ones((rows, 64 * 64), np.float32))
        x = jnp.asarray(np.ones((rows, elems), np.float32))
        spec = P(names[0], None)

        def body(vb, vp):
            b0 = engine.bcast(vb[0], names[0], 0)
            panel = vp[0] * b0[0]  # the trsm dependency block -> panel
            return engine.bcast(panel, names[0], 0)[None]

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, blk, x, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@moe.dispatch":
        # MoE's paired exchanges on the ring: the dispatch all-to-all
        # (experts split across ranks, batch shards gathered), the expert
        # compute touching every landed tile, and the inverse combine
        # exchange — measured back-to-back, the pattern an isolated
        # all-to-all misses (the second exchange departs while the first's
        # rendezvous state is still warm).
        L = max(elems // nranks, 1)
        x = jnp.asarray(np.ones((nranks, nranks, L), np.float32))
        spec = P(names[0], None, None)

        def body(v):
            # v is the local (B_loc=1, E=nranks, L) dispatch buffer
            buf = engine.all_to_all_tiles(v, names[0], split_axis=1,
                                          concat_axis=0)  # dispatch
            buf = jax.nn.silu(buf) * buf  # stand-in expert FFN
            return engine.all_to_all_tiles(buf, names[0], split_axis=0,
                                           concat_axis=1)  # combine

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, x, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@tp.qkv":
        # whole-model head-parallel attention pattern: THREE back-to-back
        # head-gathering exchanges (q, k, v), the attention compute touching
        # every landed tile, then the inverse batch-restoring exchange — the
        # four-exchange burst an isolated all-to-all misses.
        L = max(elems // nranks, 1)
        x = jnp.asarray(np.ones((nranks, nranks, L), np.float32))
        spec = P(names[0], None, None)

        def body(t):
            # t is the local (B_loc=1, H=nranks, L) activation
            def gather(a):  # heads split out, batch gathered
                return engine.all_to_all_tiles(a, names[0], split_axis=1,
                                               concat_axis=0)
            q, k, v = gather(t), gather(t * 0.5), gather(t * 0.25)
            o = jax.nn.softmax(q * k, axis=-1) * v  # attention stand-in
            return engine.all_to_all_tiles(o, names[0], split_axis=0,
                                           concat_axis=1)

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, x, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@sp.qkv":
        # whole-model sequence-parallel ring attention pattern: the seq-
        # gathering exchanges for q/k/v, the k/v block circulating the ring
        # (~n/2 bidirectional hops) with the online-softmax fold between
        # hops, then the inverse exchange — the a2a's rendezvous interleaves
        # with the ring traffic, which an isolated all-to-all misses.
        L = max(elems // nranks, 1)
        x = jnp.asarray(np.ones((nranks, nranks, L), np.float32))
        spec = P(names[0], None, None)

        def body(v):
            def gather(a):  # sequence split out, batch gathered
                return engine.all_to_all_tiles(a, names[0], split_axis=1,
                                               concat_axis=0)
            q, k, kv = gather(v), gather(v * 0.5), gather(v * 0.25)
            acc = jax.nn.softmax(q * k, axis=-1) * kv  # local block fold
            fwd = bwd = kv
            for _ in range(max(nranks // 2, 1)):
                fwd, bwd = engine.ring_exchange(fwd, bwd, names[0])
                acc = acc + jax.nn.softmax(q * fwd, axis=-1) * bwd
            return engine.all_to_all_tiles(acc, names[0], split_axis=0,
                                           concat_axis=1)

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, x, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@decode.qkv":
        # per-token decode pattern: q and the token's k/v ride three tiny
        # head-gathering exchanges, single-query attention against the page
        # pool runs between them, the inverse exchange restores the batch
        # layout, and the MoE dispatch/FFN/combine pair follows — six
        # back-to-back latency-bound exchanges, the serving burst an
        # isolated (training-sized) all-to-all measurement misses. Sized by
        # the decode ladder in :func:`autotune_mesh`, not the default one.
        L = max(elems // nranks, 1)
        x = jnp.asarray(np.ones((nranks, nranks, L), np.float32))
        pool = jnp.asarray(np.ones((nranks, 8, L), np.float32))
        spec = P(names[0], None, None)

        def body(t, pg_):
            def gather(a):  # heads split out, batch gathered
                return engine.all_to_all_tiles(a, names[0], split_axis=1,
                                               concat_axis=0)
            q, k, v = gather(t), gather(t * 0.5), gather(t * 0.25)
            s = jax.nn.softmax(q * pg_[:, :1] + k, axis=-1)  # paged attn
            o = engine.all_to_all_tiles(s * v, names[0], split_axis=0,
                                        concat_axis=1)
            buf = engine.all_to_all_tiles(o, names[0], split_axis=1,
                                          concat_axis=0)  # moe dispatch
            buf = jax.nn.silu(buf) * buf  # stand-in expert FFN
            return engine.all_to_all_tiles(buf, names[0], split_axis=0,
                                           concat_axis=1)  # moe combine

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, x, pool, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@ra.updates":
        # GUPS update routing on the ring: the bucketed (n_dev, L, 2) int32
        # (local_index, value) exchange followed by the receiving
        # scatter-add — the latency-corner pattern (small irregular int
        # payloads, a serialized scatter on landing) an isolated float
        # all-to-all misses.
        L = max(elems // (2 * nranks), 1)  # nranks*L*2 int32 = nbytes
        tbl = jnp.asarray(np.zeros((nranks, 4096), np.int32))
        buf = jnp.asarray(np.ones((nranks, nranks, L, 2), np.int32))
        spec_t = P(names[0], None)
        spec_b = P(names[0], None, None, None)

        def body(t, b):
            recv = engine.all_to_all_tiles(b[0], names[0], split_axis=0,
                                           concat_axis=0)
            out = t[0].at[recv[..., 0].reshape(-1)].add(
                recv[..., 1].reshape(-1), mode="drop")
            return out[None]

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec_t, spec_b),
                               out_specs=spec_t, check_vma=False))
        _, t = timeit(fn, tbl, buf, reps=reps, warmup=1)
        return t

    if op == "all_to_all_tiles@fft.transpose":
        # pencil-FFT global transpose on the ring: the signal-gathering
        # exchange, the local full-signal FFT, and the inverse scatter
        # back-to-back — paired exchanges with the transform between them
        # (direction-symmetric, so one tag covers both directions).
        ns = max(elems // (2 * nranks), 1)  # complex64: 8 B per element
        x = jnp.asarray(np.ones((nranks, nranks, 1, ns), np.complex64))
        spec = P(names[0], None, None, None)

        def body(v):
            b = v[0]  # (B=nranks, 1, ns) local pencils
            g = engine.all_to_all_tiles(b, names[0], split_axis=0,
                                        concat_axis=1)
            s = jnp.fft.fft(g.reshape(g.shape[0], -1), axis=-1)
            s = s.reshape(g.shape)
            return engine.all_to_all_tiles(s, names[0], split_axis=1,
                                           concat_axis=0)[None]

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                               out_specs=spec, check_vma=False))
        _, t = timeit(fn, x, reps=reps, warmup=1)
        return t

    if op == "grid_transpose":
        pg = mesh.shape[names[0]]
        side = max(int(math.sqrt(elems)), 1)
        x = jnp.asarray(np.ones((nranks, side, side), np.float32))
        spec = P(tuple(names), None, None)
        body = (lambda v: engine.grid_transpose(v[0], tuple(names), pg)[None])
    elif op == "ring_exchange":
        x = jnp.asarray(np.ones((nranks, elems), np.float32))
        spec = P(names[0], None)
        body = (lambda v: engine.ring_exchange(v[0], v[0], names[0])[0][None])
    elif op == "bcast":
        x = jnp.asarray(np.ones((nranks, elems), np.float32))
        spec = P(names[0], None)
        body = (lambda v: engine.bcast(v[0], names[0], 0)[None])
    elif op == "allreduce":
        ax = tuple(names) if len(names) > 1 else names[0]
        x = jnp.asarray(np.ones((nranks, elems), np.float32))
        spec = P(tuple(names) if len(names) > 1 else names[0], None)
        body = (lambda v: engine.allreduce(v[0], ax)[None])
    else:  # all_to_all_tiles
        x = jnp.asarray(np.ones((nranks, nranks * max(elems // nranks, 1)),
                                np.float32))
        spec = P(names[0], None)
        body = (lambda v: engine.all_to_all_tiles(
            v[0], names[0], split_axis=0, concat_axis=0)[None])

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                           check_vma=False))
    _, t = timeit(fn, x, reps=reps, warmup=1)
    return t


# callsite patterns that time *both* directions of a paired exchange: the
# measured winner applies to every tag of the pair
PAIRED_ALIASES: Dict[str, Tuple[str, ...]] = {
    "all_to_all_tiles@moe.dispatch": ("all_to_all_tiles@moe.combine",),
    "all_to_all_tiles@tp.qkv": ("all_to_all_tiles@tp.out",),
    "all_to_all_tiles@sp.qkv": ("all_to_all_tiles@sp.out",),
    "all_to_all_tiles@decode.qkv": ("all_to_all_tiles@decode.out",
                                    "all_to_all_tiles@decode.moe"),
}

# the per-token decode pattern is measured at decode-sized payloads (one
# token's q/k/v across the whole batch is a few KiB) instead of the
# training-sized default ladder — serving lives in the latency band
DECODE_SIZES = (1 << 8, 1 << 11, 1 << 14)
DECODE_SIZES_QUICK = (1 << 8, 1 << 12)

# callsite patterns measured on the square torus (HPL's row/column
# broadcasts); everything else — including the MoE paired exchange — runs
# on the all-device ring
_TORUS_OPS = ("grid_transpose", "bcast@hpl.panel")


def autotune_mesh(*, ops: Sequence[str] = ("bcast", "allreduce",
                                           "all_to_all_tiles",
                                           "ring_exchange", "grid_transpose",
                                           "bcast@hpl.panel",
                                           "all_to_all_tiles@moe.dispatch",
                                           "all_to_all_tiles@tp.qkv",
                                           "all_to_all_tiles@sp.qkv",
                                           "all_to_all_tiles@decode.qkv",
                                           "all_to_all_tiles@ra.updates",
                                           "all_to_all_tiles@fft.transpose"),
                  sizes: Optional[Sequence[int]] = None, reps: int = 3,
                  quick: bool = False, verbose: bool = True
                  ) -> Tuple[TuningTable, Dict]:
    """Measure every registered exact schedule per op on the live devices and
    build a :class:`TuningTable` of per-size winners.

    Ring ops run over a ring of all devices; ``grid_transpose`` over the
    largest square torus. An ``op@callsite`` entry measures the op inside
    that callsite's pattern and lands under the tagged tuning-table key,
    consulted first when the engine resolves with the matching callsite:
    ``"bcast@hpl.panel"`` times HPL's panel bcast back-to-back with the
    diagonal-block bcast on the torus row axis, and
    ``"all_to_all_tiles@moe.dispatch"`` times the MoE dispatch exchange,
    a stand-in expert FFN, and the inverse combine exchange back-to-back on
    the ring (the winner lands under both ``@moe.dispatch`` and
    ``@moe.combine`` — the pattern is direction-symmetric). The whole-model
    attention patterns measure the same way: ``"all_to_all_tiles@tp.qkv"``
    times the q/k/v head-gathering burst plus the inverse batch-restoring
    exchange (winner aliased to ``@tp.out``), and
    ``"all_to_all_tiles@sp.qkv"`` the seq-gathering exchanges interleaved
    with the ring-attention kv hops (winner aliased to ``@sp.out``; the
    hops themselves fall back to the untagged ``ring_exchange`` entry).
    ``"all_to_all_tiles@decode.qkv"`` times one serving decode step's
    six-exchange burst (q/k/v head gathers, paged attention, inverse, MoE
    dispatch/combine) at decode-sized payloads — its own size ladder
    (:data:`DECODE_SIZES`), since per-token messages sit far below the
    training sizes; the winner lands under ``@decode.out`` and
    ``@decode.moe`` too. ``"all_to_all_tiles@ra.updates"`` times the GUPS
    bucketed int32 update exchange plus the receiving scatter-add, and
    ``"all_to_all_tiles@fft.transpose"`` the pencil-FFT gather / local
    transform / inverse-scatter sandwich (both on the ring; each tag keys
    its own entry — no alias). Returns ``(table, record)`` where ``record``
    holds the raw per-(op, schedule, size) timings for the bench
    artifact."""
    import jax

    from repro.comm.engine import schedules_for
    from repro.comm.topology import MeshTopology
    from repro.compat import make_mesh

    default_sizes = sizes is None
    if sizes is None:
        sizes = ((1 << 10, 1 << 16) if quick
                 else (1 << 10, 1 << 14, 1 << 18, 1 << 22))
    reps = 2 if quick else reps

    ndev = len(jax.devices())
    ring = make_mesh((ndev,), ("x",))
    pg = int(math.isqrt(ndev))
    torus = make_mesh((pg, pg), ("rows", "cols")) if pg >= 2 else None

    table = TuningTable(meta={"devices": ndev, "sizes": list(sizes),
                              "backend": jax.default_backend()})
    record: Dict[str, Dict] = {}
    for op in ops:
        base_op = op.split("@", 1)[0]
        mesh = torus if op in _TORUS_OPS else ring
        if mesh is None:
            continue
        topo = MeshTopology.from_mesh(mesh)
        if "@" in op:
            # callsite patterns are measured along one axis; the HPL pattern
            # is row/column-symmetric, so the winner is stored under every
            # single-axis signature (the l_panel bcast on "cols", sig
            # torus_col[pg], matches too). On the ring there is one axis.
            sig = axis_signature([topo.axis(topo.names()[0])])
            extra_sigs = [axis_signature([topo.axis(a)])
                          for a in topo.names()[1:]]
        else:
            sig = axis_signature([topo.axis(a) for a in topo.names()])
            extra_sigs = []
        names = [s for s in schedules_for(base_op)
                 if s not in LOSSY_SCHEDULES]
        op_sizes = sizes
        if default_sizes and op.endswith("@decode.qkv"):
            op_sizes = DECODE_SIZES_QUICK if quick else DECODE_SIZES
        winners, measured_sizes = [], []
        for S in op_sizes:
            times = {}
            for name in names:
                try:
                    times[name] = _measure_op(mesh, op, S, name, reps)
                except Exception as e:  # noqa: BLE001 — skip broken combos
                    if verbose:
                        print(f"  [autotune] {op}/{name}@{S}B failed: {e}")
            if not times:
                continue  # winners stay aligned with measured_sizes
            best = min(sorted(times), key=times.get)
            winners.append(best)
            measured_sizes.append(S)
            record[f"{op}/{sig}/{S}"] = {"winner": best, "times_s": times}
            if verbose:
                ladder = " ".join(f"{n}={times[n]*1e3:.2f}ms"
                                  for n in sorted(times))
                print(f"  [autotune] {op:16s} {S:>9d}B -> {best:8s} ({ladder})")
        if winners:
            bounds = _winner_bounds(measured_sizes, winners)
            for key in (op,) + PAIRED_ALIASES.get(op, ()):
                for s in [sig] + extra_sigs:
                    table.set(key, s, bounds)
    return table, record
