"""Distributed primitives — thin compatibility layer over the engine.

The schedule implementations now live in :mod:`repro.comm.engine`, registered
by name (``chain`` / ``native`` / ``staged`` / ``ring2d`` / ``rs_ag``) and
selected through :class:`repro.comm.engine.CollectiveEngine`. The keyword
functions here preserve the original ad-hoc ``(comm, schedule)`` signatures
for external callers; in-repo code routes through an engine instance.

All functions run inside a ``shard_map`` body.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.comm.engine import CollectiveEngine
from repro.comm.topology import ring_perm
from repro.comm.types import CommunicationType, comm_type
from repro.compat import axis_size


def axis_index(axis: str):
    return lax.axis_index(axis)


def _engine(comm, schedule: str) -> CollectiveEngine:
    return CollectiveEngine(comm=comm_type(comm), schedule=schedule)


# ---------------------------------------------------------------------------
# ring primitives (b_eff pattern)
# ---------------------------------------------------------------------------


def ring_shift(x: jnp.ndarray, axis: str, shift: int = 1) -> jnp.ndarray:
    """Send ``x`` to the neighbor ``shift`` hops along the ring; receive the
    buffer from the opposite neighbor. One circuit-switched hop."""
    n = axis_size(axis)
    return lax.ppermute(x, axis, ring_perm(n, shift))


def ring_exchange_bidir(x_fwd: jnp.ndarray, x_bwd: jnp.ndarray, axis: str,
                        comm=CommunicationType.ICI_DIRECT):
    """Bidirectional neighbor exchange (the b_eff message pattern). Returns
    (recv_from_left, recv_from_right)."""
    return _engine(comm, "auto").ring_exchange(x_fwd, x_bwd, axis)


# ---------------------------------------------------------------------------
# broadcast along a torus row/column (HPL panel broadcast)
# ---------------------------------------------------------------------------


def ring_bcast(val: jnp.ndarray, axis: str, src, comm=CommunicationType.ICI_DIRECT,
               schedule: str = "chain") -> jnp.ndarray:
    """Broadcast ``val`` from rank ``src`` (traced scalar ok) along ``axis``
    with the named schedule (see :mod:`repro.comm.engine`)."""
    return _engine(comm, schedule).bcast(val, axis, src)


# ---------------------------------------------------------------------------
# all-to-all tile exchange (PTRANS / MoE dispatch pattern)
# ---------------------------------------------------------------------------


def all_to_all_tiles(x: jnp.ndarray, axis: str, *, split_axis: int,
                     concat_axis: int, comm=CommunicationType.ICI_DIRECT,
                     schedule: str = "native") -> jnp.ndarray:
    """Exchange tiles so rank i's j-th split lands on rank j; rank j
    concatenates received tiles ordered by source rank on ``concat_axis``."""
    return _engine(comm, schedule).all_to_all_tiles(
        x, axis, split_axis=split_axis, concat_axis=concat_axis)


def roll_with_axis(x: jnp.ndarray, shift, axis: int) -> jnp.ndarray:
    """jnp.roll with traced shift along ``axis``."""
    n = x.shape[axis]
    idx = (jnp.arange(n) - shift) % n
    return jnp.take(x, idx, axis=axis)


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------


def psum_schedule(x: jnp.ndarray, axis: str, comm=CommunicationType.ICI_DIRECT,
                  schedule: str = "native") -> jnp.ndarray:
    """All-reduce over ``axis`` with the named schedule."""
    return _engine(comm, schedule).allreduce(x, axis)
