"""Distributed primitives with one implementation per CommunicationType.

All functions are designed to run inside a ``shard_map`` body. Three
schedules exist where relevant:

* ``chain``  — paper-faithful circuit-switched store-and-forward: data moves
  hop-by-hop via ``ppermute`` along the ring/torus, exactly like the paper's
  network kernels forwarding blocks through the CSN (Figs. 2, 6, 8).
* ``native`` — beyond-paper: XLA's native collective (all_gather/psum/
  all_to_all), which uses all torus links in both directions.
* ``staged`` — the PCIe+MPI analogue: every byte is routed through a shared
  staging domain (emulated intra-pod as gather+select; across the ``pod``
  mesh axis XLA itself must stage over DCN, which is the real host network).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.topology import ring_perm
from repro.comm.types import CommunicationType, comm_type


def axis_size(axis: str) -> int:
    return lax.axis_size(axis)


def axis_index(axis: str):
    return lax.axis_index(axis)


# ---------------------------------------------------------------------------
# ring primitives (b_eff pattern)
# ---------------------------------------------------------------------------


def ring_shift(x: jnp.ndarray, axis: str, shift: int = 1) -> jnp.ndarray:
    """Send ``x`` to the neighbor ``shift`` hops along the ring; receive the
    buffer from the opposite neighbor. One circuit-switched hop."""
    n = axis_size(axis)
    return lax.ppermute(x, axis, ring_perm(n, shift))


def ring_exchange_bidir(x_fwd: jnp.ndarray, x_bwd: jnp.ndarray, axis: str,
                        comm=CommunicationType.ICI_DIRECT):
    """Bidirectional neighbor exchange (the b_eff message pattern: each rank
    sends simultaneously to both ring neighbors). Returns (recv_from_left,
    recv_from_right)."""
    ct = comm_type(comm)
    if ct is CommunicationType.ICI_DIRECT:
        recv_l = ring_shift(x_fwd, axis, +1)   # left neighbor's fwd buffer
        recv_r = ring_shift(x_bwd, axis, -1)   # right neighbor's bwd buffer
        return recv_l, recv_r
    # staged: both buffers transit the staging domain (gather + select)
    n = axis_size(axis)
    idx = axis_index(axis)
    all_f = lax.all_gather(x_fwd, axis)  # (n, ...)
    all_b = lax.all_gather(x_bwd, axis)
    recv_l = jnp.take(all_f, (idx - 1) % n, axis=0)
    recv_r = jnp.take(all_b, (idx + 1) % n, axis=0)
    return recv_l, recv_r


# ---------------------------------------------------------------------------
# broadcast along a torus row/column (HPL panel broadcast)
# ---------------------------------------------------------------------------


def ring_bcast(val: jnp.ndarray, axis: str, src, comm=CommunicationType.ICI_DIRECT,
               schedule: str = "chain") -> jnp.ndarray:
    """Broadcast ``val`` from rank ``src`` (traced scalar ok) to all ranks
    along ``axis``.

    chain   : (n-1)-hop store-and-forward pipeline (paper network kernels).
    native  : one-hot mask + psum (single XLA all-reduce on the axis).
    staged  : all_gather + select.
    """
    ct = comm_type(comm)
    n = axis_size(axis)
    idx = axis_index(axis)
    if ct is CommunicationType.HOST_STAGED or schedule == "staged":
        allv = lax.all_gather(val, axis)
        return jnp.take(allv, src, axis=0)
    if schedule == "native":
        # all-gather + select: (n-1)/n wire vs the masked-psum broadcast's
        # 2(n-1)/n — measured 2x on the production HPL torus (§Perf C4).
        # (psum would also need a zero-mask: non-source ranks hold inf/nan
        # garbage from speculative local factorizations.)
        allv = lax.all_gather(val, axis)
        return jnp.take(allv, src, axis=0)
    # chain: after k hops, ranks src..src+k (mod n) hold the value
    out = val
    for _ in range(n - 1):
        nxt = ring_shift(out, axis, +1)
        out = jnp.where(idx == src, out, nxt)
    return out


# ---------------------------------------------------------------------------
# all-to-all tile exchange (PTRANS / MoE dispatch pattern)
# ---------------------------------------------------------------------------


def all_to_all_tiles(x: jnp.ndarray, axis: str, *, split_axis: int,
                     concat_axis: int, comm=CommunicationType.ICI_DIRECT,
                     schedule: str = "native") -> jnp.ndarray:
    """Exchange tiles so rank i's j-th split lands on rank j; rank j
    concatenates received tiles ordered by source rank on ``concat_axis``.

    native : lax.all_to_all (XLA uses all links).
    chain  : n-1 ppermute rounds, one ring distance per round (paper CSN
             schedule: every tile travels hop-by-hop through the ring).
    staged : all_gather + local slice (every byte transits the staging domain).
    """
    ct = comm_type(comm)
    n = axis_size(axis)
    idx = axis_index(axis)
    chunk = x.shape[split_axis] // n

    if ct is CommunicationType.HOST_STAGED or schedule == "staged":
        gathered = lax.all_gather(x, axis)  # (n, ...): every rank's buffer
        outs = []
        for s in range(n):  # tile ``idx`` from each source rank s, in order
            row = jnp.squeeze(lax.dynamic_slice_in_dim(gathered, s, 1, 0), 0)
            outs.append(lax.dynamic_slice_in_dim(row, idx * chunk, chunk, split_axis))
        return jnp.concatenate(outs, axis=concat_axis)

    if schedule == "chain":
        received = []
        for dist in range(n):
            # the tile this rank owes the rank ``dist`` hops to its right is
            # split index (idx + dist) mod n; forward it ``dist`` hops.
            send = lax.dynamic_slice_in_dim(
                x, ((idx + dist) % n) * chunk, chunk, split_axis)
            recv = send
            for _ in range(dist):
                recv = ring_shift(recv, axis, +1)
            received.append(recv)  # tile from source rank (idx - dist) mod n
        stacked = jnp.stack(received, axis=0)  # indexed by dist
        # output position s holds the tile from source s = (idx - dist) mod n,
        # i.e. dist = (idx - s) mod n
        perm = (idx - jnp.arange(n)) % n
        by_src = jnp.take(stacked, perm, axis=0)
        return jnp.concatenate([by_src[s] for s in range(n)], axis=concat_axis)

    return lax.all_to_all(x, axis, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def roll_with_axis(x: jnp.ndarray, shift, axis: int) -> jnp.ndarray:
    """jnp.roll with traced shift along ``axis``."""
    n = x.shape[axis]
    idx = (jnp.arange(n) - shift) % n
    return jnp.take(x, idx, axis=axis)


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------


def psum_schedule(x: jnp.ndarray, axis: str, comm=CommunicationType.ICI_DIRECT,
                  schedule: str = "native") -> jnp.ndarray:
    """All-reduce.  chain = ring reduce (n-1 hops, paper-style); native =
    lax.psum; staged = all_gather + local sum."""
    ct = comm_type(comm)
    n = axis_size(axis)
    if ct is CommunicationType.HOST_STAGED or schedule == "staged":
        return jnp.sum(lax.all_gather(x, axis), axis=0)
    if schedule == "chain":
        acc = x
        buf = x
        for _ in range(n - 1):
            buf = ring_shift(buf, axis, +1)
            acc = acc + buf
        return acc
    return lax.psum(x, axis)
