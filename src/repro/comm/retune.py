"""Adaptive in-run retuning: the static tuning table made a runtime.

ROADMAP item 5, motivated by the ACCL latency study (PAPERS.md): schedule
winners flip when link conditions change, so a long-running job must
detect drift and re-resolve — not trust a table measured at startup.

:class:`RetuneController` watches per-callsite step timings. Its state
machine::

    BASELINE --(min_baseline samples)--> WATCH
    WATCH    --(recent median drifts past drift_factor x baseline,
                or a StragglerMonitor flag: policy "retune")--> RETUNE
    RETUNE   --(re-price / re-measure, invalidate, re-arm)--> BASELINE

Drift detection is **two-sided**: a degraded link slows steps (ratio
above ``drift_factor``), a healed one speeds them (ratio below
``1/drift_factor``) — both mean the current resolutions were priced on
stale conditions, and both trigger.

A retune is deliberately *narrow*: only the hot callsites (the streams
that drifted) are re-resolved. Two refresh paths compose:

* ``hw_probe`` — a callable returning the current
  :class:`~repro.comm.types.HardwareModel` (link telemetry; in tests and
  benchmarks, :meth:`repro.comm.faults.FaultInjector.hardware_view`). The
  engine's analytic ranking is re-priced on it. Deterministic — this is
  what the CI gate asserts on. ``health_probe`` is its hard-failure
  sibling: a callable returning the current link-health mask (in tests,
  :meth:`repro.comm.faults.FaultInjector.down_links`), so a retune also
  excludes every route crossing a link that is *gone*, not just slow.
* ``measure=True`` — a narrow :func:`~repro.comm.autotune.autotune_mesh`
  ladder over only the hot callsites' tagged patterns, at sizes bracketing
  their live payloads; the refreshed winners are merged over the engine's
  existing table (and persisted to ``table_path`` when given). While a
  fault injector is active the measurements include its injected delays,
  so measured winners flip consistently with the analytic view.

Either way the swap lands through
:meth:`~repro.comm.engine.CollectiveEngine.invalidate_resolutions` — the
engine object persists; callers rebuild their (cheap) jitted step from it
and the next trace resolves fresh.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from statistics import median
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

RETUNE_TRIGGERS = ("drift", "straggler", "forced")

_STEP_STREAM = "step"  # the untagged whole-step timing stream


@dataclass(frozen=True)
class Watched:
    """One callsite the controller re-resolves on a retune.

    ``op`` / ``nbytes`` / ``axis`` are the engine resolution key the
    callsite runs at — what ``schedule_for`` is queried with before and
    after the swap, and what sizes the narrow measured ladder brackets.
    """
    callsite: str
    op: str
    nbytes: int
    axis: object  # axis name or tuple of names


@dataclass
class RetuneEvent:
    """Provenance for one retune: what fired it and what it changed."""
    step: int
    trigger: str                       # one of RETUNE_TRIGGERS
    hot: Tuple[str, ...]               # callsites re-tuned
    detect_steps: int                  # samples between arming and trigger
    duration_s: float = 0.0
    before: Dict[str, str] = field(default_factory=dict)
    after: Dict[str, str] = field(default_factory=dict)

    @property
    def changed(self) -> Dict[str, Tuple[str, str]]:
        return {cs: (b, self.after[cs]) for cs, b in self.before.items()
                if self.after.get(cs, b) != b}


class _Stream:
    """One callsite's timing samples since the last (re-)arm."""

    def __init__(self, recent: int):
        self.samples: List[float] = []
        self.recent: Deque[float] = deque(maxlen=recent)
        self.baseline: Optional[float] = None

    def add(self, duration: float, min_baseline: int) -> None:
        self.samples.append(duration)
        self.recent.append(duration)
        if self.baseline is None and len(self.samples) >= min_baseline:
            self.baseline = median(self.samples[:min_baseline])

    def drift(self, factor: float) -> Optional[float]:
        """The recent/baseline median ratio when it breaches ``factor``
        either way; None while armed-but-nominal or still collecting."""
        if self.baseline is None or self.baseline <= 0.0 \
                or len(self.recent) < self.recent.maxlen:
            return None
        ratio = median(self.recent) / self.baseline
        if ratio > factor or ratio < 1.0 / factor:
            return ratio
        return None


class RetuneController:
    """Watches step timings and swaps the engine's schedule resolutions.

    ``engine``       the :class:`~repro.comm.engine.CollectiveEngine` whose
                     resolutions to refresh (its cost model is mutated in
                     place — pass an engine built with an explicit
                     ``cost_model`` to keep the process default untouched).
    ``watched``      :class:`Watched` entries — the callsites a retune
                     re-resolves and reports on.
    ``drift_factor`` two-sided trigger threshold on recent/baseline medians.
    ``recent``       samples in the recent-median window.
    ``min_baseline`` samples collected before a stream arms.
    ``cooldown``     observations ignored after each retune (lets the new
                     schedule's timings settle before re-arming decisions).
    ``hw_probe``     optional ``() -> HardwareModel`` link telemetry.
    ``health_probe`` optional ``() -> frozenset`` of hard-down ``(axis,
                     hop)`` links (the injector's ``down_links``); fed to
                     ``invalidate_resolutions(health=...)`` on retune.
    ``measure``      run the narrow measured ladder on retune.
    ``table_path``   where to persist the merged table after a measured
                     retune (None = in-memory only).
    """

    def __init__(self, engine, watched: Sequence[Watched], *,
                 drift_factor: float = 1.75, recent: int = 3,
                 min_baseline: int = 5, cooldown: int = 8,
                 hw_probe: Optional[Callable] = None,
                 health_probe: Optional[Callable] = None,
                 measure: bool = False,
                 sizes: Optional[Sequence[int]] = None, reps: int = 2,
                 quick: bool = True, table_path=None, verbose: bool = False):
        if drift_factor <= 1.0:
            raise ValueError("drift_factor must exceed 1.0")
        if not watched:
            raise ValueError("RetuneController needs at least one Watched "
                             "callsite")
        self.engine = engine
        self.watched = tuple(watched)
        self.drift_factor = float(drift_factor)
        self.recent = int(recent)
        self.min_baseline = int(min_baseline)
        self.cooldown = int(cooldown)
        self.hw_probe = hw_probe
        self.health_probe = health_probe
        self.measure = measure
        self.sizes = tuple(sizes) if sizes is not None else None
        self.reps = int(reps)
        self.quick = quick
        self.table_path = table_path
        self.verbose = verbose
        self.events: List[RetuneEvent] = []
        self._streams: Dict[str, _Stream] = {}
        self._cooldown_left = 0

    # -- observation --------------------------------------------------------

    def observe(self, step: int, duration: float,
                callsite: Optional[str] = None) -> Optional[RetuneEvent]:
        """Record one timing sample (whole-step when ``callsite`` is None)
        and retune if it tips a stream past the drift threshold. Returns
        the event when a retune ran."""
        key = callsite or _STEP_STREAM
        stream = self._streams.get(key)
        if stream is None:
            stream = self._streams[key] = _Stream(self.recent)
        stream.add(float(duration), self.min_baseline)
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            return None
        hot = self._hot()
        if not hot:
            return None
        return self.retune(step, trigger="drift", hot=hot)

    def on_straggler(self, step: int) -> Optional[RetuneEvent]:
        """A StragglerMonitor flag under policy ``"retune"``: force a
        retune of every watched callsite (None during cooldown)."""
        if self._cooldown_left > 0:
            return None
        return self.retune(step, trigger="straggler")

    def _hot(self) -> List[str]:
        """Callsites whose stream drifted; the untagged step stream counts
        for every watched callsite."""
        hot: List[str] = []
        for key, stream in self._streams.items():
            if stream.drift(self.drift_factor) is None:
                continue
            if key == _STEP_STREAM:
                return [w.callsite for w in self.watched]
            if key not in hot:
                hot.append(key)
        return hot

    # -- the retune itself --------------------------------------------------

    def resolutions(self) -> Dict[str, str]:
        """Current per-watched-callsite resolved schedule names."""
        return {w.callsite: self.engine.schedule_for(
                    w.op, nbytes=w.nbytes, axis=w.axis, callsite=w.callsite)
                for w in self.watched}

    def retune(self, step: int, *, trigger: str = "forced",
               hot: Optional[Sequence[str]] = None) -> RetuneEvent:
        """Re-resolve the hot callsites (all watched by default): re-price
        on ``hw_probe``'s current link numbers and/or re-measure the narrow
        ladder, then invalidate the engine's resolution cache. Re-arms
        every stream and starts the cooldown."""
        if trigger not in RETUNE_TRIGGERS:
            raise ValueError(f"unknown retune trigger {trigger!r}; "
                             f"triggers are {RETUNE_TRIGGERS}")
        hot = tuple(hot) if hot else tuple(w.callsite for w in self.watched)
        detect = max((len(s.samples) - self.min_baseline
                      for s in self._streams.values()), default=0)
        t0 = time.perf_counter()
        before = self.resolutions()
        kwargs: Dict[str, object] = {}
        if self.hw_probe is not None:
            kwargs["hw"] = self.hw_probe()
        if self.health_probe is not None:
            kwargs["health"] = frozenset(self.health_probe())
        if self.measure:
            kwargs["table"] = self._measure_hot(hot)
        self.engine.invalidate_resolutions(**kwargs)
        after = self.resolutions()
        event = RetuneEvent(step=step, trigger=trigger, hot=hot,
                            detect_steps=detect,
                            duration_s=time.perf_counter() - t0,
                            before=before, after=after)
        self.events.append(event)
        self._streams.clear()
        self._cooldown_left = self.cooldown
        if self.verbose:
            print(f"  [retune] step {step} ({trigger}): "
                  f"{event.changed or 'no schedule change'} "
                  f"in {event.duration_s * 1e3:.1f}ms")
        return event

    def _measure_hot(self, hot: Sequence[str]):
        """The narrow measured ladder: only the hot callsites' tagged
        patterns (untagged op as the fallback), at sizes bracketing their
        live payloads, merged over the engine's current table."""
        from repro.comm.autotune import TuningTable, autotune_mesh
        from repro.comm.callsites import CALLSITES
        ops: List[str] = []
        sizes = set(self.sizes or ())
        for w in self.watched:
            if w.callsite not in hot:
                continue
            cs = CALLSITES.get(w.callsite)
            key = cs.tuned if cs is not None and cs.tuned else w.op
            if key not in ops:
                ops.append(key)
            if self.sizes is None:
                sizes |= {max(int(w.nbytes) // 4, 256), int(w.nbytes),
                          int(w.nbytes) * 4}
        fresh, _ = autotune_mesh(ops=tuple(ops), sizes=sorted(sizes),
                                 reps=self.reps, quick=self.quick,
                                 verbose=self.verbose)
        base = getattr(self.engine._model(), "table", None)
        merged = base.merge(fresh) if isinstance(base, TuningTable) else fresh
        if self.table_path is not None:
            merged.save(self.table_path)
        return merged
