"""Collective engine: named communication schedules behind one API.

The paper's core architecture is one set of benchmark kernels running over
interchangeable communication paths (circuit-switched inter-FPGA links vs
host-staged PCIe+MPI). ACCL-style engines show the productive way to express
that: a *schedule registry* — every collective op has named implementations
("schedules") registered against it, and a :class:`CollectiveEngine` selects
one per op from ``(CommunicationType, schedule name)`` plus per-axis topology
metadata (:class:`repro.comm.topology.MeshTopology`). Callers hold an engine
and never branch on comm/schedule themselves. ``schedule="auto"`` resolves
per callsite through the :mod:`repro.comm.autotune` cost model from the
payload size and axis topology (measured tuning table first, analytic
alpha-beta ranking otherwise).

Ops
---
``bcast(val, axis, src)``                     one-to-all along a ring/torus dim
``all_to_all_tiles(x, axis, split/concat)``   PTRANS / MoE dispatch exchange
``allreduce(x, axis)``                        gradient / scalar reduction
``allreduce_tree(tree, axis, bucket_bytes)``  bucketed pytree reduction — the
                                              overlap structure of the paper's
                                              Fig. 5/7 applied to gradients
``ring_exchange(fwd, bwd, axis)``             b_eff bidirectional neighbor swap
``grid_transpose(x, axes, pg)``               PTRANS partner exchange on a torus
``pipelined(op, x, axis, nchunks=...)``       software-pipelining transform:
                                              split any single-payload op
                                              (bcast / allreduce /
                                              grid_transpose /
                                              all_to_all_tiles) into S
                                              in-flight chunks whose
                                              per-chunk consumer compute
                                              overlaps the next chunk's wire
                                              hops (chunk count from the
                                              autotune fill-cost model)

Schedules
---------
``chain``   paper-faithful store-and-forward: hop-by-hop ``ppermute`` rounds
            (the CSN network kernels of Figs. 2/6/8).
``native``  XLA's native collective — all torus links, both directions.
``staged``  host-staged analogue: every byte transits the staging domain
            (all_gather + local select). Forced whenever the engine's comm
            type is ``HOST_STAGED``.
``ring2d``  torus-aware two-phase ring schedules: bcast = scatter +
            ring all-gather (2(n-1)/n wire vs chain's (n-1)); allreduce =
            per-torus-dimension ring reduce-scatter/all-gather, applied
            row-then-column for tuple axes; grid_transpose = dimension-
            ordered row-hop-then-column-hop route to the transpose partner
            (paper Fig. 8's two-phase torus route).
``rs_ag``   bandwidth-optimal ring reduce-scatter + all-gather allreduce;
            the per-hop accumulate is the Pallas-fused step in
            :mod:`repro.kernels.ring`.
``int8_ef`` int8 block-quantized allreduce wire format riding the ``rs_ag``
            ring, with the per-hop requantization residual carried alongside
            the payload (error feedback *inside* the ring; cross-step error
            feedback is carried by the caller — see
            :func:`repro.comm.compression.compressed_psum`).
``direct``  point-to-point ``ppermute`` (ring_exchange / grid_transpose).
``chain_rooted``  the dead-link escape route for bcast/allreduce: a
            bidirectional store-and-forward chain rooted so neither arm
            crosses the ring's cut hop (the hard-down link in the cost
            model's health mask; the wraparound hop when clean). Priced
            at 2x chain so it never wins on a healthy ring — resolution
            falls through to it when a down link prices everything else
            infinite (and to ``staged``, which touches no ICI link, when
            even the rooted chain cannot avoid the break).

Registering a new schedule::

    from repro.comm.engine import register_schedule

    @register_schedule("allreduce", "mytree")
    def _allreduce_mytree(engine, x, axis):
        ...  # runs inside shard_map; use lax/ppermute freely
        return reduced

    CollectiveEngine(schedule="mytree").allreduce(x, "x")

All schedule bodies run inside ``shard_map``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.overlap import DEFAULT_BUCKET_BYTES, pack_buckets
from repro.comm.topology import MeshTopology, ring_perm, transpose_perm
from repro.comm.types import CommunicationType, comm_type
from repro.compat import axis_size

OPS: Tuple[str, ...] = ("bcast", "all_to_all_tiles", "allreduce",
                        "ring_exchange", "grid_transpose")

_REGISTRY: Dict[str, Dict[str, Callable]] = {op: {} for op in OPS}

# static per-op fallbacks for schedule="auto" — used only when the cost
# model has nothing to go on (no topology, no payload size, unknown axis);
# with both available, auto resolves through repro.comm.autotune per callsite
_AUTO = {
    "bcast": "chain",
    "all_to_all_tiles": "native",
    "allreduce": "native",
    "ring_exchange": "direct",
    "grid_transpose": "direct",
}


def _payload_bytes(x) -> Optional[int]:
    """Static byte size of an array/tracer (shapes are static under jit)."""
    try:
        return int(x.size) * jnp.dtype(x.dtype).itemsize
    except (TypeError, AttributeError):
        return None


class UnknownScheduleError(ValueError):
    """Raised for a schedule name no op has registered."""


def register_schedule(op: str, name: str):
    """Decorator: register ``fn(engine, *args, **kw)`` as schedule ``name``
    for collective ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown collective op {op!r}; ops are {OPS}")

    def deco(fn):
        _REGISTRY[op][name] = fn
        return fn
    return deco


def schedules_for(op: str) -> Tuple[str, ...]:
    """Registered schedule names for ``op``, sorted."""
    return tuple(sorted(_REGISTRY[op]))


def known_schedules() -> Tuple[str, ...]:
    names = {"auto"}
    for op in OPS:
        names.update(_REGISTRY[op])
    return tuple(sorted(names))


# ---------------------------------------------------------------------------
# shared ring helpers (shard_map-body level)
# ---------------------------------------------------------------------------


def _ring_shift(x, axis, shift=1):
    n = axis_size(axis)
    return lax.ppermute(x, axis, ring_perm(n, shift))


def _pack_chunks(x, n):
    """Flatten + zero-pad ``x`` into an (n, L) chunk stack."""
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(n, -1)


def _chunk(stack, k):
    """Chunk ``k`` (traced ok) of an (n, L) stack."""
    return jnp.squeeze(lax.dynamic_slice_in_dim(stack, k, 1, 0), 0)


def _set_chunk(stack, k, val):
    return lax.dynamic_update_slice(stack, val[None], (k, 0))


def _fused_add(engine, acc, recv):
    if jnp.issubdtype(acc.dtype, jnp.floating):
        from repro.kernels.ring import fused_chunk_add
        interp = engine.interpret
        if interp is None:  # auto: compile on TPU, interpret elsewhere
            interp = jax.default_backend() != "tpu"
        return fused_chunk_add(acc, recv, interpret=interp)
    return acc + recv


# ---------------------------------------------------------------------------
# bcast schedules
# ---------------------------------------------------------------------------


@register_schedule("bcast", "chain")
def _bcast_chain(engine, val, axis, src):
    # (n-1)-hop store-and-forward pipeline: after k hops ranks src..src+k
    # hold the value (the paper's network-kernel forwarding).
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    out = val
    for _ in range(n - 1):
        nxt = _ring_shift(out, axis, +1)
        out = jnp.where(idx == src, out, nxt)
    return out


@register_schedule("bcast", "native")
@register_schedule("bcast", "staged")
def _bcast_gather(engine, val, axis, src):
    # all_gather + select: (n-1)/n wire vs the masked-psum broadcast's
    # 2(n-1)/n; non-source ranks may hold inf/nan garbage (speculative local
    # factorizations), so a psum would need a zero-mask anyway. Under
    # HOST_STAGED this is also the staging-domain route: every byte transits
    # the gather.
    allv = lax.all_gather(val, axis)
    return jnp.take(allv, src, axis=0)


def _cut_hop(engine, axis, n: int) -> int:
    """The hop the rooted chain must not cross — static at trace time.

    The smallest hard-down hop of ``axis`` in the engine's cost-model
    health mask (:meth:`repro.comm.autotune.CostModel`), else the
    wraparound hop ``n-1``: a clean rooted chain simply avoids the
    wraparound wire. With several down hops on one axis the chain can
    only avoid the first; the others stay in its priced route, so
    resolution never picks it there (:func:`repro.comm.autotune
    .route_links`)."""
    model = engine._model()
    health = getattr(model, "health", None) or frozenset()
    down = sorted(h for (a, h) in health if a == axis)
    return down[0] if down else n - 1


@register_schedule("bcast", "chain_rooted")
def _bcast_chain_rooted(engine, val, axis, src):
    # Bidirectional chain rooted at ``src``, re-indexed so path position 0
    # sits just past the cut and position n-1 just before it: the forward
    # arm relays src -> tail, the backward arm src -> head, and the masks
    # make the two cut-crossing adoptions impossible (pos 0 never takes a
    # forward hop, pos n-1 never a backward one) — so no adopted value
    # ever traversed the down link, provably.
    n = axis_size(axis)
    if n == 1:
        return val
    cut = _cut_hop(engine, axis, n)
    idx = lax.axis_index(axis)
    pos = (idx - (cut + 1)) % n
    spos = (src - (cut + 1)) % n
    f = b = val
    for _ in range(n - 1):
        nf = _ring_shift(f, axis, +1)
        f = jnp.where(pos > spos, nf, f)
        nb = _ring_shift(b, axis, -1)
        b = jnp.where(pos < spos, nb, b)
    return jnp.where(pos < spos, b, f)


@register_schedule("bcast", "ring2d")
def _bcast_ring2d(engine, val, axis, src):
    # torus-aware two-phase ring bcast (scatter + ring all-gather): the
    # value is split into n chunks; the scatter pipeline injects chunk d at
    # step n-1-d so every chunk reaches its owner by step n-2, then a ring
    # all-gather circulates the owned chunks. Wire: 2(n-1)/n of the payload
    # per link vs chain's (n-1) — each of HPL's row/column broadcasts uses
    # only its own torus dimension, so both dimensions stream concurrently.
    n = axis_size(axis)
    if n == 1:
        return val
    idx = lax.axis_index(axis)
    chunks = _pack_chunks(val, n)
    L = chunks.shape[1]
    dist = (idx - src) % n

    # phase 1 — scatter: src injects chunks n-1, n-2, ..., 0; everyone else
    # forwards. After step s, the rank at distance d carries the chunk src
    # injected at step s-(d-1); at the final step that is chunk d.
    carry = _chunk(chunks, (n - 1) % n)
    for s in range(n - 1):
        recv = _ring_shift(carry, axis, +1)
        inject = _chunk(chunks, (n - 2 - s) % n)
        carry = jnp.where(idx == src, inject, recv)
    own = jnp.where(dist == 0, _chunk(chunks, 0), carry)

    # phase 2 — ring all-gather of the owned chunks
    out = jnp.zeros((n, L), val.dtype)
    out = _set_chunk(out, dist, own)
    cur = own
    for s in range(n - 1):
        cur = _ring_shift(cur, axis, +1)
        out = _set_chunk(out, (dist - 1 - s) % n, cur)
    return out.reshape(-1)[: val.size].reshape(val.shape)


# ---------------------------------------------------------------------------
# all_to_all_tiles schedules
# ---------------------------------------------------------------------------


@register_schedule("all_to_all_tiles", "native")
def _a2a_native(engine, x, axis, *, split_axis, concat_axis):
    return lax.all_to_all(x, axis, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


@register_schedule("all_to_all_tiles", "chain")
def _a2a_chain(engine, x, axis, *, split_axis, concat_axis):
    # n-1 ppermute rounds, one ring distance per round (paper CSN schedule:
    # every tile travels hop-by-hop through the ring).
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    chunk = x.shape[split_axis] // n
    received = []
    for dist in range(n):
        # the tile this rank owes the rank ``dist`` hops to its right is
        # split index (idx + dist) mod n; forward it ``dist`` hops.
        send = lax.dynamic_slice_in_dim(
            x, ((idx + dist) % n) * chunk, chunk, split_axis)
        recv = send
        for _ in range(dist):
            recv = _ring_shift(recv, axis, +1)
        received.append(recv)  # tile from source rank (idx - dist) mod n
    stacked = jnp.stack(received, axis=0)  # indexed by dist
    # output position s holds the tile from source s = (idx - dist) mod n,
    # i.e. dist = (idx - s) mod n
    perm = (idx - jnp.arange(n)) % n
    by_src = jnp.take(stacked, perm, axis=0)
    return jnp.concatenate([by_src[s] for s in range(n)], axis=concat_axis)


@register_schedule("all_to_all_tiles", "staged")
def _a2a_staged(engine, x, axis, *, split_axis, concat_axis):
    # every byte transits the staging domain (gather + local slice)
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    chunk = x.shape[split_axis] // n
    gathered = lax.all_gather(x, axis)  # (n, ...): every rank's buffer
    outs = []
    for s in range(n):  # tile ``idx`` from each source rank s, in order
        row = jnp.squeeze(lax.dynamic_slice_in_dim(gathered, s, 1, 0), 0)
        outs.append(lax.dynamic_slice_in_dim(row, idx * chunk, chunk,
                                             split_axis))
    return jnp.concatenate(outs, axis=concat_axis)


# ---------------------------------------------------------------------------
# allreduce schedules
# ---------------------------------------------------------------------------


@register_schedule("allreduce", "native")
def _allreduce_native(engine, x, axis):
    return lax.psum(x, axis)


@register_schedule("allreduce", "chain")
def _allreduce_chain(engine, x, axis):
    # ring reduce: n-1 full-payload hops, paper-style store-and-forward
    n = axis_size(axis)
    acc = x
    buf = x
    for _ in range(n - 1):
        buf = _ring_shift(buf, axis, +1)
        acc = acc + buf
    return acc


@register_schedule("allreduce", "chain_rooted")
def _allreduce_chain_rooted(engine, x, axis):
    # Dead-link allreduce: reduce along the open path to its head, then
    # chain-broadcast the total back. Path position 0 sits just past the
    # cut (see _cut_hop); backward shifts bring pos p the payload of pos
    # p+r, masked to zero whenever p+r walked off the path end — i.e.
    # whenever that contribution would have crossed the down link — so
    # the head's accumulator is the exact left-to-right path-order sum
    # and nothing adopted ever traversed the cut. The return broadcast is
    # the forward arm of the rooted chain (pos 0 never adopts), leaving
    # every rank with the head's bitwise-identical total.
    if isinstance(axis, (tuple, list)):
        for ax in axis:
            x = _allreduce_chain_rooted(engine, x, ax)
        return x
    n = axis_size(axis)
    if n == 1:
        return x
    cut = _cut_hop(engine, axis, n)
    idx = lax.axis_index(axis)
    pos = (idx - (cut + 1)) % n
    zeros = jnp.zeros_like(x)
    acc = x
    buf = x
    for r in range(1, n):
        buf = _ring_shift(buf, axis, -1)
        acc = acc + jnp.where(pos + r <= n - 1, buf, zeros)
    f = acc
    for _ in range(n - 1):
        nf = _ring_shift(f, axis, +1)
        f = jnp.where(pos > 0, nf, f)
    return f


@register_schedule("allreduce", "staged")
def _allreduce_staged(engine, x, axis):
    return jnp.sum(lax.all_gather(x, axis), axis=0)


@register_schedule("allreduce", "rs_ag")
def _allreduce_rs_ag(engine, x, axis):
    # bandwidth-optimal ring allreduce: reduce-scatter then all-gather,
    # 2(n-1)/n of the payload per link. The per-hop accumulate is the
    # Pallas-fused step (repro.kernels.ring) — receive buffer and local
    # chunk stream through VMEM once.
    if isinstance(axis, (tuple, list)):
        # torus: one ring pass per dimension (row-then-column)
        for ax in axis:
            x = _allreduce_rs_ag(engine, x, ax)
        return x
    n = axis_size(axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    stack = _pack_chunks(x, n)

    # reduce-scatter: step s sends chunk (idx-s) right, accumulates the
    # incoming chunk (idx-1-s). After n-1 steps rank i owns chunk (i+1)%n.
    for s in range(n - 1):
        send = _chunk(stack, (idx - s) % n)
        recv = _ring_shift(send, axis, +1)
        local = _chunk(stack, (idx - 1 - s) % n)
        stack = _set_chunk(stack, (idx - 1 - s) % n,
                           _fused_add(engine, local, recv))

    # all-gather: circulate the owned chunk around the ring
    cur = _chunk(stack, (idx + 1) % n)
    for s in range(n - 1):
        cur = _ring_shift(cur, axis, +1)
        stack = _set_chunk(stack, (idx - s) % n, cur)
    return stack.reshape(-1)[: x.size].reshape(x.shape)


@register_schedule("allreduce", "ring2d")
def _allreduce_ring2d(engine, x, axis):
    # torus-aware row/column schedule: a ring reduce-scatter/all-gather per
    # torus dimension. For a single axis this is exactly rs_ag.
    return _allreduce_rs_ag(engine, x, axis)


@register_schedule("allreduce", "int8_ef")
def _allreduce_int8_ef(engine, x, axis):
    # int8 block-quantized wire format over the bandwidth-optimal ring, with
    # the quantization applied *per ring chunk, per hop* and the per-hop
    # requantization residual carried ALONGSIDE the payload: every ppermute
    # moves the int8 chunk plus the int8-quantized residual of that same
    # quantization (2 bytes/elem + 8/BLOCK bytes/elem of scales per hop),
    # never a whole-bucket fp32 buffer. The receiver reconstructs
    # payload + residual, so the error each hop leaks is only the residual's
    # *own* requantization — second-order, O(1/127^2) of the chunk magnitude
    # per hop — tightening the lossy bound from O(hops/127) to O(hops/127^2)
    # ~ O(1/127) overall (the ROADMAP in-ring error-feedback item).
    # Reduce-scatter hops quantize the outgoing partial-sum chunk right
    # before the shift; the all-gather half quantizes each owner's reduced
    # chunk (and its residual) once and forwards both int8 payloads
    # unchanged around the ring, so all ranks agree bitwise. Accumulation
    # stays in fp32 via the fused Pallas step. Exact whenever every hop's
    # chunk is block-representable — see tests/dist/test_overlap.py. The
    # schedule stays stateless: error feedback *across steps* is carried by
    # the caller, see :func:`repro.comm.compression.compressed_psum`.
    from repro.comm.compression import dequantize_ef, quantize_ef
    if isinstance(axis, (tuple, list)):
        for ax in axis:
            x = _allreduce_int8_ef(engine, x, ax)
        return x
    n = axis_size(axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    stack = _pack_chunks(x.astype(jnp.float32), n)

    def _shift_q(chunk):
        # one ring hop of the quantized wire format: payload chunk plus its
        # requantization-residual chunk travel together
        wire = quantize_ef(chunk)
        wire = tuple(_ring_shift(w, axis, +1) for w in wire)
        return dequantize_ef(*wire, chunk.shape, chunk.size)

    # reduce-scatter: same chunk walk as rs_ag, int8+residual per hop
    for s in range(n - 1):
        send = _chunk(stack, (idx - s) % n)
        recv = _shift_q(send)
        local = _chunk(stack, (idx - 1 - s) % n)
        stack = _set_chunk(stack, (idx - 1 - s) % n,
                           _fused_add(engine, local, recv))

    # all-gather: quantize the owned chunk (and its residual) once; every
    # rank (owner included) keeps the reconstructed wire value so all ranks
    # agree bitwise
    own = _chunk(stack, (idx + 1) % n)
    wire = quantize_ef(own)
    stack = _set_chunk(stack, (idx + 1) % n,
                       dequantize_ef(*wire, own.shape, own.size))
    for s in range(n - 1):
        wire = tuple(_ring_shift(w, axis, +1) for w in wire)
        stack = _set_chunk(stack, (idx - s) % n,
                           dequantize_ef(*wire, own.shape, own.size))
    return stack.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# ring_exchange schedules
# ---------------------------------------------------------------------------


@register_schedule("ring_exchange", "direct")
@register_schedule("ring_exchange", "chain")
def _exchange_direct(engine, x_fwd, x_bwd, axis):
    # one circuit-switched hop in each direction (b_eff message pattern)
    recv_l = _ring_shift(x_fwd, axis, +1)  # left neighbor's fwd buffer
    recv_r = _ring_shift(x_bwd, axis, -1)  # right neighbor's bwd buffer
    return recv_l, recv_r


@register_schedule("ring_exchange", "staged")
def _exchange_staged(engine, x_fwd, x_bwd, axis):
    # both buffers transit the staging domain (gather + select)
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    all_f = lax.all_gather(x_fwd, axis)  # (n, ...)
    all_b = lax.all_gather(x_bwd, axis)
    recv_l = jnp.take(all_f, (idx - 1) % n, axis=0)
    recv_r = jnp.take(all_b, (idx + 1) % n, axis=0)
    return recv_l, recv_r


# ---------------------------------------------------------------------------
# grid_transpose schedules (PTRANS partner exchange)
# ---------------------------------------------------------------------------


@register_schedule("grid_transpose", "direct")
@register_schedule("grid_transpose", "chain")
def _transpose_direct(engine, x, axes, pg):
    # pure point-to-point circuit-switched exchange with the grid-transpose
    # partner (paper §2.2.2)
    return lax.ppermute(x, axes, transpose_perm(pg))


@register_schedule("grid_transpose", "staged")
def _transpose_staged(engine, x, axes, pg):
    # all_gather over the full grid + local selection: every block transits
    # the staging domain (paper §2.2.1 via PCIe+MPI)
    row_ax, col_ax = axes
    g = lax.all_gather(x, axes)  # (P*P, ...)
    r = lax.axis_index(row_ax)
    c = lax.axis_index(col_ax)
    return jnp.squeeze(lax.dynamic_slice_in_dim(g, c * pg + r, 1, 0), 0)


@register_schedule("grid_transpose", "ring2d")
def _transpose_ring2d(engine, x, axes, pg):
    # dimension-ordered two-phase torus route (paper Fig. 8): the block from
    # (r, c) reaches its transpose partner (c, r) over row links only, then
    # column links only, relayed by the diagonal rank (r, r) — the common
    # intermediate of every (r, *) -> (*, r) route.
    #
    # Phase 1 (row hops): hop-by-hop ring all-gather along the column axis,
    # so each diagonal rank ends up holding all of its grid row. Phase 2
    # (column hops): chain-forward the relay stack down each column from its
    # diagonal rank; rank (r, c) finally keeps the block whose source is
    # (c, r). Wire: (pg-1) unit-block row hops + (pg-1) stacked column hops,
    # vs ``direct``'s single (XLA-routed) partner ppermute.
    row_ax, col_ax = axes
    if pg == 1:
        return x
    r = lax.axis_index(row_ax)
    c = lax.axis_index(col_ax)
    zeros = (0,) * x.ndim

    stack = jnp.zeros((pg,) + x.shape, x.dtype)
    stack = lax.dynamic_update_slice(stack, x[None], (c,) + zeros)
    cur = x
    for s in range(pg - 1):
        cur = _ring_shift(cur, col_ax, +1)  # now from column (c - 1 - s)
        stack = lax.dynamic_update_slice(stack, cur[None],
                                         ((c - 1 - s) % pg,) + zeros)

    # each column's ring runs the chain independently; src row index == c
    out = stack
    for _ in range(pg - 1):
        nxt = _ring_shift(out, row_ax, +1)
        out = jnp.where(r == c, out, nxt)
    return jnp.squeeze(lax.dynamic_slice(out, (r,) + zeros, (1,) + x.shape), 0)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CollectiveEngine:
    """Selects one registered schedule per collective op.

    ``comm``      the paper's Fig. 1 backend selector. ``HOST_STAGED`` forces
                  the ``staged`` schedule for every op (all bytes through the
                  staging domain), matching the paper's PCIe+MPI bitstreams.
    ``schedule``  a registered schedule name, or ``"auto"`` to resolve per
                  callsite through the cost model (:mod:`repro.comm.autotune`)
                  from the payload size and the axis topology — analytic
                  alpha-beta ranking overlaid with the measured tuning table
                  when ``results/tuning.json`` exists. Without topology or
                  payload information auto falls back to static per-op
                  defaults. A name registered for *some* ops only (e.g.
                  ``chain`` has no dedicated ring_exchange variant) resolves
                  like auto for the uncovered ops — so ``--schedule chain``
                  applies suite-wide without per-op plumbing.
    ``topology``  optional :class:`MeshTopology` for axis validation, cost-
                  model resolution, and result provenance (``describe()``).
    ``interpret`` Pallas interpret flag for fused steps; None (default)
                  resolves to compiled on TPU, interpret elsewhere — the
                  same rule as :mod:`repro.kernels.ops`.
    ``cost_model`` optional explicit :class:`repro.comm.autotune.CostModel`;
                  None uses the process-wide default (analytic + persisted
                  tuning table).
    """
    comm: CommunicationType = CommunicationType.ICI_DIRECT
    schedule: str = "auto"
    topology: Optional[MeshTopology] = None
    interpret: Optional[bool] = None
    cost_model: Optional[object] = None

    def __post_init__(self):
        object.__setattr__(self, "comm", comm_type(self.comm))
        if self.schedule != "auto" and self.schedule not in known_schedules():
            raise UnknownScheduleError(
                f"unknown schedule {self.schedule!r}; registered schedules "
                f"are {sorted(known_schedules())}")

    @classmethod
    def for_mesh(cls, mesh, comm=CommunicationType.ICI_DIRECT,
                 schedule: str = "auto", **kw) -> "CollectiveEngine":
        return cls(comm=comm_type(comm), schedule=schedule,
                   topology=MeshTopology.from_mesh(mesh), **kw)

    # -- schedule resolution ------------------------------------------------

    def schedule_for(self, op: str, override: Optional[str] = None, *,
                     nbytes: Optional[int] = None, axis=None,
                     callsite: Optional[str] = None) -> str:
        """The schedule name this engine runs ``op`` with.

        With ``nbytes`` (message payload) and ``axis`` (a topology axis name
        or tuple), ``auto`` resolves through the cost model; without them it
        falls back to the static per-op default, so provenance queries keep
        working outside any callsite. The returned name is always a
        registered schedule, never the literal ``"auto"`` — benchmarks call
        this with the per-callsite payload to *report* what actually ran.

        ``callsite`` is an optional tag from the central registry
        (:mod:`repro.comm.callsites` — ``"hpl.panel"``, ``"moe.dispatch"``,
        ``"tp.qkv"``, ``"dp.grads"``, ...) letting measured tuning-table
        entries distinguish call patterns: HPL's back-to-back bcasts tune
        independently of an isolated bcast, and the paired attention
        exchanges inherit the entry measured for their forward tag (the
        ``PAIRED_ALIASES`` mapping in :mod:`repro.comm.autotune`).

        An explicit ``override`` must be registered for ``op``
        (:class:`UnknownScheduleError` otherwise — checked before the
        HOST_STAGED short-circuit so typos fail under every comm type);
        HOST_STAGED always resolves to ``"staged"``; an engine-wide name
        that does not cover ``op`` falls back to auto-resolution rather
        than erroring, so one engine can drive ops with disjoint schedule
        sets."""
        if op not in OPS:
            raise ValueError(f"unknown collective op {op!r}; ops are {OPS}")
        if override is not None and override != "auto" \
                and override not in _REGISTRY[op]:
            # explicit per-call override must exist for the op — checked
            # before the HOST_STAGED short-circuit so a typo'd override
            # fails under every comm type, not only ICI_DIRECT
            raise UnknownScheduleError(
                f"schedule {override!r} is not registered for op {op!r}; "
                f"available: {sorted(_REGISTRY[op])}")
        if self.comm is CommunicationType.HOST_STAGED:
            return "staged"
        name = override or self.schedule
        if name != "auto" and name in _REGISTRY[op]:
            return name
        # "auto", or an engine-wide name that doesn't cover this op
        return self._auto_choice(op, nbytes, axis, callsite)

    def _axes_for(self, axis) -> Optional[Tuple]:
        if axis is None or self.topology is None:
            return None
        try:
            names = axis if isinstance(axis, (tuple, list)) else (axis,)
            return tuple(self.topology.axis(a) for a in names)
        except KeyError:
            return None

    def _model(self):
        if self.cost_model is not None:
            return self.cost_model
        from repro.comm.autotune import default_cost_model
        return default_cost_model()

    def invalidate_resolutions(self, *, table=None, hw=None,
                               health=None) -> None:
        """Drop every memoized ``(op, nbytes, axis, callsite)`` resolution
        so the next ``schedule="auto"`` lookup re-prices — the adaptive
        retune hook (:mod:`repro.comm.retune`).

        ``table`` optionally swaps a refreshed
        :class:`~repro.comm.autotune.TuningTable` into the cost model first
        (an in-run re-measurement); ``hw`` swaps the
        :class:`~repro.comm.types.HardwareModel` the analytic ranking
        prices on (a degraded-link view from
        :meth:`repro.comm.faults.FaultInjector.hardware_view`); ``health``
        swaps the link-health mask (``(axis, hop)`` pairs that are hard
        down, from :meth:`repro.comm.faults.FaultInjector.down_links` —
        pass ``frozenset()`` to declare every link healthy again), so
        resolution excludes any route crossing a down link. Mutates the
        engine's cost model — the process default when no explicit
        ``cost_model`` was given — never the frozen engine, so in-flight
        references stay valid. Already-traced jitted programs keep the
        schedule they were traced with; the swap lands on the next trace.
        """
        model = self._model()
        if table is not None:
            model.table = table
        if hw is not None:
            model.hw = hw
        if health is not None:
            model.health = frozenset(health)
        model._cache.clear()

    def _auto_choice(self, op: str, nbytes: Optional[int], axis,
                     callsite: Optional[str] = None) -> str:
        """Cost-model resolution; static default when the model has nothing
        to price (no topology / payload / unknown axis)."""
        axes = self._axes_for(axis)
        if nbytes is None or axes is None:
            return _AUTO[op]
        choice = self._model().choose(op, int(nbytes), axes,
                                      callsite=callsite)
        if choice is not None and choice in _REGISTRY[op]:
            return choice
        return _AUTO[op]

    def _resolve(self, op: str, override: Optional[str], *,
                 nbytes: Optional[int] = None, axis=None,
                 callsite: Optional[str] = None) -> Callable:
        return _REGISTRY[op][self.schedule_for(op, override, nbytes=nbytes,
                                               axis=axis, callsite=callsite)]

    def pipeline_chunks(self, op: str, *, nbytes: Optional[int] = None,
                        axis=None, schedule: Optional[str] = None,
                        callsite: Optional[str] = None) -> int:
        """The chunk count ``pipelined`` resolves ``nchunks="auto"`` to:
        :func:`repro.comm.autotune.best_nchunks` on the resolved schedule's
        hop/wire decomposition — pipeline fill cost against per-chunk
        latency. 1 (monolithic) when the model has nothing to price."""
        axes = self._axes_for(axis)
        if nbytes is None or axes is None:
            return 1
        name = self.schedule_for(op, schedule, nbytes=nbytes, axis=axis,
                                 callsite=callsite)
        model = self._model()
        if hasattr(model, "best_nchunks"):  # CostModel: carries its own hw
            return model.best_nchunks(op, name, int(nbytes), axes)[0]
        from repro.comm.autotune import best_nchunks
        return best_nchunks(op, name, int(nbytes), axes)[0]

    def _check_axis(self, axis):
        if self.topology is None:
            return
        for name in (axis if isinstance(axis, (tuple, list)) else (axis,)):
            self.topology.axis(name)  # raises KeyError with the known axes

    # -- ops (all run inside shard_map bodies) ------------------------------

    def bcast(self, val, axis, src, *, schedule: Optional[str] = None,
              callsite: Optional[str] = None):
        """Broadcast ``val`` from rank ``src`` (traced scalar ok) along
        ``axis``."""
        self._check_axis(axis)
        fn = self._resolve("bcast", schedule, nbytes=_payload_bytes(val),
                           axis=axis, callsite=callsite)
        return fn(self, val, axis, src)

    def all_to_all_tiles(self, x, axis, *, split_axis: int, concat_axis: int,
                         schedule: Optional[str] = None,
                         callsite: Optional[str] = None):
        """Exchange tiles so rank i's j-th split lands on rank j, ordered by
        source rank on ``concat_axis``.

        ``x`` is cut into ``axis``-size equal tiles along ``split_axis``;
        the output concatenates the tiles received from ranks 0..n-1 along
        ``concat_axis``, so running the exchange again with the two axes
        swapped is an exact inverse — the round-trip every paired caller
        relies on (``@moe.dispatch``/``@moe.combine`` for the expert
        exchange, ``@tp.qkv``/``@tp.out`` and ``@sp.qkv``/``@sp.out`` for
        the whole-model attention reshardings; tags and owners in
        :mod:`repro.comm.callsites`). ``schedule`` must name a registered
        ``all_to_all_tiles`` schedule (else :class:`UnknownScheduleError`);
        ``None`` defers to the engine-wide resolution, with ``auto`` priced
        on this call's payload and ``callsite``-tagged table entries taking
        precedence."""
        self._check_axis(axis)
        fn = self._resolve("all_to_all_tiles", schedule,
                           nbytes=_payload_bytes(x), axis=axis,
                           callsite=callsite)
        return fn(self, x, axis, split_axis=split_axis,
                  concat_axis=concat_axis)

    def allreduce(self, x, axis, *, schedule: Optional[str] = None,
                  callsite: Optional[str] = None):
        """Sum ``x`` over all ranks of ``axis`` (a name or tuple of names)."""
        self._check_axis(axis)
        fn = self._resolve("allreduce", schedule, nbytes=_payload_bytes(x),
                           axis=axis, callsite=callsite)
        return fn(self, x, axis)

    def bucket_bytes_for(self, axis) -> int:
        """Model-derived bucket size for :meth:`allreduce_tree` over
        ``axis``: pipeline depth x ring hops x per-hop latency-bandwidth
        product (:func:`repro.comm.autotune.derive_bucket_bytes`), replacing
        the former fixed 32 MiB constant. Falls back to that constant when
        the engine has no topology for ``axis``."""
        if self.topology is None:
            return DEFAULT_BUCKET_BYTES
        try:
            names = axis if isinstance(axis, (tuple, list)) else (axis,)
            axes = tuple(self.topology.axis(a) for a in names)
        except KeyError:
            return DEFAULT_BUCKET_BYTES
        from repro.comm.autotune import default_cost_model, derive_bucket_bytes
        model = self.cost_model
        hw = getattr(model, "hw", None) or default_cost_model().hw
        return derive_bucket_bytes(axes, hw)

    def allreduce_tree(self, tree, axis, *,
                       bucket_bytes: Optional[int] = None,
                       schedule: Optional[str] = None,
                       callsite: Optional[str] = None):
        """Sum a pytree over ``axis`` in independent ~``bucket_bytes`` buckets.

        Leaves are greedily packed in order (reverse-mode autodiff emits
        gradients in backward order, so early buckets finish first); each
        bucket's same-dtype leaves are flattened into one payload and routed
        through the registered allreduce schedule. Independent buckets give
        XLA the paper's Fig. 5/7 overlap structure: reduction of finished
        buckets runs concurrently with the compute still producing later
        leaves. Zero-size leaves pass through untouched.

        ``bucket_bytes=None`` (default) derives the size from the topology
        and hardware model via :meth:`bucket_bytes_for`. ``callsite``
        (e.g. ``"dp.grads"``) tags every bucket's allreduce so measured
        tuning-table entries for the bucketed-gradient pattern win over the
        isolated-allreduce entry.
        """
        self._check_axis(axis)
        if bucket_bytes is None:
            bucket_bytes = self.bucket_bytes_for(axis)
        leaves, treedef = jax.tree.flatten(tree)
        out = list(leaves)
        for bucket in pack_buckets(leaves, bucket_bytes):
            groups: Dict = {}
            for i in bucket:
                if leaves[i].size:
                    groups.setdefault(jnp.dtype(leaves[i].dtype), []).append(i)
            for idxs in groups.values():
                flat = jnp.concatenate([leaves[i].reshape(-1) for i in idxs])
                red = self.allreduce(flat, axis, schedule=schedule,
                                     callsite=callsite)
                off = 0
                for i in idxs:
                    n = leaves[i].size
                    out[i] = red[off:off + n].reshape(leaves[i].shape)
                    off += n
        return jax.tree.unflatten(treedef, out)

    def ring_exchange(self, x_fwd, x_bwd, axis, *,
                      schedule: Optional[str] = None,
                      callsite: Optional[str] = None):
        """Bidirectional neighbor exchange (b_eff pattern). Returns
        (recv_from_left, recv_from_right)."""
        self._check_axis(axis)
        fn = self._resolve("ring_exchange", schedule,
                           nbytes=_payload_bytes(x_fwd), axis=axis,
                           callsite=callsite)
        return fn(self, x_fwd, x_bwd, axis)

    def grid_transpose(self, x, axes, pg: int, *,
                       schedule: Optional[str] = None,
                       callsite: Optional[str] = None):
        """Exchange with the (r,c)<->(c,r) partner on a ``pg`` x ``pg``
        torus flattened over ``axes`` (PTRANS §2.2.2)."""
        self._check_axis(axes)
        fn = self._resolve("grid_transpose", schedule,
                           nbytes=_payload_bytes(x), axis=axes,
                           callsite=callsite)
        return fn(self, x, axes, pg)

    # -- pipelined transform ------------------------------------------------

    def pipelined(self, op: str, x, axis, *, nchunks="auto",
                  split_axis: int = 0, concat_axis: Optional[int] = None,
                  consume: Optional[Callable] = None,
                  schedule: Optional[str] = None,
                  callsite: Optional[str] = None, **opkw):
        """Software-pipeline any single-payload collective.

        ``x`` is split into ``nchunks`` near-equal strips along
        ``split_axis``; each strip routes through ``op``'s registered
        schedule *independently*, and ``consume(strip_out, start)`` (if
        given) is applied to each strip as it lands — the strips carry no
        data dependence on each other, so XLA overlaps strip i's consumer
        compute with strip i+1's wire hops (the chunked in-flight pipeline
        of the ACCL latency studies). Results are concatenated along
        ``concat_axis`` (default ``split_axis``; pass a different axis when
        ``consume`` reorients the strip, e.g. PTRANS's transpose-add).
        For ``all_to_all_tiles`` the strip axis indexes positions that ride
        along unchanged through the exchange (e.g. the MoE capacity slots),
        so the concatenated strips equal the monolithic exchange bitwise.

        ``nchunks="auto"`` resolves through :meth:`pipeline_chunks` (the
        alpha-beta fill-cost model); any value is clamped to the strip count
        available along ``split_axis``, so over-chunking degrades gracefully
        to one row per strip. ``nchunks=1`` is exactly the monolithic op —
        and every chunking is *bit-identical* to it for data-movement ops
        (bcast / grid_transpose / all_to_all_tiles), since chunk boundaries
        only partition the payload.

        Extra op operands ride ``opkw``: ``src=`` for bcast, ``pg=`` for
        grid_transpose, ``tile_split_axis=`` / ``tile_concat_axis=`` for
        all_to_all_tiles (the *tile* axes of the exchange, distinct from the
        pipeline's own ``split_axis``/``concat_axis`` strip axes — the strip
        axis must name a third axis, since slicing along a tile axis would
        change the tile boundaries the exchange moves).
        """
        supported = ("bcast", "allreduce", "grid_transpose",
                     "all_to_all_tiles")
        if op not in supported:
            raise ValueError(
                f"pipelined supports single-payload ops {supported}, "
                f"got {op!r}")
        required = {"bcast": ("src",), "grid_transpose": ("pg",),
                    "all_to_all_tiles": ("tile_split_axis",
                                         "tile_concat_axis")}.get(op, ())
        for name in required:
            if name not in opkw:
                raise ValueError(
                    f"pipelined({op!r}) requires the {name}= operand")
        if op == "all_to_all_tiles":
            tiles = {int(opkw["tile_split_axis"]) % x.ndim,
                     int(opkw["tile_concat_axis"]) % x.ndim}
            if int(split_axis) % x.ndim in tiles:
                raise ValueError(
                    "pipelined('all_to_all_tiles') strip split_axis "
                    f"{split_axis} collides with a tile axis {sorted(tiles)}; "
                    "strips must partition an axis the exchange leaves alone")
        self._check_axis(axis)
        size = x.shape[split_axis]
        nbytes = _payload_bytes(x)
        if nchunks == "auto":
            nchunks = self.pipeline_chunks(op, nbytes=nbytes, axis=axis,
                                           schedule=schedule,
                                           callsite=callsite)
        # resolve the schedule ONCE at the full payload: a per-strip
        # resolution could cross a cost-model / tuning-table band boundary
        # and run a different schedule than the one the chunk count was
        # priced for (and than callers record as provenance)
        resolved = self.schedule_for(op, schedule, nbytes=nbytes, axis=axis,
                                     callsite=callsite)
        s = max(min(int(nchunks), size), 1)
        base, extra = divmod(size, s)
        outs, start = [], 0
        for i in range(s):
            stop = start + base + (1 if i < extra else 0)
            strip = lax.slice_in_dim(x, start, stop, axis=split_axis)
            if op == "bcast":
                out = self.bcast(strip, axis, opkw["src"], schedule=resolved,
                                 callsite=callsite)
            elif op == "allreduce":
                out = self.allreduce(strip, axis, schedule=resolved,
                                     callsite=callsite)
            elif op == "all_to_all_tiles":
                out = self.all_to_all_tiles(
                    strip, axis, split_axis=opkw["tile_split_axis"],
                    concat_axis=opkw["tile_concat_axis"], schedule=resolved,
                    callsite=callsite)
            else:
                out = self.grid_transpose(strip, axis, opkw["pg"],
                                          schedule=resolved,
                                          callsite=callsite)
            if consume is not None:
                out = consume(out, start)
            outs.append(out)
            start = stop
        if len(outs) == 1:
            return outs[0]
        cat = split_axis if concat_axis is None else concat_axis
        return jnp.concatenate(outs, axis=cat)

    # -- provenance ---------------------------------------------------------

    def describe(self) -> Dict[str, object]:
        """Static record of what this engine runs, for benchmark results."""
        d = {
            "comm": self.comm.value,
            "schedule": self.schedule,
            # static (payload-free) resolution; callsites with a payload may
            # refine these through the cost model — benchmarks record the
            # per-callsite resolved name in their own results
            "resolved": {op: self.schedule_for(op) for op in OPS},
        }
        if self.schedule == "auto" \
                and self.comm is not CommunicationType.HOST_STAGED:
            d["auto_resolver"] = ("cost_model" if self.topology is not None
                                  else "static")
        if self.topology is not None:
            d["topology"] = self.topology.describe()
        return d
