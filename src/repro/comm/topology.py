"""Topology helpers: rings and 2-D tori over mesh axes, PQ block ownership.

These mirror the paper's network setups: the b_eff ring, the PTRANS P=Q pair
grid, and the HPL 2-D torus (paper Figs. 2, 3, 8). On TPU the physical torus
is fixed; these helpers define *logical* topologies over mesh axis names that
XLA maps onto ICI.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import numpy as np


def ring_perm(size: int, shift: int = 1) -> List[Tuple[int, int]]:
    """(source, dest) pairs for a ring ppermute by ``shift``."""
    return [(i, (i + shift) % size) for i in range(size)]


def transpose_perm(p: int) -> List[Tuple[int, int]]:
    """Pair (r, c) <-> (c, r) on a p x p grid flattened row-major —
    the PTRANS partner exchange (paper §2.2.2, P = Q required)."""
    return [(r * p + c, c * p + r) for r in range(p) for c in range(p)]


def torus_neighbors(p: int, q: int) -> dict:
    """Neighbor permutations for a p x q torus flattened row-major:
    right/left along rows, down/up along columns (paper Fig. 8 directions)."""
    def flat(r, c):
        return r * q + c
    return {
        "right": [(flat(r, c), flat(r, (c + 1) % q)) for r in range(p) for c in range(q)],
        "left": [(flat(r, c), flat(r, (c - 1) % q)) for r in range(p) for c in range(q)],
        "down": [(flat(r, c), flat((r + 1) % p, c)) for r in range(p) for c in range(q)],
        "up": [(flat(r, c), flat((r - 1) % p, c)) for r in range(p) for c in range(q)],
    }


def pq_owner(block_i: int, block_j: int, p: int, q: int) -> Tuple[int, int]:
    """Block-cyclic PQ ownership (paper Fig. 3): block (i, j) lives on grid
    coordinate (i mod P, j mod Q)."""
    return block_i % p, block_j % q


def local_block_count(nblocks: int, p: int) -> int:
    """Blocks per grid row/col under block-cyclic distribution (must divide
    evenly for the kernels here; callers validate)."""
    if nblocks % p:
        raise ValueError(f"nblocks={nblocks} not divisible by grid dim {p}")
    return nblocks // p


def grid_from_devices(n_devices: int) -> Tuple[int, int]:
    """Largest P=Q square grid using all devices (paper requires P=Q for the
    circuit-switched PTRANS/HPL)."""
    p = int(np.floor(np.sqrt(n_devices)))
    while p > 1 and n_devices % p:
        p -= 1
    return p, n_devices // p
