"""Topology helpers: rings and 2-D tori over mesh axes, PQ block ownership.

These mirror the paper's network setups: the b_eff ring, the PTRANS P=Q pair
grid, and the HPL 2-D torus (paper Figs. 2, 3, 8). On TPU the physical torus
is fixed; these helpers define *logical* topologies over mesh axis names that
XLA maps onto ICI.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# per-axis topology metadata (consumed by repro.comm.engine)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AxisTopology:
    """Static description of one mesh axis as a communication domain.

    ``kind`` is one of:
      ``ring``      — 1-D wraparound ring (b_eff, DP gradient rings)
      ``torus_row`` / ``torus_col`` — one dimension of a 2-D torus (HPL,
                      PTRANS row/column broadcasts)
      ``staging``   — a host-staged domain (the paper's PCIe+MPI network);
                      schedules over it must route every byte through the
                      staging implementation.
    """
    name: str
    size: int
    kind: str = "ring"

    @property
    def wraparound(self) -> bool:
        return self.kind != "staging"

    def perm(self, shift: int = 1) -> List[Tuple[int, int]]:
        return ring_perm(self.size, shift)

    def links(self) -> Tuple[Tuple[str, int], ...]:
        """Every physical link of this axis as ``(name, hop)`` ids — hop
        ``h`` is the bidirectional wire between ranks ``h`` and
        ``h+1 mod size``. A staging axis has no ICI links (its bytes ride
        PCIe + host MPI), so it reports none. On a size-2 ring hops 0 and
        1 are the *same* physical wire between ranks 0 and 1 (the
        "wraparound" is the forward link traversed backward), so only the
        canonical hop 0 is reported — a route or health mask naming
        either hop refers to that one wire (:meth:`canonical_hop`)."""
        if self.kind == "staging":
            return ()
        return tuple((self.name, h) for h in range(self.n_links))

    @property
    def n_links(self) -> int:
        """Distinct physical wires on this axis (0 for staging domains)."""
        if self.kind == "staging":
            return 0
        return 1 if self.size == 2 else self.size

    def canonical_hop(self, hop: int) -> int:
        """The canonical link id for ``hop`` — on a size-2 axis both hop
        names collapse onto the single wire's id 0."""
        if self.size == 2:
            return 0
        return hop


@dataclass(frozen=True)
class MeshTopology:
    """Topology metadata for every axis of a mesh, keyed by axis name.

    Built host-side (outside shard_map); the engine consults it to validate
    axis names, look up per-axis sizes, and record schedule provenance in
    benchmark results.
    """
    axes: Tuple[AxisTopology, ...]

    @classmethod
    def from_mesh(cls, mesh, kinds: Optional[Dict[str, str]] = None
                  ) -> "MeshTopology":
        """Derive topology from a jax Mesh. ``kinds`` overrides the per-axis
        classification; defaults: a lone axis is a ring, ('rows','cols') are
        the 2-D torus dimensions, 'pod' is a staging domain."""
        kinds = kinds or {}
        default = {"rows": "torus_row", "cols": "torus_col", "pod": "staging"}
        axes = []
        for name, size in mesh.shape.items():
            kind = kinds.get(name, default.get(name, "ring"))
            axes.append(AxisTopology(name=name, size=int(size), kind=kind))
        return cls(axes=tuple(axes))

    def axis(self, name: str) -> AxisTopology:
        for ax in self.axes:
            if ax.name == name:
                return ax
        raise KeyError(
            f"axis {name!r} not in topology "
            f"(have {[a.name for a in self.axes]})")

    def names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    def size(self, axis) -> int:
        """Total ranks along ``axis`` (a name or tuple of names)."""
        if isinstance(axis, (tuple, list)):
            n = 1
            for a in axis:
                n *= self.axis(a).size
            return n
        return self.axis(axis).size

    def describe(self) -> Dict[str, str]:
        return {a.name: f"{a.kind}[{a.size}]" for a in self.axes}


def ring_perm(size: int, shift: int = 1) -> List[Tuple[int, int]]:
    """(source, dest) pairs for a ring ppermute by ``shift``."""
    return [(i, (i + shift) % size) for i in range(size)]


def transpose_perm(p: int) -> List[Tuple[int, int]]:
    """Pair (r, c) <-> (c, r) on a p x p grid flattened row-major —
    the PTRANS partner exchange (paper §2.2.2, P = Q required)."""
    return [(r * p + c, c * p + r) for r in range(p) for c in range(p)]


def torus_neighbors(p: int, q: int) -> dict:
    """Neighbor permutations for a p x q torus flattened row-major:
    right/left along rows, down/up along columns (paper Fig. 8 directions)."""
    def flat(r, c):
        return r * q + c
    return {
        "right": [(flat(r, c), flat(r, (c + 1) % q)) for r in range(p) for c in range(q)],
        "left": [(flat(r, c), flat(r, (c - 1) % q)) for r in range(p) for c in range(q)],
        "down": [(flat(r, c), flat((r + 1) % p, c)) for r in range(p) for c in range(q)],
        "up": [(flat(r, c), flat((r - 1) % p, c)) for r in range(p) for c in range(q)],
    }


def pq_owner(block_i: int, block_j: int, p: int, q: int) -> Tuple[int, int]:
    """Block-cyclic PQ ownership (paper Fig. 3): block (i, j) lives on grid
    coordinate (i mod P, j mod Q)."""
    return block_i % p, block_j % q


def local_block_count(nblocks: int, p: int) -> int:
    """Blocks per grid row/col under block-cyclic distribution (must divide
    evenly for the kernels here; callers validate)."""
    if nblocks % p:
        raise ValueError(f"nblocks={nblocks} not divisible by grid dim {p}")
    return nblocks // p


def grid_from_devices(n_devices: int, *, square: bool = False
                      ) -> Tuple[int, int]:
    """Most-square P x Q factorization of ``n_devices`` (P <= Q, P*Q == n).

    The paper's circuit-switched PTRANS/HPL — and :func:`transpose_perm`,
    which is only defined on square grids — require P = Q; pass
    ``square=True`` to enforce that contract (raises :class:`ValueError`
    for non-square device counts instead of silently returning a
    rectangle, e.g. 8 -> 2 x 4). The default keeps the historical
    rectangular behavior for callers that only need a 2-D layout."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    p = int(np.floor(np.sqrt(n_devices)))
    if square:
        if p * p != n_devices:
            raise ValueError(
                f"{n_devices} devices do not form a P=Q square grid "
                f"(nearest squares: {p * p}, {(p + 1) ** 2}); the "
                "circuit-switched PTRANS/HPL path requires P = Q")
        return p, p
    while p > 1 and n_devices % p:
        p -= 1
    return p, n_devices // p
