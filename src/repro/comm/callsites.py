"""Central registry of collective callsite tags.

Every engine call that matters for tuning carries a **callsite tag** — a
short ``owner.site`` string (``"moe.dispatch"``, ``"tp.qkv"``) passed as
``callsite=`` to the :class:`~repro.comm.engine.CollectiveEngine` op. The
tag keys measured :class:`~repro.comm.autotune.TuningTable` entries
(``op@callsite``), so schedules measured *inside* a call pattern (HPL's
back-to-back broadcasts, MoE's dispatch/FFN/combine sandwich) win over the
isolated-op entry exactly where that pattern runs.

This module is the single source of truth for the tag strings and their
metadata. It is import-free on purpose (no jax, no repro siblings) so every
layer — core kernels, models, train steps — can import its constants
without cycles. The README's "Callsite tag registry" table mirrors
:data:`CALLSITES` and ``tests/test_docs.py`` cross-checks the two, so the
docs cannot drift from the code.

Adding a tag:

1. add the constant + a :class:`Callsite` entry here;
2. pass the constant as ``callsite=`` at the new engine call;
3. if the pattern deserves its own measurement, add an ``op@tag`` body to
   :func:`repro.comm.autotune._measure_op` (and a ``PAIRED_ALIASES`` entry
   when one measurement covers several tags), and set ``tuned`` here;
4. add the row to the README table — the drift test enforces the rest.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# -- tag constants (import these at callsites; never inline the strings) ----

HPL_BLOCK = "hpl.block"          # HPL diagonal-block bcast (torus row/col)
HPL_PANEL = "hpl.panel"          # HPL panel bcast, dependent on the block
PTRANS_EXCHANGE = "ptrans.exchange"  # PTRANS grid-transpose partner swap
MOE_DISPATCH = "moe.dispatch"    # MoE token->expert all-to-all
MOE_COMBINE = "moe.combine"      # MoE expert->token inverse all-to-all
DP_GRADS = "dp.grads"            # bucketed data-parallel gradient allreduce
TP_QKV = "tp.qkv"                # head-parallel attention: q/k/v head split
TP_OUT = "tp.out"                # head-parallel attention: inverse exchange
SP_QKV = "sp.qkv"                # ring attention: q/k/v sequence split
SP_KV = "sp.kv"                  # ring attention: per-step kv block rotation
SP_OUT = "sp.out"                # ring attention: inverse exchange
DECODE_QKV = "decode.qkv"        # per-token decode: q/k/v head split
DECODE_OUT = "decode.out"        # per-token decode: inverse head exchange
DECODE_MOE = "decode.moe"        # per-token decode: MoE dispatch+combine
RA_UPDATES = "ra.updates"        # GUPS: route updates to owning ranks
FFT_TRANSPOSE = "fft.transpose"  # pencil FFT: signal gather/scatter a2a


@dataclass(frozen=True)
class Callsite:
    """Metadata for one tagged engine call.

    ``op``      the engine op issued under this tag.
    ``module``  the dotted module that owns the call (imports the constant).
    ``const``   the constant's symbol name in this module.
    ``tuned``   the ``op@callsite`` autotune pattern key whose measured
                winner covers this tag (directly or via
                ``autotune.PAIRED_ALIASES``); ``None`` means lookups fall
                back to the untagged op entry.
    """
    op: str
    module: str
    const: str
    tuned: Optional[str] = None


CALLSITES: Dict[str, Callsite] = {
    HPL_BLOCK: Callsite("bcast", "repro.core.hpl", "HPL_BLOCK"),
    HPL_PANEL: Callsite("bcast", "repro.core.hpl", "HPL_PANEL",
                        tuned="bcast@hpl.panel"),
    PTRANS_EXCHANGE: Callsite("grid_transpose", "repro.core.ptrans",
                              "PTRANS_EXCHANGE"),
    MOE_DISPATCH: Callsite("all_to_all_tiles", "repro.models.moe",
                           "MOE_DISPATCH",
                           tuned="all_to_all_tiles@moe.dispatch"),
    MOE_COMBINE: Callsite("all_to_all_tiles", "repro.models.moe",
                          "MOE_COMBINE",
                          tuned="all_to_all_tiles@moe.dispatch"),
    DP_GRADS: Callsite("allreduce", "repro.train.step", "DP_GRADS"),
    TP_QKV: Callsite("all_to_all_tiles", "repro.models.parallel", "TP_QKV",
                     tuned="all_to_all_tiles@tp.qkv"),
    TP_OUT: Callsite("all_to_all_tiles", "repro.models.parallel", "TP_OUT",
                     tuned="all_to_all_tiles@tp.qkv"),
    SP_QKV: Callsite("all_to_all_tiles", "repro.models.parallel", "SP_QKV",
                     tuned="all_to_all_tiles@sp.qkv"),
    SP_KV: Callsite("ring_exchange", "repro.models.parallel", "SP_KV"),
    SP_OUT: Callsite("all_to_all_tiles", "repro.models.parallel", "SP_OUT",
                     tuned="all_to_all_tiles@sp.qkv"),
    DECODE_QKV: Callsite("all_to_all_tiles", "repro.models.parallel",
                         "DECODE_QKV",
                         tuned="all_to_all_tiles@decode.qkv"),
    DECODE_OUT: Callsite("all_to_all_tiles", "repro.models.parallel",
                         "DECODE_OUT",
                         tuned="all_to_all_tiles@decode.qkv"),
    DECODE_MOE: Callsite("all_to_all_tiles", "repro.train.serve",
                         "DECODE_MOE",
                         tuned="all_to_all_tiles@decode.qkv"),
    RA_UPDATES: Callsite("all_to_all_tiles", "repro.core.randomaccess",
                         "RA_UPDATES",
                         tuned="all_to_all_tiles@ra.updates"),
    FFT_TRANSPOSE: Callsite("all_to_all_tiles", "repro.core.fft",
                            "FFT_TRANSPOSE",
                            tuned="all_to_all_tiles@fft.transpose"),
}
