"""Deterministic fault injection: degraded/down links and lost ranks.

The paper's barrier discipline ("the slowest execution time among all
FPGAs is reported") means one degraded link paces the whole machine, and
its circuit-switched network can silently fall back to slower routing —
the failure mode a long-running job must *detect and adapt to*, not just
measure once. This module makes that failure mode injectable, three ways,
all driven by the same :class:`FaultInjector`:

* **Cost-model view** — :meth:`FaultInjector.hardware_view` returns a
  :class:`~repro.comm.types.HardwareModel` with per-hop latency inflated
  by ``alpha_scale`` and link bandwidth deflated by ``beta_scale`` for
  the worst fault touching the queried axes. Feeding it to a
  :class:`~repro.comm.autotune.CostModel` (or through
  ``CollectiveEngine.invalidate_resolutions(hw=...)``) makes the analytic
  ranking — and therefore ``schedule="auto"`` — see the slow link.
* **Measured mode** — while an injector is :func:`activate`-d,
  :func:`repro.comm.autotune._measure_op` adds
  :func:`measured_extra_time` to every microbenchmark sample: the
  degraded-minus-clean analytic cost of that exact ``(op, schedule,
  nbytes, axes)`` run, so ``autotune_mesh`` winners flip consistently
  with the perturbed model (``delay_scale`` amplifies the deltas above
  host-timing noise on the simulated CPU mesh).
* **Host-side delays** — :meth:`FaultInjector.sleep` stalls the host
  around a tagged callsite's step, which is how the train loop's
  :class:`~repro.train.straggler.StragglerMonitor` and the serve engine
  observe degradation as wall-clock drift.

Beyond degradation, the same injector models **hard** failures — the
circuit-switched network's binary mode. :meth:`FaultInjector.down_link`
marks a link unestablishable; the mask (:meth:`FaultInjector.down_links`)
reaches the cost model as ``CostModel.health`` so any route traversing a
down link prices as infinite and resolution reroutes (``chain_rooted``)
or falls back to ``staged``. :meth:`FaultInjector.fail_rank` declares a
device lost; consuming loops raise :class:`RankLostError` and recover
elastically (shrunken mesh + resharded checkpoint restore).

:class:`FaultSchedule` scripts a timeline over all of these ("degrade
link at step k, heal at step m", "down at k", "fail_rank at k"),
consumable by the train loop (``TrainLoopConfig.fault_schedule``), the
serve engine (``ServeEngine(fault_schedule=...)``),
``benchmarks/resilience_bench``, ``benchmarks/failover_bench``, and —
via :meth:`FaultSchedule.parse` — the ``--fault-schedule`` CLI flags.

Everything is seedable and deterministic: with ``jitter=0`` (default)
two runs of the same schedule inject byte-identical perturbations.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.topology import AxisTopology
from repro.comm.types import TPU_V5E, HardwareModel

FAULT_ACTIONS = ("degrade", "heal", "delay", "clear_delay", "down",
                 "fail_rank")


class RankLostError(RuntimeError):
    """A scripted rank loss fired: the mesh as built no longer exists.

    Raised by loops that consume a :class:`FaultSchedule` when
    :meth:`FaultInjector.lost_ranks` becomes non-empty. Carries enough to
    rebuild: which ranks died and at which loop step.
    """

    def __init__(self, ranks, step: int):
        self.ranks = tuple(sorted(ranks))
        self.step = int(step)
        super().__init__(
            f"rank(s) {self.ranks} lost at step {self.step}")


@dataclass(frozen=True)
class LinkFault:
    """One faulted link: hop ``hop`` of mesh axis ``axis``.

    Soft fault (``down=False``): ``alpha_scale`` multiplies the per-hop
    latency, ``beta_scale`` divides the link bandwidth. Under the barrier
    discipline every ring pass on the faulted axis is paced by the slow
    link: latency is paid per traversal (additive) while a pipelined
    transfer's steady-state throughput collapses to the slowest link's
    (bottleneck) — so the degraded view reprices the whole axis at the
    faulted numbers.

    Hard fault (``down=True``): the circuit cannot be established at all
    (the paper's circuit-switched network is binary — a circuit exists or
    it does not). A down link never contributes scales; it surfaces as a
    link-health mask (:meth:`FaultInjector.down_links`) that the cost
    model prices as infinite and schedule resolution must route around.
    """
    axis: str
    hop: int = 0
    alpha_scale: float = 1.0
    beta_scale: float = 1.0
    down: bool = False

    def __post_init__(self):
        if self.alpha_scale < 1.0 or self.beta_scale < 1.0:
            raise ValueError(
                f"fault scales must be >= 1 (a fault never speeds a link "
                f"up): alpha_scale={self.alpha_scale}, "
                f"beta_scale={self.beta_scale}")


def _axis_names(axes) -> Optional[set]:
    if axes is None:
        return None
    return {a.name if isinstance(a, AxisTopology) else str(a) for a in axes}


class FaultInjector:
    """Deterministic, seedable source of injected link degradation.

    ``hw``           the clean :class:`HardwareModel` degraded views derive
                     from (:data:`TPU_V5E` by default).
    ``delay_scale``  multiplies :meth:`extra_time` — amplifies microsecond-
                     scale link deltas into measurable host delays on the
                     simulated CPU mesh (1.0 = physical).
    ``jitter``       relative uniform noise on injected delays (0 = exactly
                     reproducible); drawn from ``seed``.
    """

    def __init__(self, *, hw: HardwareModel = TPU_V5E, seed: int = 0,
                 delay_scale: float = 1.0, jitter: float = 0.0):
        self.hw = hw
        self.delay_scale = float(delay_scale)
        self.jitter = float(jitter)
        self._rng = np.random.default_rng(seed)
        self._faults: Dict[Tuple[str, int], LinkFault] = {}
        self._host_delays: Dict[Optional[str], float] = {}
        self._lost_ranks: set = set()

    # -- fault state --------------------------------------------------------

    def degrade_link(self, axis: str, hop: int = 0, *,
                     alpha_scale: float = 1.0,
                     beta_scale: float = 1.0) -> LinkFault:
        """Install (or overwrite) the fault on ``(axis, hop)``."""
        fault = LinkFault(axis=axis, hop=hop, alpha_scale=alpha_scale,
                          beta_scale=beta_scale)
        self._faults[(axis, hop)] = fault
        return fault

    def down_link(self, axis: str, hop: int = 0) -> LinkFault:
        """Mark ``(axis, hop)`` hard-down: no circuit, route around it."""
        fault = LinkFault(axis=axis, hop=hop, down=True)
        self._faults[(axis, hop)] = fault
        return fault

    def down_links(self, axes: Optional[Sequence] = None) -> frozenset:
        """The link-health mask: ``frozenset`` of ``(axis, hop)`` pairs
        currently hard-down on the named axes (all axes when ``None``).
        Link ``hop`` is the wire between ranks ``hop`` and ``hop+1 mod n``
        on that axis, severed in both directions."""
        names = _axis_names(axes)
        return frozenset((f.axis, f.hop) for f in self._faults.values()
                         if f.down and (names is None or f.axis in names))

    def fail_rank(self, rank: int) -> None:
        """Declare device ``rank`` (mesh-linear index) lost. Loops that
        consume a schedule observe :attr:`lost_ranks` and raise
        :class:`RankLostError` to trigger elastic recovery."""
        self._lost_ranks.add(int(rank))

    def restore_ranks(self) -> None:
        """Forget lost ranks — called once recovery has rebuilt the mesh
        on the survivors, so the resumed loop does not re-fire."""
        self._lost_ranks.clear()

    @property
    def lost_ranks(self) -> frozenset:
        return frozenset(self._lost_ranks)

    def heal(self, axis: Optional[str] = None,
             hop: Optional[int] = None) -> None:
        """Remove faults: all of them, one axis's, or one (axis, hop)."""
        if axis is None:
            self._faults.clear()
            return
        self._faults = {k: f for k, f in self._faults.items()
                        if not (f.axis == axis
                                and (hop is None or f.hop == hop))}

    @property
    def active(self) -> bool:
        return (bool(self._faults) or any(self._host_delays.values())
                or bool(self._lost_ranks))

    @property
    def faults(self) -> Tuple[LinkFault, ...]:
        return tuple(self._faults.values())

    def scales(self, axes: Optional[Sequence] = None) -> Tuple[float, float]:
        """``(alpha_scale, beta_scale)`` the barrier discipline imposes on
        the named axes (axis names or :class:`AxisTopology`): the worst
        fault touching any of them; ``(1.0, 1.0)`` when clean. ``axes=None``
        means every axis."""
        names = _axis_names(axes)
        hit = [f for f in self._faults.values()
               if not f.down and (names is None or f.axis in names)]
        return (max((f.alpha_scale for f in hit), default=1.0),
                max((f.beta_scale for f in hit), default=1.0))

    # -- degraded views -----------------------------------------------------

    def hardware_view(self, hw: Optional[HardwareModel] = None,
                      axes: Optional[Sequence] = None) -> HardwareModel:
        """``hw`` with the active faults' scales applied (the object itself,
        unchanged, when no fault touches ``axes``)."""
        hw = hw or self.hw
        a, b = self.scales(axes)
        if a == 1.0 and b == 1.0:
            return hw
        return replace(hw, ici_latency=hw.ici_latency * a,
                       ici_link_bw=hw.ici_link_bw / b)

    def cost_model_view(self, hw: Optional[HardwareModel] = None):
        """A fresh analytic :class:`~repro.comm.autotune.CostModel` on the
        degraded hardware, carrying the link-health mask so down links
        price as infinite. Deliberately table-free: measured tuning entries
        predate the fault and would report the clean winners."""
        from repro.comm.autotune import CostModel
        return CostModel(hw=self.hardware_view(hw), table=None,
                         health=self.down_links())

    def extra_time(self, op: str, schedule: str, nbytes: float,
                   axes: Sequence[AxisTopology],
                   hw: Optional[HardwareModel] = None) -> float:
        """Injected wall-clock seconds for one ``(op, schedule)`` run over
        ``axes``: degraded-minus-clean analytic cost, times ``delay_scale``
        (plus seeded jitter). Zero when no fault touches the axes or the
        model has no formula for the schedule. Infinite when the run's
        route traverses a hard-down link — a circuit that cannot be
        established never completes."""
        from repro.comm.autotune import (_seg_time, canonical_health,
                                         route_links, segments)
        hw = hw or self.hw
        down = self.down_links(axes)
        if down:
            # route_links reports canonical link ids (size-2 hop aliasing),
            # so the mask must be canonicalized before intersecting
            down = canonical_health(down, axes)
            links = route_links(op, schedule, axes, health=down)
            if links is None or links & down:
                return float("inf")
        dhw = self.hardware_view(hw, axes)
        if dhw is hw:
            return 0.0
        segs = segments(op, schedule, nbytes, axes, hw)
        if segs is None:
            return 0.0
        extra = sum(_seg_time(s, dhw) - _seg_time(s, hw) for s in segs)
        extra = max(extra, 0.0) * self.delay_scale
        if self.jitter > 0.0:
            extra *= 1.0 + self.jitter * float(self._rng.uniform(-1.0, 1.0))
        return extra

    # -- host-side delays ---------------------------------------------------

    def add_host_delay(self, callsite: Optional[str],
                       seconds: float) -> None:
        """Stall :meth:`sleep` callers by ``seconds``; ``callsite=None``
        applies to every callsite."""
        self._host_delays[callsite] = float(seconds)

    def clear_host_delay(self, callsite: Optional[str] = None) -> None:
        self._host_delays.pop(callsite, None)

    def host_delay(self, callsite: Optional[str] = None) -> float:
        d = self._host_delays.get(None, 0.0)
        if callsite is not None:
            d += self._host_delays.get(callsite, 0.0)
        return d

    def sleep(self, callsite: Optional[str] = None) -> float:
        """Sleep the registered host delay for ``callsite``; returns it."""
        d = self.host_delay(callsite)
        if d > 0.0:
            time.sleep(d)
        return d


# ---------------------------------------------------------------------------
# module-level activation (the measured-mode hook)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def activate(injector: FaultInjector) -> FaultInjector:
    """Make ``injector`` the process-wide active one: measured-mode
    microbenchmarks (:func:`repro.comm.autotune._measure_op`) consult it
    through :func:`measured_extra_time`."""
    global _ACTIVE
    _ACTIVE = injector
    return injector


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_injector() -> Optional[FaultInjector]:
    return _ACTIVE


@contextmanager
def injected(injector: FaultInjector):
    """``with injected(inj): ...`` — scoped :func:`activate`."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = injector
    try:
        yield injector
    finally:
        _ACTIVE = prev


def measured_extra_time(op: str, schedule: str, nbytes: float,
                        axes: Sequence[AxisTopology],
                        hw: Optional[HardwareModel] = None) -> float:
    """The active injector's :meth:`FaultInjector.extra_time` (0 clean)."""
    if _ACTIVE is None:
        return 0.0
    return _ACTIVE.extra_time(op, schedule, nbytes, axes, hw)


# ---------------------------------------------------------------------------
# scripted fault timelines
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultEvent:
    """One scripted action at loop step ``step``.

    ``action`` is one of :data:`FAULT_ACTIONS`: ``degrade`` installs a
    :class:`LinkFault` on ``(axis, hop)``; ``down`` marks that link
    hard-down; ``heal`` removes either; ``delay`` / ``clear_delay`` manage
    a host-side stall for ``callsite``; ``fail_rank`` declares mesh-linear
    device ``rank`` lost.
    """
    step: int
    action: str
    axis: str = "x"
    hop: int = 0
    alpha_scale: float = 1.0
    beta_scale: float = 1.0
    seconds: float = 0.0
    callsite: Optional[str] = None
    rank: int = 0

    def __post_init__(self):
        if self.action not in FAULT_ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"actions are {FAULT_ACTIONS}")


class FaultSchedule:
    """A scripted timeline of :class:`FaultEvent`-s over one injector.

    The consuming loop calls :meth:`apply` once per step; events whose
    ``step`` matches fire (idempotently — installing the same fault twice
    overwrites, healing an absent one no-ops), and land in ``applied`` for
    provenance. Steps are loop-local indices, so the same schedule drives a
    train loop, a serve loop, or a benchmark unchanged.
    """

    def __init__(self, injector: FaultInjector,
                 events: Sequence[FaultEvent]):
        self.injector = injector
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self.applied: List[FaultEvent] = []

    @classmethod
    def degrade_window(cls, injector: FaultInjector, start: int, end: int, *,
                       axis: str = "x", hop: int = 0,
                       alpha_scale: float = 1.0, beta_scale: float = 1.0,
                       host_delay_s: float = 0.0,
                       callsite: Optional[str] = None) -> "FaultSchedule":
        """The canonical script: degrade at ``start``, heal at ``end``,
        optionally stalling ``callsite`` by ``host_delay_s`` meanwhile."""
        if end <= start:
            raise ValueError(f"degrade window [{start}, {end}) is empty")
        events = [FaultEvent(start, "degrade", axis=axis, hop=hop,
                             alpha_scale=alpha_scale, beta_scale=beta_scale),
                  FaultEvent(end, "heal", axis=axis, hop=hop)]
        if host_delay_s > 0.0:
            events += [FaultEvent(start, "delay", seconds=host_delay_s,
                                  callsite=callsite),
                       FaultEvent(end, "clear_delay", callsite=callsite)]
        return cls(injector, events)

    @classmethod
    def down_window(cls, injector: FaultInjector, start: int, end: int, *,
                    axis: str = "x", hop: int = 0) -> "FaultSchedule":
        """Hard variant of :meth:`degrade_window`: link down at ``start``,
        restored (cable replaced) at ``end``."""
        if end <= start:
            raise ValueError(f"down window [{start}, {end}) is empty")
        return cls(injector, [FaultEvent(start, "down", axis=axis, hop=hop),
                              FaultEvent(end, "heal", axis=axis, hop=hop)])

    @classmethod
    def rank_loss(cls, injector: FaultInjector, step: int, *,
                  rank: int) -> "FaultSchedule":
        """Lose mesh-linear device ``rank`` at ``step``."""
        return cls(injector, [FaultEvent(step, "fail_rank", rank=rank)])

    @classmethod
    def parse(cls, injector: FaultInjector, spec: str) -> "FaultSchedule":
        """Build a schedule from a CLI spec string.

        Grammar: events separated by ``;``, each
        ``action@start[-end][:key=value,...]`` —

        * ``degrade@5-20:axis=x,hop=1,beta_scale=64`` — soft window
          (``-end`` appends the matching ``heal``);
        * ``down@5-20:axis=x,hop=3`` — hard-down window;
        * ``delay@5-20:seconds=0.05,callsite=train.step`` — host stall
          window (``-end`` appends ``clear_delay``);
        * ``fail_rank@12:rank=3`` — rank loss (no window form).
        """
        events: List[FaultEvent] = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            head, _, tail = part.partition(":")
            action, at, when = head.partition("@")
            action = action.strip()
            if action not in FAULT_ACTIONS or not at:
                raise ValueError(
                    f"bad fault event {part!r}: want "
                    f"action@start[-end][:k=v,...] with action in "
                    f"{FAULT_ACTIONS}")
            start_s, _, end_s = when.partition("-")
            start = int(start_s)
            end = int(end_s) if end_s else None
            kw: Dict[str, object] = {}
            for item in filter(None, (s.strip() for s in tail.split(","))):
                k, _, v = item.partition("=")
                if k in ("hop", "rank"):
                    kw[k] = int(v)
                elif k in ("alpha_scale", "beta_scale", "seconds"):
                    kw[k] = float(v)
                elif k in ("axis", "callsite"):
                    kw[k] = v
                else:
                    raise ValueError(f"unknown fault spec key {k!r} "
                                     f"in {part!r}")
            events.append(FaultEvent(start, action, **kw))
            if end is not None:
                if action in ("degrade", "down"):
                    events.append(FaultEvent(
                        end, "heal", axis=kw.get("axis", "x"),
                        hop=kw.get("hop", 0)))
                elif action == "delay":
                    events.append(FaultEvent(
                        end, "clear_delay", callsite=kw.get("callsite")))
                else:
                    raise ValueError(
                        f"{action!r} does not take a window: {part!r}")
        return cls(injector, events)

    def apply(self, step: int) -> List[FaultEvent]:
        """Fire every event scheduled for ``step``; returns them.

        Soft events are effect-idempotent (re-applying a fired step
        overwrites the same fault, never stacks it), so they may re-fire.
        ``fail_rank`` is strictly one-shot: a loop resumed from a
        checkpoint (elastic recovery re-enters the step range) must not
        re-lose the rank it just recovered from.
        """
        fired = []
        for e in self.events:
            if e.step != step:
                continue
            if e.action == "fail_rank" and any(a is e for a in self.applied):
                continue
            if e.action == "degrade":
                self.injector.degrade_link(e.axis, e.hop,
                                           alpha_scale=e.alpha_scale,
                                           beta_scale=e.beta_scale)
            elif e.action == "down":
                self.injector.down_link(e.axis, e.hop)
            elif e.action == "heal":
                self.injector.heal(e.axis, e.hop)
            elif e.action == "delay":
                self.injector.add_host_delay(e.callsite, e.seconds)
            elif e.action == "clear_delay":
                self.injector.clear_host_delay(e.callsite)
            else:  # fail_rank
                self.injector.fail_rank(e.rank)
            fired.append(e)
            self.applied.append(e)
        return fired

    @property
    def span(self) -> Tuple[int, int]:
        """(first, last) scheduled step."""
        return (self.events[0].step, self.events[-1].step) if self.events \
            else (0, 0)
