"""Deterministic fault injection: degraded links for the resilience layer.

The paper's barrier discipline ("the slowest execution time among all
FPGAs is reported") means one degraded link paces the whole machine, and
its circuit-switched network can silently fall back to slower routing —
the failure mode a long-running job must *detect and adapt to*, not just
measure once. This module makes that failure mode injectable, three ways,
all driven by the same :class:`FaultInjector`:

* **Cost-model view** — :meth:`FaultInjector.hardware_view` returns a
  :class:`~repro.comm.types.HardwareModel` with per-hop latency inflated
  by ``alpha_scale`` and link bandwidth deflated by ``beta_scale`` for
  the worst fault touching the queried axes. Feeding it to a
  :class:`~repro.comm.autotune.CostModel` (or through
  ``CollectiveEngine.invalidate_resolutions(hw=...)``) makes the analytic
  ranking — and therefore ``schedule="auto"`` — see the slow link.
* **Measured mode** — while an injector is :func:`activate`-d,
  :func:`repro.comm.autotune._measure_op` adds
  :func:`measured_extra_time` to every microbenchmark sample: the
  degraded-minus-clean analytic cost of that exact ``(op, schedule,
  nbytes, axes)`` run, so ``autotune_mesh`` winners flip consistently
  with the perturbed model (``delay_scale`` amplifies the deltas above
  host-timing noise on the simulated CPU mesh).
* **Host-side delays** — :meth:`FaultInjector.sleep` stalls the host
  around a tagged callsite's step, which is how the train loop's
  :class:`~repro.train.straggler.StragglerMonitor` and the serve engine
  observe degradation as wall-clock drift.

:class:`FaultSchedule` scripts a timeline over the three ("degrade link
at step k, heal at step m"), consumable by the train loop
(``TrainLoopConfig.fault_schedule``), the serve engine
(``ServeEngine(fault_schedule=...)``), and ``benchmarks/resilience_bench``.

Everything is seedable and deterministic: with ``jitter=0`` (default)
two runs of the same schedule inject byte-identical perturbations.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.topology import AxisTopology
from repro.comm.types import TPU_V5E, HardwareModel

FAULT_ACTIONS = ("degrade", "heal", "delay", "clear_delay")


@dataclass(frozen=True)
class LinkFault:
    """One degraded link: hop ``hop`` of mesh axis ``axis``.

    ``alpha_scale`` multiplies the per-hop latency, ``beta_scale`` divides
    the link bandwidth. Under the barrier discipline every ring pass on the
    faulted axis is paced by the slow link: latency is paid per traversal
    (additive) while a pipelined transfer's steady-state throughput
    collapses to the slowest link's (bottleneck) — so the degraded view
    reprices the whole axis at the faulted numbers.
    """
    axis: str
    hop: int = 0
    alpha_scale: float = 1.0
    beta_scale: float = 1.0

    def __post_init__(self):
        if self.alpha_scale < 1.0 or self.beta_scale < 1.0:
            raise ValueError(
                f"fault scales must be >= 1 (a fault never speeds a link "
                f"up): alpha_scale={self.alpha_scale}, "
                f"beta_scale={self.beta_scale}")


def _axis_names(axes) -> Optional[set]:
    if axes is None:
        return None
    return {a.name if isinstance(a, AxisTopology) else str(a) for a in axes}


class FaultInjector:
    """Deterministic, seedable source of injected link degradation.

    ``hw``           the clean :class:`HardwareModel` degraded views derive
                     from (:data:`TPU_V5E` by default).
    ``delay_scale``  multiplies :meth:`extra_time` — amplifies microsecond-
                     scale link deltas into measurable host delays on the
                     simulated CPU mesh (1.0 = physical).
    ``jitter``       relative uniform noise on injected delays (0 = exactly
                     reproducible); drawn from ``seed``.
    """

    def __init__(self, *, hw: HardwareModel = TPU_V5E, seed: int = 0,
                 delay_scale: float = 1.0, jitter: float = 0.0):
        self.hw = hw
        self.delay_scale = float(delay_scale)
        self.jitter = float(jitter)
        self._rng = np.random.default_rng(seed)
        self._faults: Dict[Tuple[str, int], LinkFault] = {}
        self._host_delays: Dict[Optional[str], float] = {}

    # -- fault state --------------------------------------------------------

    def degrade_link(self, axis: str, hop: int = 0, *,
                     alpha_scale: float = 1.0,
                     beta_scale: float = 1.0) -> LinkFault:
        """Install (or overwrite) the fault on ``(axis, hop)``."""
        fault = LinkFault(axis=axis, hop=hop, alpha_scale=alpha_scale,
                          beta_scale=beta_scale)
        self._faults[(axis, hop)] = fault
        return fault

    def heal(self, axis: Optional[str] = None,
             hop: Optional[int] = None) -> None:
        """Remove faults: all of them, one axis's, or one (axis, hop)."""
        if axis is None:
            self._faults.clear()
            return
        self._faults = {k: f for k, f in self._faults.items()
                        if not (f.axis == axis
                                and (hop is None or f.hop == hop))}

    @property
    def active(self) -> bool:
        return bool(self._faults) or any(self._host_delays.values())

    @property
    def faults(self) -> Tuple[LinkFault, ...]:
        return tuple(self._faults.values())

    def scales(self, axes: Optional[Sequence] = None) -> Tuple[float, float]:
        """``(alpha_scale, beta_scale)`` the barrier discipline imposes on
        the named axes (axis names or :class:`AxisTopology`): the worst
        fault touching any of them; ``(1.0, 1.0)`` when clean. ``axes=None``
        means every axis."""
        names = _axis_names(axes)
        hit = [f for f in self._faults.values()
               if names is None or f.axis in names]
        return (max((f.alpha_scale for f in hit), default=1.0),
                max((f.beta_scale for f in hit), default=1.0))

    # -- degraded views -----------------------------------------------------

    def hardware_view(self, hw: Optional[HardwareModel] = None,
                      axes: Optional[Sequence] = None) -> HardwareModel:
        """``hw`` with the active faults' scales applied (the object itself,
        unchanged, when no fault touches ``axes``)."""
        hw = hw or self.hw
        a, b = self.scales(axes)
        if a == 1.0 and b == 1.0:
            return hw
        return replace(hw, ici_latency=hw.ici_latency * a,
                       ici_link_bw=hw.ici_link_bw / b)

    def cost_model_view(self, hw: Optional[HardwareModel] = None):
        """A fresh analytic :class:`~repro.comm.autotune.CostModel` on the
        degraded hardware. Deliberately table-free: measured tuning entries
        predate the fault and would report the clean winners."""
        from repro.comm.autotune import CostModel
        return CostModel(hw=self.hardware_view(hw), table=None)

    def extra_time(self, op: str, schedule: str, nbytes: float,
                   axes: Sequence[AxisTopology],
                   hw: Optional[HardwareModel] = None) -> float:
        """Injected wall-clock seconds for one ``(op, schedule)`` run over
        ``axes``: degraded-minus-clean analytic cost, times ``delay_scale``
        (plus seeded jitter). Zero when no fault touches the axes or the
        model has no formula for the schedule."""
        from repro.comm.autotune import _seg_time, segments
        hw = hw or self.hw
        dhw = self.hardware_view(hw, axes)
        if dhw is hw:
            return 0.0
        segs = segments(op, schedule, nbytes, axes, hw)
        if segs is None:
            return 0.0
        extra = sum(_seg_time(s, dhw) - _seg_time(s, hw) for s in segs)
        extra = max(extra, 0.0) * self.delay_scale
        if self.jitter > 0.0:
            extra *= 1.0 + self.jitter * float(self._rng.uniform(-1.0, 1.0))
        return extra

    # -- host-side delays ---------------------------------------------------

    def add_host_delay(self, callsite: Optional[str],
                       seconds: float) -> None:
        """Stall :meth:`sleep` callers by ``seconds``; ``callsite=None``
        applies to every callsite."""
        self._host_delays[callsite] = float(seconds)

    def clear_host_delay(self, callsite: Optional[str] = None) -> None:
        self._host_delays.pop(callsite, None)

    def host_delay(self, callsite: Optional[str] = None) -> float:
        d = self._host_delays.get(None, 0.0)
        if callsite is not None:
            d += self._host_delays.get(callsite, 0.0)
        return d

    def sleep(self, callsite: Optional[str] = None) -> float:
        """Sleep the registered host delay for ``callsite``; returns it."""
        d = self.host_delay(callsite)
        if d > 0.0:
            time.sleep(d)
        return d


# ---------------------------------------------------------------------------
# module-level activation (the measured-mode hook)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def activate(injector: FaultInjector) -> FaultInjector:
    """Make ``injector`` the process-wide active one: measured-mode
    microbenchmarks (:func:`repro.comm.autotune._measure_op`) consult it
    through :func:`measured_extra_time`."""
    global _ACTIVE
    _ACTIVE = injector
    return injector


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_injector() -> Optional[FaultInjector]:
    return _ACTIVE


@contextmanager
def injected(injector: FaultInjector):
    """``with injected(inj): ...`` — scoped :func:`activate`."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = injector
    try:
        yield injector
    finally:
        _ACTIVE = prev


def measured_extra_time(op: str, schedule: str, nbytes: float,
                        axes: Sequence[AxisTopology],
                        hw: Optional[HardwareModel] = None) -> float:
    """The active injector's :meth:`FaultInjector.extra_time` (0 clean)."""
    if _ACTIVE is None:
        return 0.0
    return _ACTIVE.extra_time(op, schedule, nbytes, axes, hw)


# ---------------------------------------------------------------------------
# scripted fault timelines
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultEvent:
    """One scripted action at loop step ``step``.

    ``action`` is one of :data:`FAULT_ACTIONS`: ``degrade`` installs a
    :class:`LinkFault` on ``(axis, hop)``; ``heal`` removes it; ``delay`` /
    ``clear_delay`` manage a host-side stall for ``callsite``.
    """
    step: int
    action: str
    axis: str = "x"
    hop: int = 0
    alpha_scale: float = 1.0
    beta_scale: float = 1.0
    seconds: float = 0.0
    callsite: Optional[str] = None

    def __post_init__(self):
        if self.action not in FAULT_ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"actions are {FAULT_ACTIONS}")


class FaultSchedule:
    """A scripted timeline of :class:`FaultEvent`-s over one injector.

    The consuming loop calls :meth:`apply` once per step; events whose
    ``step`` matches fire (idempotently — installing the same fault twice
    overwrites, healing an absent one no-ops), and land in ``applied`` for
    provenance. Steps are loop-local indices, so the same schedule drives a
    train loop, a serve loop, or a benchmark unchanged.
    """

    def __init__(self, injector: FaultInjector,
                 events: Sequence[FaultEvent]):
        self.injector = injector
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self.applied: List[FaultEvent] = []

    @classmethod
    def degrade_window(cls, injector: FaultInjector, start: int, end: int, *,
                       axis: str = "x", hop: int = 0,
                       alpha_scale: float = 1.0, beta_scale: float = 1.0,
                       host_delay_s: float = 0.0,
                       callsite: Optional[str] = None) -> "FaultSchedule":
        """The canonical script: degrade at ``start``, heal at ``end``,
        optionally stalling ``callsite`` by ``host_delay_s`` meanwhile."""
        if end <= start:
            raise ValueError(f"degrade window [{start}, {end}) is empty")
        events = [FaultEvent(start, "degrade", axis=axis, hop=hop,
                             alpha_scale=alpha_scale, beta_scale=beta_scale),
                  FaultEvent(end, "heal", axis=axis, hop=hop)]
        if host_delay_s > 0.0:
            events += [FaultEvent(start, "delay", seconds=host_delay_s,
                                  callsite=callsite),
                       FaultEvent(end, "clear_delay", callsite=callsite)]
        return cls(injector, events)

    def apply(self, step: int) -> List[FaultEvent]:
        """Fire every event scheduled for ``step``; returns them."""
        fired = []
        for e in self.events:
            if e.step != step:
                continue
            if e.action == "degrade":
                self.injector.degrade_link(e.axis, e.hop,
                                           alpha_scale=e.alpha_scale,
                                           beta_scale=e.beta_scale)
            elif e.action == "heal":
                self.injector.heal(e.axis, e.hop)
            elif e.action == "delay":
                self.injector.add_host_delay(e.callsite, e.seconds)
            else:  # clear_delay
                self.injector.clear_host_delay(e.callsite)
            fired.append(e)
            self.applied.append(e)
        return fired

    @property
    def span(self) -> Tuple[int, int]:
        """(first, last) scheduled step."""
        return (self.events[0].step, self.events[-1].step) if self.events \
            else (0, 0)
