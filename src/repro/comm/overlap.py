"""Overlap-friendly gradient reduction.

The paper hides HPL's communication phase behind the update phase's GEMMs
(Fig. 5/7). The LM-training analogue: gradient all-reduce overlapped with
backward compute. Under XLA the overlap happens when the reduction is split
into independent buckets whose producers finish at different times — the
scheduler then interleaves collective-permute/all-reduce ops with remaining
compute. ``bucketed_psum_tree`` provides that structure.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def bucketed_psum_tree(grads, axis: str, bucket_bytes: int = 32 * 2**20):
    """psum a gradient pytree over ``axis`` in independent buckets.

    Leaves are greedily packed into ~bucket_bytes groups; each group is
    reduced with its own psum so XLA can start reducing early buckets while
    later gradients are still being computed (reverse-mode emits leaf grads
    in backward order).
    """
    leaves, treedef = jax.tree.flatten(grads)
    buckets: List[List[int]] = [[]]
    acc = 0
    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * leaf.dtype.itemsize
        if acc + nbytes > bucket_bytes and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append(i)
        acc += nbytes
    out = list(leaves)
    for bucket in buckets:
        reduced = lax.psum(tuple(leaves[i] for i in bucket), axis)
        for j, i in enumerate(bucket):
            out[i] = reduced[j]
    return jax.tree.unflatten(treedef, out)
