"""Overlap-friendly gradient reduction.

The paper hides HPL's communication phase behind the update phase's GEMMs
(Fig. 5/7). The LM-training analogue: gradient all-reduce overlapped with
backward compute. Under XLA the overlap happens when the reduction is split
into independent buckets whose producers finish at different times — the
scheduler then interleaves collective-permute/all-reduce ops with remaining
compute.

The bucketed reduction itself is a first-class engine op,
:meth:`repro.comm.engine.CollectiveEngine.allreduce_tree`, so every
registered allreduce schedule (``native`` / ``chain`` / ``rs_ag`` /
``ring2d`` / ``int8_ef``) gets the same overlap structure, and the bucket
size is derived from the topology by default
(:func:`repro.comm.autotune.derive_bucket_bytes`). This module keeps the
pure packing helper the engine uses; :func:`bucketed_psum_tree` is a
**deprecated** shim kept one release for out-of-tree callers.
"""
from __future__ import annotations

import warnings
from typing import List

import jax

# ceiling for derived bucket sizes (repro.comm.autotune) and the fallback
# when an engine has no topology to derive from
DEFAULT_BUCKET_BYTES = 32 * 2**20


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def pack_buckets(leaves, bucket_bytes: int = DEFAULT_BUCKET_BYTES
                 ) -> List[List[int]]:
    """Greedily pack leaf indices into ~``bucket_bytes`` groups, in order.

    A leaf larger than ``bucket_bytes`` gets its own bucket; a bucket is
    closed as soon as adding the next leaf would overflow it.
    """
    buckets: List[List[int]] = [[]]
    acc = 0
    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * leaf.dtype.itemsize
        if acc + nbytes > bucket_bytes and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append(i)
        acc += nbytes
    return [b for b in buckets if b]


def bucketed_psum_tree(grads, axis: str,
                       bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Deprecated: call
    :meth:`repro.comm.engine.CollectiveEngine.allreduce_tree` instead.

    There is a single code path for bucketed reductions — the engine op —
    which also unlocks the ring schedules, the cost-model ``auto``
    resolution, and the topology-derived bucket size. This shim (the old
    hard-wired-psum entry point) forwards to it and will be removed.
    """
    warnings.warn(
        "bucketed_psum_tree is deprecated; use "
        "CollectiveEngine.allreduce_tree(tree, axis, bucket_bytes=...) — "
        "the single engine code path for bucketed reductions",
        DeprecationWarning, stacklevel=2)
    from repro.comm.engine import CollectiveEngine
    engine = CollectiveEngine(schedule="native")
    return engine.allreduce_tree(grads, axis, bucket_bytes=bucket_bytes)
