"""Configuration dataclasses for models, input shapes, and runs.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG: ModelConfig``. Shapes are global (assigned per the task): each
(arch x shape) cell is resolved through :func:`shape_for`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters.

    A single dataclass covers all six families; family-specific fields are
    ignored by families that do not use them (e.g. ``num_experts`` for dense).
    """

    name: str
    family: str  # one of FAMILIES

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    use_qk_norm: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim; 0 -> d_ff
    moe_every: int = 1  # MoE layer every k-th block (jamba: 2)
    shared_expert: bool = False  # llama4-style shared expert alongside routed
    router_jitter: float = 0.0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0  # N (state size); 0 -> no ssm layers
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (jamba) -------------------------------------------------------
    attn_every: int = 1  # attention layer every k-th block (jamba: 8); SSM otherwise

    # --- cross attention (vlm / enc-dec) --------------------------------------
    cross_attn_every: int = 0  # vlm: cross-attn block every k-th layer
    vision_dim: int = 0  # stub patch-embedding dim (vlm)
    num_patches: int = 0  # stub patch count per image (vlm)

    # --- encoder-decoder (whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    audio_ctx: int = 0  # stub frame count (whisper: 1500)

    # --- numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # -------------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived ----------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM and hybrid archs only."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string; drives the scan-block structure.

        dense/moe/vlm/audio: all layers homogeneous (vlm adds cross every k).
        hybrid: 'attn' every ``attn_every``-th layer else 'ssm'.
        """
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                # paper arch (jamba): 1 attention layer per attn_every block,
                # positioned mid-block like the published model.
                kinds.append("attn" if (i % self.attn_every) == self.attn_every // 2 else "ssm")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if not self.has_moe:
            return tuple(False for _ in range(self.num_layers))
        return tuple((i % self.moe_every) == (self.moe_every - 1) for i in range(self.num_layers))

    def cross_attn_mask(self) -> Tuple[bool, ...]:
        if not self.cross_attn_every:
            return tuple(False for _ in range(self.num_layers))
        return tuple((i % self.cross_attn_every) == (self.cross_attn_every - 1)
                     for i in range(self.num_layers))

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameter count, embedding included."""
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        n = 0
        kinds = self.layer_kinds()
        moe_mask = self.moe_layer_mask()
        cross_mask = self.cross_attn_mask()
        for i, kind in enumerate(kinds):
            if kind == "attn":
                qkv = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
                if self.qkv_bias:
                    qkv += (h + 2 * kv) * hd
                n += qkv + 2 * d  # norms
            else:  # ssm
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                # in_proj (x, z, B, C, dt), conv, out_proj, A/D/dt_bias, norm
                bc = 2 * self.ssm_ngroups * self.ssm_state
                n += d * (2 * d_in + bc + nheads) + self.ssm_conv * (d_in + bc) \
                    + d_in * d + 3 * nheads + d
            if moe_mask[i]:
                e = self.num_experts
                k = self.num_experts_per_tok if active_only else e
                n += k * 3 * d * self.moe_d_ff + d * e  # router
                if self.shared_expert:
                    n += 3 * d * self.moe_d_ff
                n += d
            elif kind == "attn" or self.family != "ssm":
                if self.d_ff:
                    n += 3 * d * self.d_ff + d
            if cross_mask[i]:
                vd = self.vision_dim or d
                n += d * (h * hd) + 2 * vd * (kv * hd) + (h * hd) * d + 2 * d
        # embedding + final norm (+ untied head counted once: tied here)
        n += self.padded_vocab() * d + d
        if self.is_encoder_decoder:
            # encoder stack: attn + mlp per layer
            enc = self.num_encoder_layers * (
                d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d + 3 * d * self.d_ff + 3 * d)
            n += enc
        return n


# ---------------------------------------------------------------------------
# Input shapes (assigned; global across archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def shape_for(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k-token decode requires "
                       "sub-quadratic attention (see DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Run configuration (training / serving / distribution knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    comm_type: str = "ici_direct"  # 'ici_direct' | 'host_staged' (paper Fig. 1)
    microbatches: int = 1
    remat: str = "full"  # 'none' | 'full' | 'dots' (activation checkpoint policy)
    grad_compression: str = "none"  # 'none' | 'int8_ef'
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    max_grad_norm: float = 1.0
    seed: int = 0
    # fault tolerance
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    # straggler mitigation
    step_deadline_factor: float = 3.0  # flag steps slower than factor x median
    # pipeline parallelism (beyond-paper, over the pod axis)
    pipeline_stages: int = 1


def reduced(cfg: ModelConfig, *, layers: int = 4, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    heads = 4
    head_dim = d_model // heads
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads else heads
    if heads % max(kv, 1):
        kv = heads
    experts = min(cfg.num_experts, 4)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv if cfg.num_kv_heads else 0,
        head_dim=head_dim,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        vocab_size=vocab,
        num_experts=experts,
        num_experts_per_tok=min(cfg.num_experts_per_tok, max(experts // 2, 1)) if experts else 0,
        moe_d_ff=d_model * 2 if cfg.num_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=32,
        attn_every=min(cfg.attn_every, max(layers // 2, 1)),
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        vision_dim=32 if cfg.vision_dim else 0,
        num_patches=8 if cfg.num_patches else 0,
        num_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        audio_ctx=16 if cfg.is_encoder_decoder else 0,
        dtype="float32",
        param_dtype="float32",
    )
    return replace(cfg, **updates)
