"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    num_experts=128,
    num_experts_per_tok=1,
    moe_d_ff=8192,
    moe_every=2,  # maverick: MoE interleaved every 2nd layer (dense FFN otherwise)
    shared_expert=True,
)
