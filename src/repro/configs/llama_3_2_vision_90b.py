"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers every 5th block.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, num_patches, vision_dim).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_dim=1280,
    num_patches=1024,
)
