"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch ids are the assignment spellings (``--arch <id>``); module names are
their pythonized forms.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported API)
    ModelConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    cell_is_applicable,
    reduced,
    shape_for,
)

_ARCH_MODULES: Dict[str, str] = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "deepseek-7b": "deepseek_7b",
    "llama3.2-3b": "llama3_2_3b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-130m": "mamba2_130m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-base": "whisper_base",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG
