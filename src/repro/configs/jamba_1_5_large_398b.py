"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2, Mamba+attention 1:7 interleave
(one attention layer per 8-layer block), MoE every 2nd layer.
[arXiv:2403.19887; hf]

Note: published Jamba uses Mamba-1 selective-scan layers; this repo's SSM
layer is the Mamba-2 SSD (chunked dual) form — same state-space family,
matmul-friendly on the MXU (DESIGN.md §2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    rope_theta=0.0,  # jamba attention layers are NoPE
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=24576,
    moe_every=2,
    attn_every=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
)
