"""whisper-base [audio] — 6L d_model=512 8H (MHA kv=8) d_ff=2048 vocab=51865.
Encoder-decoder; conv frontend STUBBED per the assignment: ``input_specs()``
provides precomputed mel-frame embeddings (batch, audio_ctx, d_model).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions
    is_encoder_decoder=True,
    num_encoder_layers=6,
    audio_ctx=1500,
)
