"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per-expert) vocab=151936, MoE 128 experts top-8, QK norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from dataclasses import replace

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    moe_every=1,
)


def tiny(ndev: int = 8, *, layers: int = 1) -> ModelConfig:
    """CI-mesh reduction of this config for the explicit-vs-GSPMD runs.

    One expert (shard) per device, head/kv counts divisible by ``ndev`` for
    the head-parallel (tp) exchange, and ``capacity_factor`` generous
    enough that routing drops nothing — drop order is the one place the
    explicit and GSPMD programs could legitimately diverge. Shared by the
    lm_step_bench whole-model section and tests/dist/test_transformer.py,
    so bench and test exercise the identical model.
    """
    cfg = reduced(CONFIG, layers=layers)
    return replace(
        cfg,
        num_heads=8,
        num_kv_heads=8,
        head_dim=cfg.d_model // 8,
        num_experts=ndev,
        num_experts_per_tok=min(cfg.num_experts_per_tok, ndev),
        capacity_factor=2.0,
    )
