"""Family dispatch: one uniform Model API over all assigned architectures.

``build_model(cfg)`` returns a :class:`Model` with ``init / apply /
init_cache / loss`` closures, so the trainer, server, dry-run, and tests
never branch on family.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.transformer import Shard, _noshard


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    apply: Callable  # (params, batch, cache=None, shard=..., remat=...) -> (logits, cache, aux)
    init_cache: Callable


def _decoder_apply(cfg):
    def apply(params, batch, *, cache=None, shard=_noshard, remat="none",
              attn_impl=None, moe_impl=None, page_table=None):
        return transformer.apply(
            params, cfg, batch["tokens"], cache=cache,
            patch_embeds=batch.get("patch_embeds"), shard=shard, remat=remat,
            attn_impl=attn_impl, moe_impl=moe_impl, page_table=page_table)
    return apply


def _encdec_apply(cfg):
    def apply(params, batch, *, cache=None, shard=_noshard, remat="none"):
        return encdec.apply(params, cfg, batch["tokens"],
                            frames=batch.get("frames"), cache=cache,
                            shard=shard, remat=remat)
    return apply


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            apply=_encdec_apply(cfg),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16:
                encdec.init_cache(cfg, batch, max_seq, dtype),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        apply=_decoder_apply(cfg),
        init_cache=lambda batch, max_seq, dtype=jnp.bfloat16:
            transformer.init_cache(cfg, batch, max_seq, dtype),
    )


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
                    z_loss: float = 1e-4) -> jnp.ndarray:
    """Shifted next-token cross entropy (+ z-loss), mean over valid positions.

    logits: (B, S, V); tokens: (B, S). Position t predicts token t+1.

    Partition-friendly: the target logit is extracted with a masked reduction
    over the vocab dim (not ``take_along_axis``), so vocab-sharded logits
    (tensor-parallel head) never get all-gathered — GSPMD turns both the
    logsumexp and the masked sum into shard-local reductions + psum.
    """
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    tgt_logit = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                        axis=-1)
    nll = lse - tgt_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation) — used by dry-run
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one (arch x shape) cell.

    train:   full-sequence tokens (+ modality inputs).
    prefill: same tokens, no labels (cache is created inside serve_step).
    decode:  one new token; the KV cache of length ``seq_len`` is part of the
             step state, not the input specs (see launch/dryrun.py).
    """
    B = global_batch
    S = 1 if kind == "decode" else seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.vision_dim), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.is_encoder_decoder and kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.audio_ctx, cfg.d_model), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    return specs
