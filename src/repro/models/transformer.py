"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are applied as a ``lax.scan`` over *super-blocks*: the layer pattern of
every assigned arch is periodic (jamba: attention every 8th layer, MoE every
2nd; maverick: MoE every 2nd; vision: cross-attn every 5th), so we stack the
parameters of each position-in-period across super-blocks and trace the body
once. This keeps the lowered HLO (and compile time on the 512-device dry-run
mesh) independent of depth.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.kvcache import (PagedCacheConfig, attn_cache_spec,
                                  paged_attn_cache_spec, ssm_cache_spec)

Shard = Callable[[jnp.ndarray, str], jnp.ndarray]
_noshard: Shard = lambda x, name: x


def period_of(cfg: ModelConfig) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = math.lcm(p, cfg.attn_every)
    if cfg.has_moe:
        p = math.lcm(p, cfg.moe_every)
    if cfg.cross_attn_every:
        p = math.lcm(p, cfg.cross_attn_every)
    if cfg.num_layers % p:
        raise ValueError(f"num_layers={cfg.num_layers} not divisible by period={p}")
    return p


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str, has_moe: bool, has_cross: bool) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict = {"ln1": L.init_rmsnorm(cfg.d_model)}
    if kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["ssm"] = SSM.init_ssm(ks[0], cfg)
    if has_cross:
        p["cross_ln"] = L.init_rmsnorm(cfg.d_model)
        p["cross_attn"] = L.init_attention(ks[1], cfg, kv_in_dim=cfg.d_model)
        p["cross_gate"] = jnp.zeros((), jnp.float32)  # llama-vision gated cross-attn
    if has_moe:
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = MOE.init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.num_layers)
    return p


def init_params(cfg: ModelConfig, key) -> Dict:
    period = period_of(cfg)
    n_super = cfg.num_layers // period
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    cross_mask = cfg.cross_attn_mask()

    k_embed, k_blocks, k_vlm = jax.random.split(key, 3)
    V = cfg.padded_vocab()
    params: Dict = {
        "embed": jax.random.normal(k_embed, (V, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "blocks": {},
    }
    pkeys = jax.random.split(k_blocks, period)
    for p_idx in range(period):
        init_fn = partial(_init_layer, cfg=cfg, kind=kinds[p_idx],
                          has_moe=moe_mask[p_idx], has_cross=cross_mask[p_idx])
        lkeys = jax.random.split(pkeys[p_idx], n_super)
        params["blocks"][f"p{p_idx}"] = jax.vmap(init_fn)(lkeys)
    if cfg.family == "vlm":
        params["vlm"] = {
            "patch_proj": jax.random.normal(
                k_vlm, (cfg.vision_dim, cfg.d_model), jnp.float32) * 0.02,
            "patch_norm": L.init_rmsnorm(cfg.d_model),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict:
    period = period_of(cfg)
    n_super = cfg.num_layers // period
    kinds = cfg.layer_kinds()

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), tree)

    cache: Dict = {"pos": jnp.zeros((), jnp.int32), "layers": {}}
    for p_idx in range(period):
        if kinds[p_idx] == "attn":
            spec = attn_cache_spec(cfg, batch, max_seq, dtype)
        else:
            spec = ssm_cache_spec(cfg, batch, dtype)
        cache["layers"][f"p{p_idx}"] = stack(spec)
    return cache


def init_paged_cache(cfg: ModelConfig, pcfg: PagedCacheConfig,
                     dtype=jnp.bfloat16) -> Dict:
    """Page pools for every layer, stacked like :func:`init_cache`'s layers.

    Returns ``{"layers": {"pN": {"k_pages","v_pages"}}}`` — no ``"pos"``
    entry: the serving decode step supplies per-slot lengths as the position
    vector each call. Attention-only architectures (SSM state is per-slot
    recurrent, not paged).
    """
    period = period_of(cfg)
    n_super = cfg.num_layers // period
    kinds = cfg.layer_kinds()
    if any(k != "attn" for k in kinds):
        raise ValueError(
            f"paged cache supports attention-only models; {cfg.name} has "
            f"layer kinds {sorted(set(kinds))}")

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), tree)

    cache: Dict = {"layers": {}}
    for p_idx in range(period):
        spec = paged_attn_cache_spec(cfg, pcfg, dtype)
        cache["layers"][f"p{p_idx}"] = stack(spec)
    return cache


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _apply_layer(lp: Dict, cfg: ModelConfig, x, *, kind: str, has_moe: bool,
                 has_cross: bool, cache, pos, cross_kv, shard: Shard,
                 aux: Optional[dict], attn_impl=None, moe_impl=None,
                 page_table=None):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        a, new_cache = L.apply_attention(lp["attn"], cfg, h, cache=cache,
                                         pos=pos, shard=shard,
                                         attn_impl=attn_impl,
                                         page_table=page_table)
    else:
        a, new_cache = SSM.apply_ssm(lp["ssm"], cfg, h, cache=cache, pos=pos)
    x = shard(x + a, "residual")

    if has_cross and cross_kv is not None:
        h = L.rmsnorm(x, lp["cross_ln"], cfg.norm_eps)
        c, _ = L.apply_attention(lp["cross_attn"], cfg, h, kv_x=cross_kv,
                                 causal=False, use_rope=False)
        x = shard(x + jnp.tanh(lp["cross_gate"]).astype(x.dtype) * c, "residual")

    if has_moe:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if moe_impl is not None:
            m = moe_impl(lp["moe"], h)
        else:
            m = MOE.apply_moe(lp["moe"], cfg, h, aux=aux, shard=shard)
        x = shard(x + m, "residual")
    elif cfg.d_ff:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = shard(x + L.apply_mlp(lp["mlp"], h), "residual")
    return x, new_cache


def apply(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S) int32
    *,
    cache: Optional[Dict] = None,
    patch_embeds: Optional[jnp.ndarray] = None,  # vlm: (B, P, vision_dim)
    shard: Shard = _noshard,
    remat: str = "none",
    collect_aux: bool = False,
    attn_impl=None,
    moe_impl=None,
    page_table: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], Optional[Dict]]:
    """Returns (logits, new_cache, aux).

    train:   cache=None                  -> logits (B, S, V)
    prefill: cache at pos 0              -> logits (B, S, V), cache filled
    decode:  cache with pos>0, S == 1    -> logits (B, 1, V), cache advanced
    paged:   cache from init_paged_cache (+ ``page_table``), S == 1 only —
             ``cache["pos"]`` is the (B,) per-slot length vector and the
             merge scatters each layer's token update into its page

    ``attn_impl`` / ``moe_impl`` are the explicit whole-model hooks: inside
    a ``shard_map`` body they replace the self-attention core and the MoE
    layer with engine-routed equivalents (:mod:`repro.models.parallel`,
    :func:`repro.models.moe.make_moe_impl`). Every other op is identical,
    so the traced math matches the GSPMD program exactly.
    """
    period = period_of(cfg)
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    cross_mask = cfg.cross_attn_mask()
    dtype = jnp.dtype(cfg.dtype)

    x = params["embed"].astype(dtype)[tokens]
    x = shard(x, "residual")

    cross_kv = None
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = jnp.einsum("bpv,vd->bpd", patch_embeds.astype(dtype),
                        params["vlm"]["patch_proj"].astype(dtype))
        cross_kv = L.rmsnorm(pe, params["vlm"]["patch_norm"], cfg.norm_eps)

    pos = None
    is_decode = False
    paged = False
    if cache is not None:
        pos = cache["pos"]
        is_decode = tokens.shape[1] == 1
        first = next(iter(cache["layers"].values()))
        paged = "k_pages" in first
        if paged and not is_decode:
            raise ValueError(
                "paged cache is decode-only (S == 1); prefill runs against "
                "a dense cache and is committed into pages via "
                "repro.models.kvcache.commit_prefill")
        if not is_decode:
            pos = None  # prefill writes from 0

    def superblock(x, xs):
        lps, lcaches = xs
        new_caches = {}
        for p_idx in range(period):
            kp = f"p{p_idx}"
            x, nc = _apply_layer(
                lps[kp], cfg, x, kind=kinds[p_idx], has_moe=moe_mask[p_idx],
                has_cross=cross_mask[p_idx],
                cache=lcaches[kp] if lcaches is not None else None,
                pos=pos, cross_kv=cross_kv, shard=shard, aux=None,
                attn_impl=attn_impl, moe_impl=moe_impl,
                page_table=page_table)
            new_caches[kp] = nc if nc is not None else ()
        return x, new_caches

    body = superblock
    if remat == "full" and not is_decode:
        body = jax.checkpoint(superblock)
    elif remat == "dots" and not is_decode:
        body = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    layer_caches = cache["layers"] if cache is not None else None
    x, new_layer_caches = jax.lax.scan(
        body, x, (params["blocks"], layer_caches))

    if is_decode:
        # Decode cache merge (§Perf iteration B2): the scan emitted only
        # token-sized k/v updates as ys; every layer writes the same ``pos``,
        # so ONE dynamic-update-slice per cache buffer commits them all.
        # HBM writes stay O(new tokens) instead of O(cache) — the scan reads
        # the (donated) stacked cache via xs slicing, which is the decode
        # read floor, and XLA needs no defensive whole-stack copies.
        merged = {}
        for kp, stacked in cache["layers"].items():
            upd = new_layer_caches[kp]
            m = dict(stacked)
            if paged:
                # scatter the token update into each slot's current page;
                # sentinel block-table entries (inactive slots) drop
                bt = page_table["block_table"]
                lengths = page_table["lengths"]
                ps = stacked["k_pages"].shape[2]
                col = jnp.clip(lengths // ps, 0, bt.shape[1] - 1)
                page_idx = jnp.take_along_axis(bt, col[:, None], axis=1)[:, 0]
                off = lengths % ps
                for name, val in upd.items():
                    pooled = name[0] + "_pages"
                    m[pooled] = stacked[pooled].at[:, page_idx, off].set(
                        val[:, :, 0], mode="drop")
            else:
                for name, val in upd.items():
                    if name in ("k_upd", "v_upd"):
                        m[name[0]] = jax.lax.dynamic_update_slice(
                            stacked[name[0]], val, (0, 0, pos, 0, 0))
                    else:
                        m[name] = val.astype(stacked[name].dtype)
            merged[kp] = m
        new_layer_caches = merged

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    x = shard(x, "residual")
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dtype))
    logits = shard(logits, "logits")

    new_cache = None
    if cache is not None:
        seq = tokens.shape[1]
        new_cache = {"pos": cache["pos"] + seq, "layers": new_layer_caches}
    aux = {} if collect_aux else None
    return logits, new_cache, aux
