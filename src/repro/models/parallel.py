"""Engine-routed attention exchanges for the explicit whole-model path.

Inside the whole-model ``shard_map`` (:func:`repro.train.step.
make_whole_model_train_step_explicit`) the residual stream stays
batch-sharded over one mesh axis, and attention — whose score matrix
couples every query to every key of the *same* batch row — needs a
resharding exchange. Two modes cover the two classic layouts, every wire
hop an explicit :class:`~repro.comm.engine.CollectiveEngine` call under a
registered :mod:`~repro.comm.callsites` tag:

* **tp** (head-parallel): q/k/v are exchanged from (B_loc, S, H, hd) to
  (B, S, H_loc, hd) — an all-to-all that splits the head dim and gathers
  the batch shards (``@tp.qkv``) — dense attention runs on the full batch
  with local heads, and the inverse exchange (``@tp.out``) restores the
  batch-sharded layout. GQA stays consistent: heads and KV heads are both
  split contiguously, so local q head ``j`` maps to local kv head ``j//G``
  exactly as in the unsharded computation. Math-identical to
  :func:`repro.models.layers.attention` (pure data movement).

* **sp** (sequence-parallel ring attention): q/k/v are exchanged to
  (B, S_loc, H, hd) (``@sp.qkv``), then the K/V block circulates the ring
  via bidirectional :meth:`~repro.comm.engine.CollectiveEngine.
  ring_exchange` hops (``@sp.kv``) — after hop j a rank holds the blocks
  of ranks r-j and r+j, so ``ceil((n-1)/2)`` hops cover all n blocks —
  while an online softmax (the same accumulator as the blockwise path in
  :func:`~repro.models.layers.attention`) folds each block in with global
  positions for the causal mask. The inverse exchange (``@sp.out``)
  restores the batch-sharded layout. Equal to the dense computation up to
  softmax reassociation (~1e-6 in f32).

Factories return ``attn_impl(q, k, v, *, causal, q_offset=0) -> o`` hooks
that :func:`repro.models.layers.apply_attention` accepts via ``attn_impl=``
— projections, biases, qk-norm, and rope all run *before* the hook (rope
positions depend only on the sequence index, so applying it pre-exchange is
exact in both modes).
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax.numpy as jnp
from jax import lax

from repro.comm.callsites import (DECODE_OUT, DECODE_QKV, SP_KV, SP_OUT,
                                  SP_QKV, TP_OUT, TP_QKV)
from repro.comm.engine import CollectiveEngine, schedules_for
from repro.configs.base import ModelConfig
from repro.models.kvcache import gather_pages
from repro.models.layers import (_gqa_out_einsum, _gqa_scores_einsum,
                                 attention, decode_attention)

ATTN_MODES = ("tp", "sp")


def _engine_for(mesh, engine: Optional[CollectiveEngine]) -> CollectiveEngine:
    return engine or CollectiveEngine.for_mesh(mesh, schedule="auto")


def make_tp_attention(cfg: ModelConfig, mesh, *, axis: str = "x",
                      engine: Optional[CollectiveEngine] = None,
                      schedule: Optional[str] = None) -> Callable:
    """Head-parallel attention hook: exchange heads out, batch in.

    Requires ``num_heads`` and ``num_kv_heads`` divisible by the axis size
    (GQA keeps separate q and kv head counts, hence three forward
    exchanges). The result is bit-equivalent to local dense attention —
    the exchanges only relocate whole heads.
    """
    n = mesh.shape[axis]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if H % n or KV % n:
        raise ValueError(
            f"num_heads={H} and num_kv_heads={KV} must be divisible by the "
            f"{axis!r} axis size {n} for the head-parallel (tp) exchange")
    engine = _engine_for(mesh, engine)

    def attn_impl(q, k, v, *, causal: bool = True, q_offset=0):
        def gather_heads(t):  # (B_loc, S, H, hd) -> (B, S, H_loc, hd)
            return engine.all_to_all_tiles(t, axis, split_axis=2,
                                           concat_axis=0, schedule=schedule,
                                           callsite=TP_QKV)
        o = attention(gather_heads(q), gather_heads(k), gather_heads(v),
                      causal=causal, q_offset=q_offset)
        return engine.all_to_all_tiles(o, axis, split_axis=0, concat_axis=2,
                                       schedule=schedule, callsite=TP_OUT)

    return attn_impl


def make_paged_decode_attention(cfg: ModelConfig, mesh, *, axis: str = "x",
                                engine: Optional[CollectiveEngine] = None,
                                schedule: Optional[str] = None) -> Callable:
    """Head-parallel paged-decode hook for the explicit serving path.

    Per-token collectives are tiny — the latency band of the alpha-beta
    model — so the exchanges carry their own ``decode.*`` tags and resolve
    independently of the training-sized ``tp.*`` entries. Layout mirrors
    :func:`make_tp_attention`: q and the token's k/v ride an all-to-all
    from (B_loc, 1, heads, hd) to (B, 1, heads_loc, hd) (``@decode.qkv``),
    the rank-local page pool (KV heads sharded over ``axis``) is gathered
    and the new token written, :func:`repro.models.layers.decode_attention`
    runs on the full batch with local heads, and the inverse exchange
    (``@decode.out``) restores the batch-sharded layout. Returns the hook
    ``(q, k_upd, v_upd, *, pages_k, pages_v, block_table, lengths) ->
    (o, k_full, v_full)`` with ``paged=True`` — the exchanged full-batch
    k/v go back to the layer scan, whose merge scatters them into the
    local pool.
    """
    n = mesh.shape[axis]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if H % n or KV % n:
        raise ValueError(
            f"num_heads={H} and num_kv_heads={KV} must be divisible by the "
            f"{axis!r} axis size {n} for the paged decode exchange")
    engine = _engine_for(mesh, engine)

    def attn_impl(q, k_upd, v_upd, *, pages_k, pages_v, block_table,
                  lengths):
        def gather_heads(t):  # (B_loc, 1, heads, hd) -> (B, 1, heads_loc, hd)
            return engine.all_to_all_tiles(t, axis, split_axis=2,
                                           concat_axis=0, schedule=schedule,
                                           callsite=DECODE_QKV)
        qh = gather_heads(q)
        kh = gather_heads(k_upd)
        vh = gather_heads(v_upd)
        gk = gather_pages(pages_k, block_table)
        gv = gather_pages(pages_v, block_table)
        b_idx = jnp.arange(qh.shape[0])
        gk = gk.at[b_idx, lengths].set(kh[:, 0], mode="drop")
        gv = gv.at[b_idx, lengths].set(vh[:, 0], mode="drop")
        o = decode_attention(qh, gk.astype(qh.dtype), gv.astype(qh.dtype),
                             lengths=lengths)
        o = engine.all_to_all_tiles(o, axis, split_axis=0, concat_axis=2,
                                    schedule=schedule, callsite=DECODE_OUT)
        return o, kh, vh

    attn_impl.paged = True
    return attn_impl


def make_sp_attention(cfg: ModelConfig, mesh, *, axis: str = "x",
                      engine: Optional[CollectiveEngine] = None,
                      schedule: Optional[str] = None) -> Callable:
    """Sequence-parallel ring-attention hook.

    Requires the sequence length divisible by the axis size (checked at
    trace time — shapes are static). ``schedule`` overrides the a2a
    exchanges; the kv rotation only honors it when the name is registered
    for ``ring_exchange`` (an a2a-only name like ``native`` falls back to
    the engine-wide resolution instead of erroring).
    """
    n = mesh.shape[axis]
    engine = _engine_for(mesh, engine)
    rx_schedule = schedule if schedule in schedules_for("ring_exchange") \
        else None

    def attn_impl(q, k, v, *, causal: bool = True, q_offset=0):
        B_loc, S, H, hd = q.shape
        if S % n:
            raise ValueError(
                f"sequence length {S} must be divisible by the {axis!r} "
                f"axis size {n} for the sequence-parallel (sp) exchange")

        def gather_seq(t):  # (B_loc, S, H, hd) -> (B, S_loc, H, hd)
            return engine.all_to_all_tiles(t, axis, split_axis=1,
                                           concat_axis=0, schedule=schedule,
                                           callsite=SP_QKV)
        qs, ks, vs = gather_seq(q), gather_seq(k), gather_seq(v)
        B, S_loc = qs.shape[0], S // n
        KV = ks.shape[2]
        G = H // KV
        r = lax.axis_index(axis)
        scale = 1.0 / math.sqrt(hd)
        qg = (qs * scale).reshape(B, S_loc, KV, G, hd)
        q_pos = q_offset + r * S_loc + jnp.arange(S_loc)

        def fold(carry, kblk, vblk, kv_start):
            # one online-softmax step over a ring block (same accumulator
            # as the blockwise path in layers.attention, global positions)
            acc, m, l = carry
            s = _gqa_scores_einsum(qg, kblk)  # (B, KV, G, S_loc, S_loc) f32
            if causal:
                kv_pos = kv_start + jnp.arange(S_loc)
                valid = kv_pos[None, :] <= q_pos[:, None]
                s = jnp.where(valid[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            p = jnp.exp(s - m_safe[..., None])
            if causal:
                p = jnp.where(valid[None, None, None], p, 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            o_blk = _gqa_out_einsum(p, vblk)
            acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + o_blk
            return acc_new, m_new, l_new

        carry = (jnp.zeros((B, S_loc, KV, G, hd), jnp.float32),
                 jnp.full((B, KV, G, S_loc), -jnp.inf, jnp.float32),
                 jnp.zeros((B, KV, G, S_loc), jnp.float32))
        carry = fold(carry, ks, vs, r * S_loc)  # local block first

        # the kv block rides both ring directions at once: after hop j the
        # fwd buffer holds rank r-j's block and the bwd buffer rank r+j's,
        # so n//2 hops visit all n blocks (at j == n-j both name the same
        # source — fold only one)
        kv = jnp.concatenate([ks, vs], axis=-1)
        fwd = bwd = kv
        for j in range(1, n // 2 + 1):
            fwd, bwd = engine.ring_exchange(fwd, bwd, axis,
                                            schedule=rx_schedule,
                                            callsite=SP_KV)
            carry = fold(carry, fwd[..., :hd], fwd[..., hd:],
                         ((r - j) % n) * S_loc)
            if j != n - j:
                carry = fold(carry, bwd[..., :hd], bwd[..., hd:],
                             ((r + j) % n) * S_loc)

        acc, m, l = carry
        l = jnp.maximum(l, 1e-20)
        o = (acc / l.transpose(0, 3, 1, 2)[..., None]) \
            .reshape(B, S_loc, H, hd).astype(qs.dtype)
        return engine.all_to_all_tiles(o, axis, split_axis=0, concat_axis=1,
                                       schedule=schedule, callsite=SP_OUT)

    return attn_impl


def make_attn_impl(mode: str, cfg: ModelConfig, mesh, *, axis: str = "x",
                   engine: Optional[CollectiveEngine] = None,
                   schedule: Optional[str] = None) -> Callable:
    """Dispatch on ``mode`` in :data:`ATTN_MODES` (``"tp"`` / ``"sp"``)."""
    if mode == "tp":
        return make_tp_attention(cfg, mesh, axis=axis, engine=engine,
                                 schedule=schedule)
    if mode == "sp":
        return make_sp_attention(cfg, mesh, axis=axis, engine=engine,
                                 schedule=schedule)
    raise ValueError(f"unknown attention mode {mode!r}; modes: {ATTN_MODES}")
