"""Decode-state containers: KV caches for attention layers, conv+SSD state
for SSM layers, plus the paged KV cache backing the serving path.

Dense specs (``attn_cache_spec`` / ``ssm_cache_spec``) are stored stacked
per scan position-group (leading n_super dim) so the layer scan can thread
them as xs/ys; they remain the prefill/training-eval format.

The paged cache replaces the per-request dense (B, max_seq, KV, hd) layout
for serving: one global pool of fixed-size pages per layer, a host-side
:class:`PageAllocator` (block table + free-list) that hands pages to
requests on admission and recycles them on completion, and pure gather /
scatter helpers the decode step uses on device. Heads shard over the mesh
axis (pages carry the KV-head dim), so the explicit tensor-parallel decode
path keeps each rank's page pool local.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.ssm import ssm_dims


def attn_cache_spec(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((batch, max_seq, kv, hd), dtype),
    }


def ssm_cache_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d_in, H, P, G, N = ssm_dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * G * N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------


class OutOfPagesError(RuntimeError):
    """The page pool cannot satisfy an allocation (pages or slots)."""


@dataclass(frozen=True)
class PagedCacheConfig:
    """Geometry of the page pool.

    ``page_size``   tokens per page.
    ``num_pages``   pool size, shared by all requests (also the block-table
                    sentinel value: an entry == ``num_pages`` means "no
                    page"; device scatters to it are dropped).
    ``max_slots``   decode batch width — concurrent requests.
    ``max_seq``     per-request token cap (prompt + generated); bounds the
                    block-table row width.
    """
    page_size: int
    num_pages: int
    max_slots: int
    max_seq: int

    def __post_init__(self):
        if min(self.page_size, self.num_pages,
               self.max_slots, self.max_seq) <= 0:
            raise ValueError(f"non-positive paged-cache geometry: {self}")

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_seq // self.page_size)


def paged_attn_cache_spec(cfg: ModelConfig, pcfg: PagedCacheConfig,
                          dtype) -> Dict:
    """One layer's page pool: k/v pages of (num_pages, page_size, KV, hd)."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (pcfg.num_pages, pcfg.page_size, kv, hd)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


class PageAllocator:
    """Host-side block table + free-list over one page pool.

    A request reserves its worst-case page count up front (``allocate`` with
    the prompt + max-new token total), so decode never runs out of pages
    mid-flight — admission control happens once, via ``can_allocate``.
    ``seq_len`` then tracks the filled prefix: ``append`` advances it one
    token per decode step, ``release`` recycles the slot and its pages.

    The numpy ``block_table`` / ``seq_lens`` views are the device inputs:
    unallocated entries hold the sentinel ``num_pages`` so device-side
    scatters into them drop and gathers clip (masked off by length).
    """

    def __init__(self, pcfg: PagedCacheConfig):
        self.cfg = pcfg
        self.block_table = np.full(
            (pcfg.max_slots, pcfg.pages_per_slot), pcfg.num_pages, np.int32)
        self.seq_lens = np.zeros((pcfg.max_slots,), np.int32)
        self._capacity = np.zeros((pcfg.max_slots,), np.int32)
        self._free_pages: List[int] = list(range(pcfg.num_pages))
        self._free_slots: List[int] = list(range(pcfg.max_slots))

    def _pages_for(self, total_tokens: int) -> int:
        return -(-total_tokens // self.cfg.page_size)

    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def can_allocate(self, total_tokens: int) -> bool:
        return (bool(self._free_slots)
                and 0 < total_tokens <= self.cfg.max_seq
                and self._pages_for(total_tokens) <= len(self._free_pages))

    def allocate(self, total_tokens: int) -> int:
        """Reserve a slot + pages for up to ``total_tokens``; returns slot."""
        if total_tokens <= 0 or total_tokens > self.cfg.max_seq:
            raise ValueError(
                f"request of {total_tokens} tokens exceeds max_seq="
                f"{self.cfg.max_seq}")
        npages = self._pages_for(total_tokens)
        if not self._free_slots or npages > len(self._free_pages):
            raise OutOfPagesError(
                f"cannot reserve {npages} pages + 1 slot "
                f"(free: {len(self._free_pages)} pages, "
                f"{len(self._free_slots)} slots)")
        slot = self._free_slots.pop(0)
        for i in range(npages):
            self.block_table[slot, i] = self._free_pages.pop(0)
        self.seq_lens[slot] = 0
        self._capacity[slot] = npages * self.cfg.page_size
        return slot

    def commit(self, slot: int, length: int) -> None:
        """Record ``length`` prefilled tokens for ``slot``."""
        if length > self._capacity[slot]:
            raise ValueError(
                f"slot {slot}: prefill of {length} exceeds reserved "
                f"capacity {int(self._capacity[slot])}")
        self.seq_lens[slot] = length

    def append(self, slot: int, n: int = 1) -> None:
        """Advance ``slot`` by ``n`` decoded tokens."""
        if self.seq_lens[slot] + n > self._capacity[slot]:
            raise OutOfPagesError(
                f"slot {slot}: append past reserved capacity "
                f"{int(self._capacity[slot])}")
        self.seq_lens[slot] += n

    def release(self, slot: int) -> None:
        """Recycle the slot and its pages (block-table row -> sentinel)."""
        row = self.block_table[slot]
        self._free_pages.extend(int(p) for p in row if p < self.cfg.num_pages)
        row[:] = self.cfg.num_pages
        self.seq_lens[slot] = 0
        self._capacity[slot] = 0
        self._free_slots.append(slot)

    def device_tables(self):
        """(block_table, seq_lens) as device arrays for the decode step."""
        return jnp.asarray(self.block_table), jnp.asarray(self.seq_lens)


def gather_pages(pages: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """Gather a pool's pages into per-slot contiguous KV.

    ``pages``: (num_pages, page_size, KV, hd); ``block_table``: (B, pmax)
    int32 (sentinel entries out of range clip to the last page — callers
    mask by length). Returns (B, pmax * page_size, KV, hd).
    """
    B, pmax = block_table.shape
    ps = pages.shape[1]
    g = jnp.take(pages, block_table, axis=0, mode="clip")
    return g.reshape(B, pmax * ps, *pages.shape[2:])


def commit_prefill(pages_layers: Dict, dense_layers: Dict,
                   block_row: jnp.ndarray, length, *,
                   page_size: int) -> Dict:
    """Scatter one request's dense prefill cache into its reserved pages.

    ``pages_layers``: {"pN": {"k_pages": (n_super, P, ps, KV, hd), ...}};
    ``dense_layers``: {"pN": {"k": (n_super, 1, S, KV, hd), ...}} (batch-1
    prefill, possibly padded past ``length`` — pad positions scatter to the
    sentinel and drop). ``block_row``: (pmax,) int32. Pure; jit with the
    page buffers donated.
    """
    out: Dict = {}
    for name, stacked in pages_layers.items():
        dense = dense_layers[name]
        S = dense["k"].shape[2]
        pos = jnp.arange(S)
        row = jnp.take(block_row, pos // page_size, mode="clip")
        num_pages = stacked["k_pages"].shape[1]
        page_idx = jnp.where(pos < length, row, num_pages)
        off = pos % page_size
        m = dict(stacked)
        for pooled, flat in (("k_pages", "k"), ("v_pages", "v")):
            val = dense[flat][:, 0].astype(stacked[pooled].dtype)
            m[pooled] = stacked[pooled].at[:, page_idx, off].set(
                val, mode="drop")
        out[name] = m
    return out
