"""Decode-state containers: KV caches for attention layers, conv+SSD state
for SSM layers. Stored stacked per scan position-group (leading n_super dim)
so the layer scan can thread them as xs/ys."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.ssm import ssm_dims


def attn_cache_spec(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((batch, max_seq, kv, hd), dtype),
    }


def ssm_cache_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d_in, H, P, G, N = ssm_dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * G * N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }
