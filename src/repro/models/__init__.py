from repro.models.model import Model, build_model, input_specs, next_token_loss  # noqa: F401
