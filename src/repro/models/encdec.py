"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, audio_ctx, d_model) from ``input_specs()``.
Positions are sinusoidal (whisper uses sinusoidal encoder positions; the
decoder's learned table is replaced by sinusoids here — deviation recorded in
DESIGN.md §9).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.kvcache import attn_cache_spec
from repro.models.transformer import Shard, _noshard


def _init_enc_layer(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg, layers_for_scale=cfg.num_encoder_layers),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.num_encoder_layers),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "self_attn": L.init_attention(k1, cfg),
        "cross_ln": L.init_rmsnorm(cfg.d_model),
        "cross_attn": L.init_attention(k2, cfg, kv_in_dim=cfg.d_model),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.num_layers),
    }


def init_params(cfg: ModelConfig, key) -> Dict:
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    V = cfg.padded_vocab()
    enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": jax.random.normal(k_embed, (V, cfg.d_model), jnp.float32) * 0.02,
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict:
    nl = cfg.num_layers

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (nl,) + a.shape), tree)

    return {
        "pos": jnp.zeros((), jnp.int32),
        "layers": stack(attn_cache_spec(cfg, batch, max_seq, dtype)),
        "encoder_out": jnp.zeros((batch, cfg.audio_ctx, cfg.d_model), dtype),
    }


def encode(params: Dict, cfg: ModelConfig, frames: jnp.ndarray,
           shard: Shard = _noshard) -> jnp.ndarray:
    """frames: (B, T, d_model) stub embeddings -> (B, T, d_model)."""
    dtype = jnp.dtype(cfg.dtype)
    T = frames.shape[1]
    x = frames.astype(dtype) + L.sinusoidal_positions(
        jnp.arange(T), cfg.d_model)[None].astype(dtype)
    x = shard(x, "residual")

    def block(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, _ = L.apply_attention(lp["attn"], cfg, h, causal=False, use_rope=False)
        x = shard(x + a, "residual")
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = shard(x + L.apply_mlp(lp["mlp"], h), "residual")
        return x, None

    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
           encoder_out: jnp.ndarray, *, cache: Optional[Dict] = None,
           shard: Shard = _noshard, remat: str = "none") -> Tuple:
    """Returns (logits, new_layer_caches)."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    pos = None
    if cache is not None and S == 1:
        pos = cache["pos"]
    positions = (pos if pos is not None else 0) + jnp.arange(S)
    x = params["embed"].astype(dtype)[tokens]
    x = x + L.sinusoidal_positions(positions, cfg.d_model)[None].astype(dtype)
    x = shard(x, "residual")

    layer_caches = cache["layers"] if cache is not None else None

    def block(x, xs):
        lp, lc = xs
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, nc = L.apply_attention(lp["self_attn"], cfg, h, cache=lc, pos=pos,
                                  use_rope=False)
        if nc is not None and "k_upd" in nc:
            # decode: re-materialize the full layer cache (whisper's decoder
            # cache is small; the big-cache token-slice path lives in
            # transformer.apply)
            nc = {"k": jax.lax.dynamic_update_slice(
                      lc["k"], nc["k_upd"], (0, pos, 0, 0)),
                  "v": jax.lax.dynamic_update_slice(
                      lc["v"], nc["v_upd"], (0, pos, 0, 0))}
        x = shard(x + a, "residual")
        h = L.rmsnorm(x, lp["cross_ln"], cfg.norm_eps)
        c, _ = L.apply_attention(lp["cross_attn"], cfg, h, kv_x=encoder_out,
                                 causal=False, use_rope=False)
        x = shard(x + c, "residual")
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = shard(x + L.apply_mlp(lp["mlp"], h), "residual")
        return x, nc if nc is not None else ()

    body = jax.checkpoint(block) if remat == "full" else block
    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], layer_caches))

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dtype))
    return shard(logits, "logits"), new_caches


def apply(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
          frames: Optional[jnp.ndarray] = None, cache: Optional[Dict] = None,
          shard: Shard = _noshard, remat: str = "none"):
    """Enc-dec forward. train/prefill: frames given, encoder runs; decode:
    encoder output comes from the cache."""
    if cache is None:
        enc = encode(params, cfg, frames, shard=shard)
        logits, _ = decode(params, cfg, tokens, enc, shard=shard, remat=remat)
        return logits, None, None
    if tokens.shape[1] > 1:  # prefill
        enc = encode(params, cfg, frames, shard=shard)
        logits, new_layers = decode(params, cfg, tokens, enc,
                                    cache=cache, shard=shard)
        new_cache = {"pos": cache["pos"] + tokens.shape[1], "layers": new_layers,
                     "encoder_out": enc.astype(cache["encoder_out"].dtype)}
        return logits, new_cache, None
    enc = cache["encoder_out"].astype(jnp.dtype(cfg.dtype))
    logits, new_layers = decode(params, cfg, tokens, enc, cache=cache, shard=shard)
    new_cache = {"pos": cache["pos"] + 1, "layers": new_layers,
                 "encoder_out": cache["encoder_out"]}
    return logits, new_cache, None
