"""Core neural-net layers: norms, rotary embeddings, attention, MLP.

Pure-functional: ``init_*`` build param pytrees, ``apply``-style functions
consume them. Attention is implemented blockwise (online softmax over KV
blocks) so activation memory stays O(S * block) instead of O(S^2); the Pallas
flash kernel in ``repro.kernels.attention`` is the TPU-optimized counterpart
and is validated against this implementation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> jnp.ndarray:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope_table(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """(sin, cos) tables for given integer positions; shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); sin/cos: (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, half) -> broadcast over batch and heads
        sin_b = sin[None, :, None, :]
        cos_b = cos[None, :, None, :]
    else:  # (B, S, half)
        sin_b = sin[:, :, None, :]
        cos_b = cos[:, :, None, :]
    dtype = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = x1f * cos_b - x2f * sin_b
    out2 = x2f * cos_b + x1f * sin_b
    return jnp.concatenate([out1, out2], axis=-1).astype(dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Transformer sinusoidal embedding for integer positions -> (..., d_model)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (blockwise online softmax; GQA; causal or full)
# ---------------------------------------------------------------------------


def _gqa_scores_einsum(q, k):
    # q: (B, Sq, KV, G, hd), k: (B, Skv, KV, hd) -> (B, KV, G, Sq, Skv)
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)


def _gqa_out_einsum(p, v):
    # p: (B, KV, G, Sq, Skv), v: (B, Skv, KV, hd) -> (B, Sq, KV, G, hd)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    causal: bool,
    q_offset=0,  # scalar or traced scalar: absolute position of q[0]
    kv_block: int = 1024,
    dense_threshold: int = 2048,
) -> jnp.ndarray:
    """Memory-efficient multi-head attention with GQA head grouping.

    For short KV (<= dense_threshold) or single-query decode the dense path is
    used (one einsum pair); otherwise KV is processed in blocks with an online
    softmax carried through ``lax.scan`` and per-block rematerialization.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, KV, G, hd)

    q_pos = q_offset + jnp.arange(Sq)

    if Sq == 1 or Skv <= dense_threshold:
        s = _gqa_scores_einsum(qg, k)  # (B, KV, G, Sq, Skv) fp32
        if causal:
            kv_pos = jnp.arange(Skv)
            mask = kv_pos[None, :] <= q_pos[:, None]  # (Sq, Skv)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = _gqa_out_einsum(p, v)
        return o.reshape(B, Sq, H, hd).astype(q.dtype)

    # ---- blockwise path -----------------------------------------------------
    nblk = -(-Skv // kv_block)
    pad = nblk * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    def block(carry, xs):
        acc, m, l = carry
        kblk, vblk, bstart = xs  # (B, kv_block, KV, hd), scalar

        s = _gqa_scores_einsum(qg, kblk)  # (B, KV, G, Sq, kv_block) fp32
        kv_pos = bstart + jnp.arange(kv_block)
        valid = kv_pos[None, :] < Skv  # mask zero padding
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        else:
            valid = jnp.broadcast_to(valid, (Sq, kv_block))
        s = jnp.where(valid[None, None, None], s, -jnp.inf)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard all-masked rows (m_new == -inf): scale factors become 0/exp(-inf)=0
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_blk = _gqa_out_einsum(p, vblk)  # (B, Sq, KV, G, hd) fp32
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + o_blk
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    starts = jnp.arange(nblk) * kv_block
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(block), (acc0, m0, l0), (kb, vb, starts))
    l = jnp.maximum(l, 1e-20)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k: jnp.ndarray,  # (B, Smax, KV, hd) gathered pages, new token written
    v: jnp.ndarray,  # (B, Smax, KV, hd)
    *,
    lengths: jnp.ndarray,  # (B,) int32: kv position of the newest token
) -> jnp.ndarray:
    """Single-token attention over gathered pages with per-row valid lengths.

    Positions ``> lengths[b]`` are masked out (``lengths[b]`` itself is the
    just-written token, so it participates). The mask fill is finite (no
    ``-inf``) so fully-masked rows — inactive serving slots — produce
    garbage instead of NaN; the server discards those rows.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, KV, G, hd)
    s = _gqa_scores_einsum(qg, k)  # (B, KV, G, Sq, Smax) f32
    kv_pos = jnp.arange(k.shape[1])
    mask = kv_pos[None, :] <= lengths[:, None]  # (B, Smax)
    s = jnp.where(mask[:, None, None, None, :], s, jnp.finfo(s.dtype).min)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out_einsum(p, v)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash-attention path: Pallas kernel under shard_map (prefill/forward-only)
# ---------------------------------------------------------------------------


def _flash_sharded(q, k, v, *, shard, causal: bool):
    """Run the Pallas flash kernel per device via shard_map: heads over the
    tensor-parallel axis, batch over dp; KV heads follow when they divide.
    The kernel keeps the score tile in VMEM, which removes the O(S^2) score
    materialization that dominates every prefill cell's HBM term (§Perf
    iteration A2). Returns None when this sharding is not applicable."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.kernels import ops as kops

    mesh = getattr(shard, "mesh", None)
    rules = getattr(shard, "rules", None)
    if mesh is None or rules is None:
        return None
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    dp, tp = rules.dp_spec, rules.tp
    dp_n = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_n *= mesh.shape[a]
    tp_n = mesh.shape[tp] if tp else 1
    if B % dp_n or (tp_n > 1 and H % tp_n) or Sq < 128:
        return None
    kv_ax = tp if (tp_n > 1 and KV % tp_n == 0) else None

    qspec = P(dp, None, tp if tp_n > 1 else None, None)
    kvspec = P(dp, None, kv_ax, None)
    H_loc = H // tp_n
    G_glob = H // KV

    def body(q_, k_, v_):
        if kv_ax is None and tp_n > 1:
            # KV heads replicated per shard: select the contiguous block of
            # kv heads this shard's q heads map to (GQA groups consecutive
            # q heads), so the kernel's local h//G mapping stays correct.
            s = jax.lax.axis_index(tp)
            n_kv = max(H_loc // G_glob, 1)
            start = (s * H_loc) // G_glob
            k_ = jax.lax.dynamic_slice_in_dim(k_, start, n_kv, axis=2)
            v_ = jax.lax.dynamic_slice_in_dim(v_, start, n_kv, axis=2)
        return kops.flash_attention(q_, k_, v_, causal=causal,
                                    bq=min(512, q_.shape[1]),
                                    bk=min(512, k_.shape[1]))

    fn = shard_map(body, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                   out_specs=qspec, check_vma=False)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# Attention block (params + apply): self-attention with optional cache
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, *, kv_in_dim: Optional[int] = None,
                   layers_for_scale: Optional[int] = None) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_in = kv_in_dim or d
    nl = layers_for_scale or cfg.num_layers
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), jnp.float32) * std,
        "wk": jax.random.normal(k2, (kv_in, kv, hd), jnp.float32) * std,
        "wv": jax.random.normal(k3, (kv_in, kv, hd), jnp.float32) * std,
        "wo": jax.random.normal(k4, (h, hd, d), jnp.float32) * (std / math.sqrt(2 * nl)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.use_qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def apply_attention(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, Sq, d_model)
    *,
    kv_x: Optional[jnp.ndarray] = None,  # cross-attn source (B, Skv, kv_in)
    cache: Optional[dict] = None,  # {'k','v'} (B, Smax, KV, hd) + pos,
    # or a paged pool {'k_pages','v_pages'} (num_pages, ps, KV, hd)
    pos=None,  # decode position scalar, or (B,) lengths for the paged path
    causal: bool = True,
    use_rope: bool = True,
    shard=None,  # activation-constraint callback (enables the flash path)
    attn_impl=None,  # explicit-path hook: (q, k, v, *, causal, q_offset) -> o
    page_table=None,  # paged decode: {'block_table': (B, pmax), 'lengths': (B,)}
):
    """Returns (out, new_cache). ``cache`` is updated at ``pos`` in decode.

    ``attn_impl`` (if given) replaces the core attention call — projections,
    biases, qk-norm, and rope still run here, then the hook receives the
    post-rope q/k/v. The explicit whole-model path passes the engine-routed
    exchanges from :mod:`repro.models.parallel`; the flash path is bypassed
    so the hook owns the entire score/softmax computation.

    When ``cache`` is a paged pool (``k_pages``/``v_pages``), ``page_table``
    maps serving slots to pages and the new-cache return carries only the
    token-sized ``k_upd``/``v_upd`` — the layer scan scatters them into the
    pool. A hook with a truthy ``paged`` attribute takes over the whole
    exchange + gather + attention (:func:`repro.models.parallel.
    make_paged_decode_attention`).
    """
    dtype = x.dtype
    src = kv_x if kv_x is not None else x

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if cfg.use_qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    q_offset = 0
    if use_rope and cfg.rope_theta > 0 and kv_x is None:
        if pos is None:
            positions = jnp.arange(x.shape[1])
        elif getattr(pos, "ndim", 0) == 1:  # paged decode: per-row lengths
            positions = pos[:, None] + jnp.arange(x.shape[1])
            q_offset = pos
        else:
            positions = pos + jnp.arange(x.shape[1])
            q_offset = pos
        sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    elif pos is not None:
        q_offset = pos

    if cache is not None and "k_pages" in cache:
        # paged decode: S == 1; the token update is NOT written here — the
        # layer scan scatters k_upd/v_upd into the page pool (one scatter
        # per buffer, same O(new tokens) HBM story as the dense merge)
        if kv_x is not None:
            raise ValueError("cross-attention KV is not cached here")
        if page_table is None:
            raise ValueError("paged cache requires page_table=")
        kp, vp = cache["k_pages"], cache["v_pages"]
        k_upd, v_upd = k.astype(kp.dtype), v.astype(vp.dtype)
        bt, lengths = page_table["block_table"], page_table["lengths"]
        if attn_impl is not None and getattr(attn_impl, "paged", False):
            o, k_upd, v_upd = attn_impl(q, k_upd, v_upd, pages_k=kp,
                                        pages_v=vp, block_table=bt,
                                        lengths=lengths)
        else:
            from repro.models.kvcache import gather_pages  # lazy: no cycle
            gk = gather_pages(kp, bt)
            gv = gather_pages(vp, bt)
            b_idx = jnp.arange(q.shape[0])
            gk = gk.at[b_idx, lengths].set(k_upd[:, 0], mode="drop")
            gv = gv.at[b_idx, lengths].set(v_upd[:, 0], mode="drop")
            o = decode_attention(q, gk.astype(dtype), gv.astype(dtype),
                                 lengths=lengths)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))
        return out, {"k_upd": k_upd, "v_upd": v_upd}

    new_cache = None
    if cache is not None:
        if kv_x is not None:
            raise ValueError("cross-attention KV is not cached here")
        ck, cv = cache["k"], cache["v"]
        if pos is None:  # prefill: write the whole prefix
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
        else:  # decode: write one (or few) positions
            k_upd, v_upd = k.astype(ck.dtype), v.astype(cv.dtype)
            ck = jax.lax.dynamic_update_slice(ck, k_upd, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v_upd, (0, pos, 0, 0))
            k, v = ck.astype(dtype), cv.astype(dtype)
            # return only the written token slice — the layer scan writes it
            # into the stacked cache with a token-sized dynamic-update-slice
            # instead of re-writing the whole layer cache (measured as the
            # dominant decode HBM term, §Perf iteration B2)
            new_cache = {"k_upd": k_upd, "v_upd": v_upd}

    o = None
    if attn_impl is not None:
        o = attn_impl(q, k, v, causal=causal and kv_x is None,
                      q_offset=q_offset)
    elif (shard is not None and kv_x is None and causal and cache is not None
            and pos is None):
        # prefill: forward-only — VMEM-tiled Pallas flash kernel per shard
        o = _flash_sharded(q, k, v, shard=shard, causal=True)
    if o is None:
        o = attention(q, k, v, causal=causal and kv_x is None,
                      q_offset=q_offset)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, num_layers: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), jnp.float32) * std,
        "w_in": jax.random.normal(k2, (d, d_ff), jnp.float32) * std,
        "w_out": jax.random.normal(k3, (d_ff, d), jnp.float32) * (std / math.sqrt(2 * num_layers)),
    }


def apply_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dtype = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dtype))
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["w_out"].astype(dtype))
