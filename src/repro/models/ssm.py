"""Mamba-2 SSD (state-space duality) block — chunked matmul form + decode.

The chunked dual form (Dao & Gu, arXiv:2405.21060 §6) computes the selective
state-space recurrence as block-diagonal "attention-like" matmuls within
chunks plus a low-rank inter-chunk state recurrence — this is the MXU-friendly
TPU adaptation (systolic matmuls instead of a sequential scan over L).

Decode is the O(1)-memory recurrent step: h' = exp(dt*A) h + dt * (B ⊗ x),
y = C·h' + D*x — which is why the SSM/hybrid archs are the only ones that run
the 500k-token long-context decode cell (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm, init_rmsnorm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    """(d_inner, nheads, head_dim P, ngroups G, state N)."""
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    return d_in, H, P, cfg.ssm_ngroups, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig) -> dict:
    """Projections are stored *segmented* (x, z, BC, dt) rather than as
    mamba's packed in_proj so each segment can be tensor-sharded cleanly:
    d_inner and heads shard over 'model'; the (small, grouped) B/C and the
    conv over them stay replicated (repro/sharding.py)."""
    d = cfg.d_model
    d_in, H, P, G, N = ssm_dims(cfg)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    std = 0.02
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(k4, (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_x": jax.random.normal(k1, (d, d_in), jnp.float32) * std,
        "in_z": jax.random.normal(k5, (d, d_in), jnp.float32) * std,
        "in_bc": jax.random.normal(k6, (d, 2 * G * N), jnp.float32) * std,
        "in_dt": jax.random.normal(k7, (d, H), jnp.float32) * std,
        "conv_x": jax.random.normal(k2, (cfg.ssm_conv, d_in), jnp.float32) * std,
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_bc": jax.random.normal(k2, (cfg.ssm_conv, 2 * G * N), jnp.float32) * std,
        "conv_bc_b": jnp.zeros((2 * G * N,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": init_rmsnorm(d_in),
        "out_proj": jax.random.normal(k3, (d_in, d), jnp.float32)
        * (std / math.sqrt(2 * cfg.num_layers)),
    }


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD.

    x: (b, L, H, P)  dt: (b, L, H)  A: (H,) (negative)
    B, C: (b, L, G, N);  heads h use group h // (H//G).
    Returns (y (b,L,H,P), h_final (b,H,P,N)).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = chunk
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, G, N)
    Cc = C.reshape(b, nc, Q, G, N)

    dA = dtc * A  # (b,nc,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (block-diagonal "attention") --------------------------
    # scores_g[b,c,g,q,k] = C_q . B_k  (per group)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc,
                        preferred_element_type=jnp.float32)
    # decay L[b,c,h,q,k] = exp(cs_q - cs_k) for q >= k
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (b,nc,Q,Q,H) q,k
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # fold group->head: M[b,c,h,q,k]
    scores_h = jnp.repeat(scores, rep, axis=2) if rep > 1 else scores
    # scores_h: (b,nc,G*rep=H,q,k); decay: (b,nc,q,k,H) -> align
    M = scores_h * jnp.moveaxis(decay, -1, 2) * jnp.moveaxis(
        dtc, -1, 2)[:, :, :, None, :]  # dt_k
    y = jnp.einsum("bchqk,bckhp->bcqhp", M, xc.astype(jnp.float32),
                   preferred_element_type=jnp.float32)

    # ---- chunk states -------------------------------------------------------
    # S_c[b,h,p,n] = sum_k exp(cs_last - cs_k) dt_k x_k B_k
    seg = jnp.exp(cs[:, :, -1:, :] - cs) * dtc  # (b,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3) if rep > 1 else Bc  # (b,nc,Q,H,N)
    states = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn",
                        seg, xc.astype(jnp.float32), Bh.astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (b,nc,H): exp(sum dA over chunk)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    def step(h, inp):
        s_c, g_c = inp  # (b,H,P,N), (b,H)
        prev = h
        h = g_c[:, :, None, None] * h + s_c
        return h, prev

    h_final, prev_states = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,H,P,N)

    # ---- inter-chunk contribution --------------------------------------------
    Ch = jnp.repeat(Cc, rep, axis=3) if rep > 1 else Cc  # (b,nc,Q,H,N)
    q_decay = jnp.exp(cs)  # (b,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Ch.astype(jnp.float32), prev_states,
                       preferred_element_type=jnp.float32)
    y = y + y_off * q_decay[..., None]

    y = y.reshape(b, Lp, H, P)[:, :L]
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, A, B, C, h0=None):
    """Oracle: sequential recurrence over L (slow; tests only)."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2) if rep > 1 else B
    Ch = jnp.repeat(C, rep, axis=2) if rep > 1 else C
    h = jnp.zeros((b, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(L):
        dt_t = dt[:, t].astype(jnp.float32)  # (b,H)
        g = jnp.exp(dt_t * A)  # (b,H)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x[:, t].astype(jnp.float32),
                         Bh[:, t].astype(jnp.float32))
        h = g[:, :, None, None] * h + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t].astype(jnp.float32), h)
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), h


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _causal_conv(xBC, w, b, conv_cache=None):
    """Depthwise causal conv. xBC: (B, L, ch); w: (K, ch)."""
    K = w.shape[0]
    if conv_cache is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_cache.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, L+K-1, ch)
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + xBC.shape[1]].astype(jnp.float32) * w[k].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_cache = xp[:, xp.shape[1] - (K - 1):]
    return out.astype(xBC.dtype), new_cache


def apply_ssm(p: dict, cfg: ModelConfig, x: jnp.ndarray, *,
              cache: Optional[dict] = None, pos=None):
    """Mamba-2 block. x: (B, S, D) -> (B, S, D); returns (y, new_cache).

    cache = {'conv': (B, K-1, ch), 'state': (B, H, P, N)}; decode when
    ``pos is not None`` and S == 1 (recurrent step).
    """
    Bsz, S, D = x.shape
    d_in, H, P, G, N = ssm_dims(cfg)
    dtype = x.dtype

    z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(dtype))
    xin = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(dtype))
    bc = jnp.einsum("bsd,de->bse", x, p["in_bc"].astype(dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(dtype))

    decode = pos is not None and S == 1
    xin, new_conv_x = _causal_conv(
        xin, p["conv_x"], p["conv_x_b"],
        conv_cache=cache.get("conv_x") if (cache and decode) else None)
    bc, new_conv_bc = _causal_conv(
        bc, p["conv_bc"], p["conv_bc_b"],
        conv_cache=cache.get("conv_bc") if (cache and decode) else None)
    xin = jax.nn.silu(xin)
    bc = jax.nn.silu(bc)
    Bm, Cm = jnp.split(bc, [G * N], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    xh = xin.reshape(Bsz, S, H, P)
    Bh = Bm.reshape(Bsz, S, G, N)
    Ch = Cm.reshape(Bsz, S, G, N)

    if decode:
        h = cache["state"].astype(jnp.float32)  # (B,H,P,N)
        dt1 = dt[:, 0]  # (B,H)
        g = jnp.exp(dt1 * A)
        rep = H // G
        B1 = jnp.repeat(Bh[:, 0], rep, axis=1) if rep > 1 else Bh[:, 0]  # (B,H,N)
        C1 = jnp.repeat(Ch[:, 0], rep, axis=1) if rep > 1 else Ch[:, 0]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh[:, 0].astype(jnp.float32),
                         B1.astype(jnp.float32))
        h = g[:, :, None, None] * h + upd
        y = jnp.einsum("bhn,bhpn->bhp", C1.astype(jnp.float32), h)[:, None]  # (B,1,H,P)
        new_state = h
    else:
        h0 = cache["state"] if cache else None
        y, new_state = ssd_chunked(xh, dt, A, Bh, Ch, cfg.ssm_chunk, h0=h0)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, d_in).astype(dtype)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))

    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": new_conv_x.astype(cache["conv_x"].dtype),
                     "conv_bc": new_conv_bc.astype(cache["conv_bc"].dtype),
                     "state": new_state.astype(cache["state"].dtype)}
    return out, new_cache
