"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Dispatch is *scatter-based* (GShard-style capacity, MegaBlocks-style index
routing): tokens are routed into an (experts, capacity, d_model) buffer with
positions computed by a cumulative count — NO dense one-hot dispatch einsum.
This keeps compiled HLO FLOPs proportional to *active* compute (top-k), which
matters for the MODEL_FLOPS/HLO_FLOPs roofline ratio (EXPERIMENTS.md).

Sharding intent under pjit (see repro/sharding.py):
  tokens  (B, S, D)   : B -> ('pod','data')
  experts (E, D, F)   : E -> 'model'  (expert parallelism)
  dispatch buffer (B, E, C, D): B -> data, E -> model  (GSPMD inserts the
  expert all-to-all-equivalent resharding; the explicit schedule is
  :func:`exchange_dispatch` / :func:`exchange_combine` below, which route the
  buffer through ``CollectiveEngine.all_to_all_tiles`` inside ``shard_map``
  with a named schedule — ``native``, paper-style ``chain``, or ``staged``).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.engine import CollectiveEngine
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# explicit expert-parallel dispatch (shard_map path)
# ---------------------------------------------------------------------------


def exchange_dispatch(buf: jnp.ndarray, axis: str,
                      engine: CollectiveEngine) -> jnp.ndarray:
    """Route a locally-built dispatch buffer to its expert owners.

    Inside ``shard_map`` over ``axis`` each rank holds tokens for *all*
    experts, ``buf`` = (B_loc, E, C, D). The exchange splits the expert dim
    across ranks and concatenates the batch shards, returning
    (B, E_loc, C, D): rank e now holds every rank's tokens for its experts —
    the MoE all-to-all, under whichever schedule the engine selects.
    """
    return engine.all_to_all_tiles(buf, axis, split_axis=1, concat_axis=0)


def exchange_combine(buf: jnp.ndarray, axis: str,
                     engine: CollectiveEngine) -> jnp.ndarray:
    """Inverse of :func:`exchange_dispatch`: return expert outputs
    (B, E_loc, C, D) to the token-owning ranks as (B_loc, E, C, D)."""
    return engine.all_to_all_tiles(buf, axis, split_axis=0, concat_axis=1)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = 0.02
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * std,
        "w_gate": jax.random.normal(k2, (e, d, f), jnp.float32) * std,
        "w_in": jax.random.normal(k3, (e, d, f), jnp.float32) * std,
        "w_out": jax.random.normal(k4, (e, f, d), jnp.float32)
        * (std / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.shared_expert:
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
            "w_in": jax.random.normal(ks[1], (d, f), jnp.float32) * std,
            "w_out": jax.random.normal(ks[2], (f, d), jnp.float32)
            * (std / math.sqrt(2 * cfg.num_layers)),
        }
    return p


def _capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(math.ceil(seq * cfg.num_experts_per_tok * cfg.capacity_factor
                      / cfg.num_experts))
    return max(c, 1)


def route(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Router: returns (probs (B,S,k), ids (B,S,k)); probs renormalized over top-k."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    top_logits, ids = jax.lax.top_k(logits, cfg.num_experts_per_tok)
    probs = jax.nn.softmax(top_logits, axis=-1)
    return probs, ids


def apply_moe(p: dict, cfg: ModelConfig, x: jnp.ndarray,
              aux: Optional[dict] = None, shard=None) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). Per-batch-row dispatch groups.

    ``shard`` (optional activation-constraint callback) pins the dispatch
    buffer to P(dp, tp, None, None) — expert-parallel over the model axis —
    and the gathered-back tokens to P(dp, None, None). Without the
    constraints GSPMD lowers the scatter/gather through full-tensor fp32
    all-reduces (measured 16 GB wire per MoE layer on qwen3-moe prefill,
    §Perf iteration A1).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(cfg, S)
    dtype = x.dtype
    shard = shard or (lambda v, _name: v)

    probs, ids = route(p, cfg, x)  # (B,S,K)

    # --- position within expert via cumulative count (no dense one-hot matmul)
    # onehot counts: (B, S, K, E) int8 is avoided; compute cumsum over flat (S*K)
    flat_ids = ids.reshape(B, S * K)  # (B, T)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (B, T, E) -- adds only
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot  # exclusive cumsum
    pos = jnp.take_along_axis(
        pos_in_expert, flat_ids[..., None], axis=-1)[..., 0]  # (B, T)
    keep = pos < C  # capacity drop mask

    # --- scatter tokens into (B, E, C, D)
    tok = jnp.repeat(x, K, axis=1).reshape(B, S * K, D)  # each token K times
    # clamp dropped slots to a scratch position (C) then slice off
    e_idx = flat_ids
    c_idx = jnp.where(keep, pos, C)
    tok = shard(tok, "moe_tokens")  # keep D sharded entering the all-to-all

    # vmap the scatters over the batch row: a 3-dim advanced-index scatter
    # hides batch-locality from GSPMD (it all-gathers the dp dim, measured
    # §Perf iteration A1c); per-row scatters keep batch a clean mapped dim.
    def _dispatch_row(tok_row, e_row, c_row):
        return jnp.zeros((E, C + 1, D), dtype).at[e_row, c_row].set(
            tok_row, mode="drop")

    buf = jax.vmap(_dispatch_row)(tok.astype(dtype), e_idx, c_idx)
    buf = shard(buf[:, :, :C], "moe_buf")  # (B, E, C, D), E over 'model'

    # --- expert FFN (SwiGLU), experts sharded over 'model'
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dtype))
    h = jnp.einsum("becd,edf->becf", buf, p["w_in"].astype(dtype))
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, p["w_out"].astype(dtype))
    y = shard(y, "moe_buf")

    # --- combine: weight in expert layout, then SCATTER-ADD back to tokens.
    # A fancy-index gather from the E-sharded buffer lowers to an all-reduce
    # of the (B, S*K, D) output — K x more wire than needed. Scatter-add sums
    # the K expert contributions shard-locally before the cross-device
    # reduction, so the payload is (B, S, D/tp) once (§Perf iteration A1).
    w = probs.reshape(B, S * K) * keep  # (B, T) f32
    s_idx = jnp.arange(S * K) // K      # slot -> destination token

    def _weights_row(w_row, e_row, c_row):
        return jnp.zeros((E, C + 1), jnp.float32).at[e_row, c_row].set(
            w_row, mode="drop")

    def _tokens_row(e_row, c_row):
        return jnp.full((E, C + 1), S, jnp.int32).at[e_row, c_row].set(
            s_idx, mode="drop")

    def _combine_row(yw_row, tok_row):
        return jnp.zeros((S, D), jnp.float32).at[tok_row].add(
            yw_row, mode="drop")

    w_buf = jax.vmap(_weights_row)(w, e_idx, c_idx)
    y_w = y.astype(jnp.float32) * w_buf[:, :, :C, None]  # (B, E, C, D) f32
    tok_buf = jax.vmap(_tokens_row)(e_idx, c_idx)
    out = jax.vmap(_combine_row)(y_w.reshape(B, E * C, D),
                                 tok_buf[:, :, :C].reshape(B, E * C))
    out = shard(out, "moe_tokens").astype(dtype)

    if cfg.shared_expert:
        sp = p["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(dtype))
        sh = jnp.einsum("bsd,df->bsf", x, sp["w_in"].astype(dtype))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * sh,
                               sp["w_out"].astype(dtype))
    if aux is not None:
        # load-balance metrics (Switch aux loss terms), fp32
        onehot_f = onehot.astype(jnp.float32)
        frac_tokens = onehot_f.mean(axis=(0, 1))  # (E,)
        aux["moe_frac_tokens"] = frac_tokens
        aux["moe_dropped"] = 1.0 - keep.astype(jnp.float32).mean()
    return out


def reference_moe(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: dense loop over experts, no capacity drop. For tests with
    capacity_factor large enough that apply_moe drops nothing."""
    B, S, D = x.shape
    probs, ids = route(p, cfg, x)
    out = jnp.zeros((B, S, D), jnp.float32)
    for e in range(cfg.num_experts):
        w_e = ((ids == e).astype(jnp.float32) * probs).sum(axis=-1)  # (B,S)
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][e].astype(x.dtype))
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"][e].astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["w_out"][e].astype(x.dtype))
        out = out + y.astype(jnp.float32) * w_e[..., None]
    out = out.astype(x.dtype)
    if cfg.shared_expert:
        sp = p["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(x.dtype))
        sh = jnp.einsum("bsd,df->bsf", x, sp["w_in"].astype(x.dtype))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * sh,
                               sp["w_out"].astype(x.dtype))
    return out
