"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Dispatch is *scatter-based* (GShard-style capacity, MegaBlocks-style index
routing): tokens are routed into an (experts, capacity, d_model) buffer with
positions computed by a cumulative count — NO dense one-hot dispatch einsum.
This keeps compiled HLO FLOPs proportional to *active* compute (top-k), which
matters for the MODEL_FLOPS/HLO_FLOPs roofline ratio (EXPERIMENTS.md).

Two execution paths share the routing/scatter internals:

* :func:`apply_moe` — the GSPMD path: one un-mapped program; sharding intent
  under pjit (see repro/sharding.py):
    tokens  (B, S, D)   : B -> ('pod','data')
    experts (E, D, F)   : E -> 'model'  (expert parallelism)
    dispatch buffer (B, E, C, D): B -> data, E -> model  (GSPMD inserts the
    expert all-to-all-equivalent resharding).
* :func:`apply_moe_explicit` / :func:`make_apply_moe_explicit` — the
  engine-routed path: the whole layer runs inside ``shard_map`` over one
  mesh axis with experts sharded across ranks, and the dispatch/combine
  exchanges are *explicit* ``CollectiveEngine.all_to_all_tiles`` calls under
  the ``moe.dispatch`` / ``moe.combine`` callsite tags (``native``,
  paper-style ``chain``, ``staged``, or ``"auto"`` through the cost model),
  optionally software-pipelined into capacity-axis strips via
  ``engine.pipelined`` so the combine weighting of strip i overlaps strip
  i+1's wire hops.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm.callsites import MOE_COMBINE, MOE_DISPATCH
from repro.comm.engine import CollectiveEngine
from repro.compat import shard_map
from repro.configs.base import ModelConfig

# tuning-table callsite tags for the two expert exchanges (from the central
# repro.comm.callsites registry): they are issued back-to-back around the
# expert FFN, so measured winners may differ from an isolated all-to-all's
# (the paired pattern autotune_mesh measures)
DISPATCH_CALLSITE = MOE_DISPATCH
COMBINE_CALLSITE = MOE_COMBINE


# ---------------------------------------------------------------------------
# explicit expert-parallel dispatch (shard_map path)
# ---------------------------------------------------------------------------


def _monolithic(nchunks) -> bool:
    return isinstance(nchunks, int) and nchunks <= 1


def exchange_dispatch(buf: jnp.ndarray, axis: str, engine: CollectiveEngine,
                      *, schedule: Optional[str] = None, nchunks=1,
                      consume=None, callsite: str = DISPATCH_CALLSITE
                      ) -> jnp.ndarray:
    """Route a locally-built dispatch buffer to its expert owners.

    Inside ``shard_map`` over ``axis`` each rank holds tokens for *all*
    experts, ``buf`` = (B_loc, E, C, D). The exchange splits the expert dim
    across ranks and concatenates the batch shards, returning
    (B, E_loc, C, D): rank e now holds every rank's tokens for its experts —
    the MoE all-to-all, under whichever schedule the engine selects for the
    ``moe.dispatch`` callsite. ``nchunks`` > 1 (or ``"auto"``) pipelines the
    exchange into capacity-axis strips through ``engine.pipelined``;
    ``consume(strip, start)`` runs per landed strip.
    """
    if consume is None and _monolithic(nchunks):
        return engine.all_to_all_tiles(buf, axis, split_axis=1,
                                       concat_axis=0, schedule=schedule,
                                       callsite=callsite)
    return engine.pipelined("all_to_all_tiles", buf, axis, nchunks=nchunks,
                            split_axis=2, tile_split_axis=1,
                            tile_concat_axis=0, consume=consume,
                            schedule=schedule, callsite=callsite)


def exchange_combine(buf: jnp.ndarray, axis: str, engine: CollectiveEngine,
                     *, schedule: Optional[str] = None, nchunks=1,
                     consume=None, callsite: str = COMBINE_CALLSITE
                     ) -> jnp.ndarray:
    """Inverse of :func:`exchange_dispatch`: return expert outputs
    (B, E_loc, C, D) to the token-owning ranks as (B_loc, E, C, D), tagged
    ``moe.combine``. Same pipelining knobs as dispatch — the combine
    weighting is the natural ``consume`` hook."""
    if consume is None and _monolithic(nchunks):
        return engine.all_to_all_tiles(buf, axis, split_axis=0,
                                       concat_axis=1, schedule=schedule,
                                       callsite=callsite)
    return engine.pipelined("all_to_all_tiles", buf, axis, nchunks=nchunks,
                            split_axis=2, tile_split_axis=0,
                            tile_concat_axis=1, consume=consume,
                            schedule=schedule, callsite=callsite)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = 0.02
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * std,
        "w_gate": jax.random.normal(k2, (e, d, f), jnp.float32) * std,
        "w_in": jax.random.normal(k3, (e, d, f), jnp.float32) * std,
        "w_out": jax.random.normal(k4, (e, f, d), jnp.float32)
        * (std / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.shared_expert:
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
            "w_in": jax.random.normal(ks[1], (d, f), jnp.float32) * std,
            "w_out": jax.random.normal(ks[2], (f, d), jnp.float32)
            * (std / math.sqrt(2 * cfg.num_layers)),
        }
    return p


def _capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(math.ceil(seq * cfg.num_experts_per_tok * cfg.capacity_factor
                      / cfg.num_experts))
    return max(c, 1)


def route(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Router: returns (probs (B,S,k), ids (B,S,k)); probs renormalized over top-k."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    top_logits, ids = jax.lax.top_k(logits, cfg.num_experts_per_tok)
    probs = jax.nn.softmax(top_logits, axis=-1)
    return probs, ids


# ---------------------------------------------------------------------------
# shared routing/scatter internals (GSPMD + explicit paths)
# ---------------------------------------------------------------------------


def _dispatch_indices(ids: jnp.ndarray, E: int, C: int):
    """Capacity bookkeeping: per-row exclusive cumulative counts give each
    (token, expert) slot its position within the expert's capacity buffer.

    Returns ``(e_idx, c_idx, keep, onehot)`` with e_idx/c_idx (B, S*K) flat
    scatter indices (dropped slots clamped to the scratch position C) and
    onehot (B, S*K, E) int32 for the load-balance metrics.
    """
    B, S, K = ids.shape
    flat_ids = ids.reshape(B, S * K)  # (B, T)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # adds only
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot  # exclusive cumsum
    pos = jnp.take_along_axis(
        pos_in_expert, flat_ids[..., None], axis=-1)[..., 0]  # (B, T)
    keep = pos < C  # capacity drop mask
    c_idx = jnp.where(keep, pos, C)
    return flat_ids, c_idx, keep, onehot


def _scatter_dispatch(tok: jnp.ndarray, e_idx, c_idx, E: int, C: int):
    """Scatter (B, S*K, D) token copies into the (B, E, C, D) dispatch
    buffer. vmapped over the batch row: a 3-dim advanced-index scatter hides
    batch-locality from GSPMD (it all-gathers the dp dim, measured §Perf
    iteration A1c); per-row scatters keep batch a clean mapped dim."""
    D = tok.shape[-1]

    def _dispatch_row(tok_row, e_row, c_row):
        # clamp dropped slots to a scratch position (C) then slice off
        return jnp.zeros((E, C + 1, D), tok.dtype).at[e_row, c_row].set(
            tok_row, mode="drop")

    return jax.vmap(_dispatch_row)(tok, e_idx, c_idx)[:, :, :C]


def _expert_ffn(p: dict, buf: jnp.ndarray, dtype) -> jnp.ndarray:
    """SwiGLU expert FFN on an expert-layout buffer (B, E[_loc], C, D)."""
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dtype))
    h = jnp.einsum("becd,edf->becf", buf, p["w_in"].astype(dtype))
    return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h,
                      p["w_out"].astype(dtype))


def _combine_weights(probs, keep, e_idx, c_idx, E: int, C: int):
    """Top-k router probs scattered into expert layout: (B, E, C) f32."""
    B = e_idx.shape[0]
    w = probs.reshape(B, -1) * keep  # (B, T) f32

    def _weights_row(w_row, e_row, c_row):
        return jnp.zeros((E, C + 1), jnp.float32).at[e_row, c_row].set(
            w_row, mode="drop")

    return jax.vmap(_weights_row)(w, e_idx, c_idx)[:, :, :C]


def _combine_scatter(y_w, e_idx, c_idx, S: int, K: int, E: int, C: int):
    """SCATTER-ADD weighted expert outputs (B, E, C, D) f32 back to tokens.

    A fancy-index gather from the E-sharded buffer lowers to an all-reduce
    of the (B, S*K, D) output — K x more wire than needed. Scatter-add sums
    the K expert contributions shard-locally before the cross-device
    reduction, so the payload is (B, S, D/tp) once (§Perf iteration A1)."""
    D = y_w.shape[-1]
    s_idx = jnp.arange(S * K) // K  # slot -> destination token

    def _tokens_row(e_row, c_row):
        return jnp.full((E, C + 1), S, jnp.int32).at[e_row, c_row].set(
            s_idx, mode="drop")

    def _combine_row(yw_row, tok_row):
        return jnp.zeros((S, D), jnp.float32).at[tok_row].add(
            yw_row, mode="drop")

    tok_buf = jax.vmap(_tokens_row)(e_idx, c_idx)
    return jax.vmap(_combine_row)(y_w.reshape(-1, E * C, D),
                                  tok_buf[:, :, :C].reshape(-1, E * C))


def _shared_expert(sp: dict, x: jnp.ndarray, dtype) -> jnp.ndarray:
    sg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(dtype))
    sh = jnp.einsum("bsd,df->bsf", x, sp["w_in"].astype(dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * sh,
                      sp["w_out"].astype(dtype))


# ---------------------------------------------------------------------------
# GSPMD path
# ---------------------------------------------------------------------------


def apply_moe(p: dict, cfg: ModelConfig, x: jnp.ndarray,
              aux: Optional[dict] = None, shard=None) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). Per-batch-row dispatch groups.

    ``shard`` (optional activation-constraint callback) pins the dispatch
    buffer to P(dp, tp, None, None) — expert-parallel over the model axis —
    and the gathered-back tokens to P(dp, None, None). Without the
    constraints GSPMD lowers the scatter/gather through full-tensor fp32
    all-reduces (measured 16 GB wire per MoE layer on qwen3-moe prefill,
    §Perf iteration A1).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(cfg, S)
    dtype = x.dtype
    shard = shard or (lambda v, _name: v)

    probs, ids = route(p, cfg, x)  # (B,S,K)
    e_idx, c_idx, keep, onehot = _dispatch_indices(ids, E, C)

    tok = jnp.repeat(x, K, axis=1).reshape(B, S * K, D)  # each token K times
    tok = shard(tok, "moe_tokens")  # keep D sharded entering the all-to-all
    buf = shard(_scatter_dispatch(tok.astype(dtype), e_idx, c_idx, E, C),
                "moe_buf")  # (B, E, C, D), E over 'model'

    # --- expert FFN (SwiGLU), experts sharded over 'model'
    y = shard(_expert_ffn(p, buf, dtype), "moe_buf")

    # --- combine: weight in expert layout, then scatter-add back to tokens
    w_buf = _combine_weights(probs, keep, e_idx, c_idx, E, C)
    y_w = y.astype(jnp.float32) * w_buf[..., None]  # (B, E, C, D) f32
    out = _combine_scatter(y_w, e_idx, c_idx, S, K, E, C)
    out = shard(out, "moe_tokens").astype(dtype)

    if cfg.shared_expert:
        out = out + _shared_expert(p["shared"], x, dtype)
    if aux is not None:
        # load-balance metrics (Switch aux loss terms), fp32
        onehot_f = onehot.astype(jnp.float32)
        frac_tokens = onehot_f.mean(axis=(0, 1))  # (E,)
        aux["moe_frac_tokens"] = frac_tokens
        aux["moe_dropped"] = 1.0 - keep.astype(jnp.float32).mean()
    return out


# ---------------------------------------------------------------------------
# explicit engine-routed path (shard_map over one mesh axis)
# ---------------------------------------------------------------------------


def moe_param_specs(p: dict, axis: str, *, scanned: bool = False) -> dict:
    """PartitionSpecs for an :func:`init_moe` pytree under the explicit
    path: experts sharded over ``axis``, router/shared replicated.
    ``scanned`` shifts the expert specs one dim right for block params that
    carry a leading layer-scan (super-block) dim, (n_super, E, ...)."""
    e_spec = P(None, axis) if scanned else P(axis)
    specs = {"router": P(),
             "w_gate": e_spec, "w_in": e_spec, "w_out": e_spec}
    if "shared" in p:
        specs["shared"] = {k: P() for k in p["shared"]}
    return specs


def _explicit_body(p: dict, cfg: ModelConfig, x: jnp.ndarray, *, axis: str,
                   engine: CollectiveEngine, schedule: Optional[str] = None,
                   nchunks=1, dispatch_callsite: str = DISPATCH_CALLSITE,
                   combine_callsite: str = COMBINE_CALLSITE) -> jnp.ndarray:
    """The per-rank MoE layer (runs inside an enclosing ``shard_map``).

    ``x`` is the local batch shard (B_loc, S, D); ``p`` holds the local
    expert shard (E_loc experts) with the router/shared weights replicated.
    Routing uses global expert ids, so the dispatch/combine exchanges and
    the capacity bookkeeping match :func:`apply_moe` exactly.
    """
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    B_loc, S, D = x.shape
    C = _capacity(cfg, S)
    dtype = x.dtype
    probs, ids = route(p, cfg, x)  # router replicated: global expert ids
    e_idx, c_idx, keep, _ = _dispatch_indices(ids, E, C)
    tok = jnp.repeat(x, K, axis=1).reshape(B_loc, S * K, D)
    buf = _scatter_dispatch(tok.astype(dtype), e_idx, c_idx, E, C)
    buf = exchange_dispatch(buf, axis, engine, schedule=schedule,
                            nchunks=nchunks,
                            callsite=dispatch_callsite)  # (B, E_loc, C, D)
    y = _expert_ffn(p, buf, dtype)  # local experts only
    w_buf = _combine_weights(probs, keep, e_idx, c_idx, E, C)

    def weigh(strip, start):
        # the per-strip combine compute: weight the landed capacity
        # strip while the next strip is still on the wire
        wsl = lax.dynamic_slice_in_dim(w_buf, start, strip.shape[2], 2)
        return strip.astype(jnp.float32) * wsl[..., None]

    y_w = exchange_combine(y, axis, engine, schedule=schedule,
                           nchunks=nchunks, consume=weigh,
                           callsite=combine_callsite)
    out = _combine_scatter(y_w, e_idx, c_idx, S, K, E, C).astype(dtype)
    if cfg.shared_expert:
        out = out + _shared_expert(p["shared"], x, dtype)
    return out


def make_moe_impl(cfg: ModelConfig, mesh, *, axis: str = "x",
                  engine: Optional[CollectiveEngine] = None,
                  schedule: Optional[str] = None, nchunks=1,
                  dispatch_callsite: str = DISPATCH_CALLSITE,
                  combine_callsite: str = COMBINE_CALLSITE):
    """``moe_impl(p, x)`` hook for the explicit whole-model path.

    Unlike :func:`make_apply_moe_explicit` (which wraps one layer in its own
    ``shard_map``), the returned hook is the bare per-rank body — the
    transformer passes it via ``moe_impl=`` so the whole forward+backward
    stays inside a single enclosing ``shard_map``. Expert shards ride the
    param tree (specs from :func:`moe_param_specs` with ``scanned=True``).
    """
    n = mesh.shape[axis]
    if cfg.num_experts % n:
        raise ValueError(
            f"num_experts={cfg.num_experts} must be divisible by the "
            f"{axis!r} axis size {n} for the explicit expert-parallel "
            f"exchange")
    engine = engine or CollectiveEngine.for_mesh(mesh, schedule="auto")

    def moe_impl(p, x):
        return _explicit_body(p, cfg, x, axis=axis, engine=engine,
                              schedule=schedule, nchunks=nchunks,
                              dispatch_callsite=dispatch_callsite,
                              combine_callsite=combine_callsite)

    return moe_impl


def make_apply_moe_explicit(cfg: ModelConfig, mesh, *, axis: str = "x",
                            engine: Optional[CollectiveEngine] = None,
                            schedule: Optional[str] = None, nchunks=1):
    """jit'd ``(params, x) -> (B, S, D)`` expert-parallel MoE layer whose
    exchanges route through the collective engine.

    The whole layer runs inside ``shard_map`` over ``axis``: tokens are
    batch-sharded (B divisible by the axis size), experts sharded across
    ranks (E divisible too — ``E == axis size`` is the single-expert-per-
    rank edge). Each rank routes and scatters its own token rows into a
    (B_loc, E, C, D) buffer, :func:`exchange_dispatch` moves every rank's
    tokens to their expert owners (``all_to_all_tiles @ moe.dispatch``),
    the local experts run, and :func:`exchange_combine` returns the outputs
    (``@ moe.combine``) with the combine *weighting* applied per landed
    capacity strip — so with ``nchunks`` > 1 (or ``"auto"``, resolved by the
    fill-cost model) strip i's weighting overlaps strip i+1's wire hops.

    Routing, capacity drops, and the combine scatter-add order are shared
    with :func:`apply_moe`, so the output matches the GSPMD path (and
    :func:`reference_moe` when nothing is dropped) for every registered
    ``all_to_all_tiles`` schedule and every chunk count.
    """
    n = mesh.shape[axis]
    E = cfg.num_experts
    if E % n:
        raise ValueError(
            f"num_experts={E} must be divisible by the {axis!r} axis size "
            f"{n} for the explicit expert-parallel exchange")
    engine = engine or CollectiveEngine.for_mesh(mesh, schedule="auto")

    def body(p, x):
        return _explicit_body(p, cfg, x, axis=axis, engine=engine,
                              schedule=schedule, nchunks=nchunks)

    def wrapped(p, x):
        fn = shard_map(body, mesh=mesh,
                       in_specs=(moe_param_specs(p, axis), P(axis)),
                       out_specs=P(axis), check_vma=False)
        return fn(p, x)

    return jax.jit(wrapped)


def apply_moe_explicit(p: dict, cfg: ModelConfig, x: jnp.ndarray, mesh, *,
                       axis: str = "x",
                       engine: Optional[CollectiveEngine] = None,
                       schedule: Optional[str] = None, nchunks=1) -> jnp.ndarray:
    """Convenience wrapper: build :func:`make_apply_moe_explicit` and apply
    it once. For repeated timed calls hold the factory's jitted function."""
    return make_apply_moe_explicit(cfg, mesh, axis=axis, engine=engine,
                                   schedule=schedule, nchunks=nchunks)(p, x)


def reference_moe(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: dense loop over experts, no capacity drop. For tests with
    capacity_factor large enough that apply_moe drops nothing."""
    B, S, D = x.shape
    probs, ids = route(p, cfg, x)
    out = jnp.zeros((B, S, D), jnp.float32)
    for e in range(cfg.num_experts):
        w_e = ((ids == e).astype(jnp.float32) * probs).sum(axis=-1)  # (B,S)
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][e].astype(x.dtype))
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"][e].astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["w_out"][e].astype(x.dtype))
        out = out + y.astype(jnp.float32) * w_e[..., None]
    out = out.astype(x.dtype)
    if cfg.shared_expert:
        out = out + _shared_expert(p["shared"], x, x.dtype)
    return out
