"""Sharding rules: logical names -> mesh PartitionSpecs.

The production mesh has axes ``('data', 'model')`` (single pod, 16x16) or
``('pod', 'data', 'model')`` (multi-pod, 2x16x16). Data parallelism runs over
``pod x data`` (the ``pod`` axis is the host-staged/DCN domain — exactly the
paper's PCIe+MPI network — while ``data`` and ``model`` ride the
circuit-switched ICI torus). Tensor/expert parallelism runs over ``model``.

Rules are *divisibility-aware*: a dimension is only sharded when the mesh
axis size divides it (GQA KV heads with kv < tp stay replicated, exactly
like Megatron's KV replication; SSM head-count dims that don't divide stay
replicated — they are tiny).

Every rule function takes the concrete mesh so specs can be turned into
``NamedSharding`` directly; ``make_shard_fn`` returns the activation-
constraint callback threaded through the model code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshRules:
    dp: Tuple[str, ...]          # data-parallel mesh axes, e.g. ('pod', 'data')
    tp: str = "model"            # tensor/expert-parallel axis
    sp: Optional[str] = None     # sequence-shard axis for long-context decode
    fsdp: bool = False           # additionally shard params over dp (ZeRO-3)

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]


def rules_for(mesh: Mesh, *, seq_shard: bool = False,
              fsdp: bool = False) -> MeshRules:
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    if not dp:
        dp = (names[0],)
    if "model" in names:
        tp = "model"
    else:  # no named model axis: TP over the last axis not already used for DP
        spare = [a for a in names if a not in dp]
        tp = spare[-1] if spare else None
    return MeshRules(dp=dp, tp=tp,
                     sp=("data" if seq_shard and "data" in names else None),
                     fsdp=fsdp)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(dim: int, axes, mesh: Mesh):
    """Return the axes if they evenly divide ``dim``, else None (replicate)."""
    if axes is None or dim % _axsize(mesh, axes):
        return None
    return axes if not (isinstance(axes, tuple) and len(axes) == 1) else axes[0]


# ---------------------------------------------------------------------------
# activation constraints (the ``shard`` callback threaded through the model)
# ---------------------------------------------------------------------------


def activation_spec(name: str, rules: MeshRules) -> P:
    dp = rules.dp_spec
    if name == "residual":      # (B, S, D)
        return P(dp, rules.sp, None)
    if name == "logits":        # (B, S, V) — vocab stays sharded until the loss
        return P(dp, rules.sp, rules.tp)
    if name == "ffn":           # (B, S, F)
        return P(dp, rules.sp, rules.tp)
    if name == "heads":         # (B, S, H, hd)
        return P(dp, rules.sp, rules.tp, None)
    if name == "moe_buf":       # (B, E, C, D) — expert-parallel dispatch
        return P(dp, rules.tp, None, None)
    if name == "moe_tokens":    # (B, T/S, D) — token-side views stay D-sharded
        return P(dp, None, rules.tp)
    return P()


def make_shard_fn(mesh: Mesh, rules: MeshRules) -> Callable:
    def shard(x: jnp.ndarray, name: str) -> jnp.ndarray:
        spec = activation_spec(name, rules)
        if all(s is None for s in spec):
            return x
        # drop constraint entries for dims the spec cannot legally shard
        fixed = []
        for d, s in zip(x.shape, spec):
            fixed.append(s if s is not None and d % _axsize(mesh, s) == 0 else None)
        # pad spec to rank
        fixed += [None] * (x.ndim - len(fixed))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    # model code inspects these to build shard_map-wrapped Pallas kernels
    shard.mesh = mesh
    shard.rules = rules
    return shard


# ---------------------------------------------------------------------------
# parameter specs (name-based rules over the param pytree)
# ---------------------------------------------------------------------------


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...], rules: MeshRules,
               mesh: Mesh) -> P:
    """Partition rule for one parameter leaf.

    ``path`` is the tuple of dict keys; block params carry a leading scan
    (super-block) dim that is never sharded.
    """
    tp, dp = rules.tp, rules.dp_spec
    name = path[-1]
    in_blocks = bool(path) and path[0] in ("blocks", "enc_blocks", "dec_blocks")
    parent = path[-2] if len(path) >= 2 else ""

    # strip the scan dim for rule matching; re-prepend at the end
    core = shape[1:] if in_blocks else shape
    lead = (None,) if in_blocks else ()

    def out(*axes):
        axes = tuple(axes) + (None,) * (len(core) - len(axes))
        return P(*(lead + axes))

    if name == "embed":                             # (V, D) vocab-parallel
        return out(_maybe(core[0], tp, mesh))
    if name == "wq":                                # (D, H, hd) heads sharded
        return out(None, _maybe(core[1], tp, mesh))
    if name in ("wk", "wv"):                        # (Din, KV, hd) if kv % tp
        return out(None, _maybe(core[1], tp, mesh))
    if name == "wo":                                # (H, hd, D)
        return out(_maybe(core[0], tp, mesh))
    if name == "bq":                                # (H, hd)
        return out(_maybe(core[0], tp, mesh))
    if name in ("bk", "bv"):                        # (KV, hd)
        return out(_maybe(core[0], tp, mesh))
    if parent == "moe":
        if name == "router":                        # (D, E)
            return out(None, _maybe(core[1], tp, mesh))
        if name in ("w_gate", "w_in", "w_out"):     # (E, D, F) / (E, F, D): EP
            return out(_maybe(core[0], tp, mesh))
    if name in ("w_gate", "w_in"):                  # (D, F) mlp/shared
        return out(None, _maybe(core[1], tp, mesh))
    if name == "w_out":                             # (F, D)
        return out(_maybe(core[0], tp, mesh))
    if parent == "ssm":
        if name in ("in_x", "in_z"):                # (D, d_in): channel-shard
            return out(None, _maybe(core[1], tp, mesh))
        if name in ("conv_x",):                     # (k, d_in)
            return out(None, _maybe(core[1], tp, mesh))
        if name in ("conv_x_b", "norm"):            # (d_in,)
            return out(_maybe(core[0], tp, mesh))
        if name == "out_proj":                      # (d_in, D)
            return out(_maybe(core[0], tp, mesh))
        # in_bc, in_dt, conv_bc, A_log, D, dt_bias: small, replicate
        return out()
    if name == "patch_proj":                        # (vision_dim, D)
        return out()
    # norms / scalars / anything unmatched: replicated
    return out()


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for e in path:
        if hasattr(e, "key"):
            keys.append(str(e.key))
        elif hasattr(e, "idx"):
            keys.append(str(e.idx))
        else:
            keys.append(str(e))
    return tuple(keys)


def param_specs(params, rules: MeshRules, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params`` (arrays or ShapeDtypeStruct).

    With ``rules.fsdp`` the name-based TP spec is extended by sharding the
    largest remaining unsharded dim over the dp axes (fully-sharded /
    ZeRO-3 weights; GSPMD all-gathers them per layer at use sites — the
    standard scheme for the 100B+ assigned archs whose weights cannot live
    TP-sharded-only on a 16 GB chip).
    """
    def leaf(path, x):
        keys = _path_keys(path)
        spec = _leaf_spec(keys, x.shape, rules, mesh)
        if rules.fsdp:
            in_blocks = bool(keys) and keys[0] in ("blocks", "enc_blocks",
                                                   "dec_blocks")
            spec = zero1_spec(spec, x.shape, rules, mesh, skip_first=in_blocks)
        return spec
    return jax.tree_util.tree_map_with_path(leaf, params)


def param_shardings(params, rules: MeshRules, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, rules, mesh))


# ---------------------------------------------------------------------------
# optimizer-state specs (ZeRO-1: moments additionally sharded over dp)
# ---------------------------------------------------------------------------


def zero1_spec(spec: P, shape: Tuple[int, ...], rules: MeshRules, mesh: Mesh,
               *, skip_first: bool = False) -> P:
    """Extend a param spec by sharding the largest unsharded dim over dp.

    Used for optimizer-state (ZeRO-1) sharding and — via ``rules.fsdp`` —
    for fully-sharded weights (ZeRO-3). ``skip_first`` protects the layer-
    scan stack dim of block params (sharding it would make every scan slice
    a cross-dp gather).
    """
    dp = rules.dp_spec
    dpn = _axsize(mesh, dp)
    if dpn == 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dp_axes = set(dp) if isinstance(dp, tuple) else {dp}
    for e in entries:  # already dp-sharded (e.g. fsdp params): no-op
        es = set(e) if isinstance(e, tuple) else {e}
        if es & dp_axes:
            return spec
    best, best_dim = -1, -1
    for i, (s, d) in enumerate(zip(entries, shape)):
        if skip_first and i == 0:
            continue
        if s is None and d % dpn == 0 and d > best_dim:
            best, best_dim = i, d
    if best < 0:
        return spec
    entries[best] = dp
    return P(*entries)


def opt_state_specs(params, rules: MeshRules, mesh: Mesh, *, zero1: bool = True):
    pspecs = param_specs(params, rules, mesh)
    if not zero1:
        return pspecs
    return jax.tree.map(
        lambda spec, p: zero1_spec(spec, p.shape, rules, mesh), pspecs, params)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch, rules: MeshRules, mesh: Mesh) -> Dict[str, P]:
    """Shard every batch input's leading (batch) dim over dp when divisible."""
    dp = rules.dp_spec
    out = {}
    for k, v in batch.items():
        ax = _maybe(v.shape[0], dp, mesh)
        out[k] = P(*((ax,) + (None,) * (v.ndim - 1)))
    return out


def cache_specs(cache, rules: MeshRules, mesh: Mesh, *, seq_shard: bool = False,
                kv_fallback: str = "hd"):
    """KV/SSM cache specs. Attention cache leaves are (n_super, B, Smax, KV,
    hd): batch-shard over dp; KV heads over tp when divisible, otherwise the
    *sequence* dim shards over tp (flash-decoding style — GSPMD inserts the
    partial-softmax combine). For B=1 long-context cells (``seq_shard``) the
    sequence dim additionally shards over 'data'.
    SSM state leaves (n_super, B, nh, hd, N): batch over dp, heads over tp."""
    dp, tp = rules.dp_spec, rules.tp

    def leaf(path, x):
        if x.ndim == 0:
            return P()
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        if name in ("k", "v") and x.ndim == 5:       # attn: (L, B, S, KV, hd)
            b_ax = _maybe(x.shape[1], dp, mesh)
            kv_ax = _maybe(x.shape[3], tp, mesh)
            hd_ax = None
            s_axes = []
            if kv_ax is None and tp is not None:
                # kv heads don't divide tp. 'hd' (default): shard head_dim —
                # a dynamic-pos cache update on a sharded seq dim lowers to
                # full-shard masked writes (measured: the dominant HBM term
                # of every decode cell, §Perf iteration B1); hd-sharding
                # keeps updates slice-sized and costs only a psum over the
                # contracted dim. 'seq' (the pre-B1 baseline, kept for
                # ablation) shards the sequence dim instead.
                if kv_fallback == "hd":
                    hd_ax = _maybe(x.shape[4], tp, mesh)
                else:
                    s_axes.append(tp)
            if seq_shard and b_ax is None and "data" in mesh.axis_names:
                s_axes.append("data")
            s_ax = _maybe(x.shape[2], tuple(s_axes), mesh) if s_axes else None
            return P(None, b_ax, s_ax, kv_ax, hd_ax)
        if name == "state" and x.ndim == 5:          # ssm: (L, B, nh, hd, N)
            return P(None, _maybe(x.shape[1], dp, mesh),
                     _maybe(x.shape[2], tp, mesh), None, None)
        if name.startswith("conv") and x.ndim == 4:  # ssm conv: (L, B, k, d_in)
            return P(None, _maybe(x.shape[1], dp, mesh), None,
                     _maybe(x.shape[3], tp, mesh))
        if name == "encoder_out":                    # enc-dec: (B, T, D)
            return P(_maybe(x.shape[0], dp, mesh), None, None)
        if x.ndim >= 2:                              # generic (L, B, ...) leaf
            return P(None, _maybe(x.shape[1], dp, mesh))
        return P()

    return jax.tree_util.tree_map_with_path(leaf, cache)


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
