"""Continuous-batching serving engine over the paged KV cache.

One :class:`ServeEngine` owns the device state (page pools + params), the
host :class:`~repro.serve.scheduler.Scheduler`, and the three jitted
programs of the serving loop:

* **prefill** — per admitted request, the dense prefill step on a batch of
  one, prompt padded to a power-of-two bucket (bounded recompiles; causal
  attention makes the pad positions inert), then a jitted
  :func:`~repro.models.kvcache.commit_prefill` scatters the prefix into
  the request's reserved pages;
* **decode** — ONE batched step over all ``max_slots`` slots per loop
  iteration, inactive slots riding along (their logits are discarded and
  their cache writes drop on the sentinel block-table rows). Either the
  GSPMD reference (:func:`repro.train.serve.make_paged_decode_step`) or
  the engine-routed explicit tensor-parallel program
  (:func:`repro.train.serve.make_decode_step_explicit`) whose per-token
  collectives carry the ``decode.*`` callsite tags;
* **sampling** — host-side (numpy) greedy/temperature, so the scheduler
  can branch on EOS without another device round-trip.

``step()`` = admit within the prefill-token budget -> prefill those ->
one decode batch -> sample/advance/recycle. ``run()`` drains the queue and
returns the full token streams.

Rank-death drain (ARCHITECTURE.md §8): when the fault schedule marks a
rank lost, every active request holding a KV page resident on it (pages
stripe round-robin: page ``p`` lives on rank ``p % nranks``) is drained —
preempted with its tokens intact and re-queued at the head, so
re-admission re-prefills ``tokens_so_far`` on surviving pages. The same
zero-loss contract page-pool preemption honors, triggered by rank death
instead of pool pressure.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.kvcache import (OutOfPagesError, PagedCacheConfig,
                                  PageAllocator, commit_prefill)
from repro.models.model import Model
from repro.serve.scheduler import Request, Scheduler
from repro.train.serve import (make_decode_step_explicit, make_paged_decode_step,
                               make_prefill_step)

SERVE_MODES = ("gspmd", "explicit")


def _bucket(n: int, lo: int = 8, hi: Optional[int] = None) -> int:
    """Next power-of-two >= n (floor ``lo``): the prefill shape ladder.

    ``hi`` clamps the ladder to the max context — the top bucket is exactly
    ``hi`` (not the next power of two past it), so prefill never pads
    beyond what the cache can hold. ``n > hi`` is the caller's bug."""
    if hi is not None and n > hi:
        raise ValueError(f"sequence of {n} tokens exceeds the {hi}-token "
                         "max context")
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


class ServeEngine:
    """Continuous-batching server for one model + page-pool geometry."""

    def __init__(self, model: Model, params, pcfg: PagedCacheConfig, *,
                 mode: str = "gspmd", mesh=None, axis: str = "x",
                 schedule: Optional[str] = None, nchunks=1,
                 prefill_token_budget: int = 512,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0, dtype=jnp.float32,
                 engine=None, preempt: bool = False,
                 admission_retries: int = 256, fault_schedule=None):
        if mode not in SERVE_MODES:
            raise ValueError(f"unknown serve mode {mode!r}; modes: "
                             f"{SERVE_MODES}")
        if mode == "explicit":
            if mesh is None:
                raise ValueError("explicit serve mode requires a mesh")
            n = mesh.shape[axis]
            if pcfg.max_slots % n:
                raise ValueError(
                    f"max_slots={pcfg.max_slots} must be divisible by the "
                    f"{axis!r} axis size {n} for the explicit decode batch")
        self.model = model
        self.params = params
        self.pcfg = pcfg
        self.mode = mode
        self.eos_id = eos_id
        self.temperature = temperature
        self._rng = np.random.default_rng(seed)
        self._next_rid = 0
        if admission_retries <= 0:
            raise ValueError("admission_retries must be positive")
        self.admission_retries = admission_retries
        self._fault_schedule = fault_schedule
        self._steps = 0
        self._nranks = int(mesh.shape[axis]) if mesh is not None else 1
        self._drained_ranks: set = set()

        self.alloc = PageAllocator(pcfg)
        self.scheduler = Scheduler(self.alloc,
                                   prefill_token_budget=prefill_token_budget,
                                   preempt=preempt)
        self.pages = T.init_paged_cache(model.cfg, pcfg, dtype)
        self._dtype = dtype
        self._last_tok = np.zeros((pcfg.max_slots,), np.int32)

        self._prefill = make_prefill_step(model, None)
        if mode == "explicit":
            self._decode = make_decode_step_explicit(
                model, mesh, axis=axis, engine=engine, schedule=schedule,
                nchunks=nchunks)
        else:
            self._decode = make_paged_decode_step(model, mesh)
        ps = pcfg.page_size
        self._commit = jax.jit(
            lambda pages, dense, row, length: commit_prefill(
                pages, dense, row, length, page_size=ps),
            donate_argnums=(0,))

    # -- request API ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16, *,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request; returns its id (key into ``run()``'s result).

        Rejects impossible requests *here*, not mid-run: a worst-case page
        reservation larger than the whole pool raises
        :class:`OutOfPagesError` (it could never be admitted, even with
        every slot idle), and prompt+max_new past ``max_seq`` raises
        ``ValueError``. ``deadline_s`` is a wall-clock budget from now;
        an expired request finishes with reason ``"timeout"``."""
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = int(prompt.shape[0]) + max_new_tokens
        need = -(-total // self.pcfg.page_size)
        if need > self.pcfg.num_pages:
            raise OutOfPagesError(
                f"request {rid} ({total} tokens) needs {need} pages but the "
                f"pool holds {self.pcfg.num_pages}: it can never be admitted")
        self.scheduler.submit(Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
            deadline_s=deadline_s))
        return rid

    # -- sampling (host) --------------------------------------------------

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        z -= z.max()
        p = np.exp(z)
        return int(self._rng.choice(p.shape[0], p=p / p.sum()))

    def _advance(self, req: Request, tok: int) -> None:
        """Record one generated token; finish on EOS / max-new."""
        req.generated.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            self.scheduler.finish(req, "eos")
        elif len(req.generated) >= req.max_new_tokens:
            self.scheduler.finish(req, "max_new")
        else:
            self._last_tok[req.slot] = tok

    # -- serving loop -----------------------------------------------------

    def _prefill_one(self, req: Request) -> None:
        # prefill_len/tokens_so_far, not the bare prompt: a preempted
        # request re-enters here with its generated tokens intact, and the
        # re-prefill resumes the stream exactly where eviction cut it
        S0 = req.prefill_len
        Sp = _bucket(S0, hi=self.pcfg.max_seq)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :S0] = req.tokens_so_far
        cache = self.model.init_cache(1, Sp, self._dtype)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      cache)
        row = jnp.asarray(self.alloc.block_table[req.slot])
        self.pages = {"layers": self._commit(
            self.pages["layers"], cache["layers"], row, S0)}
        self.alloc.commit(req.slot, S0)
        self._advance(req, self._sample(np.asarray(logits[0, S0 - 1])))

    def _drain_lost_ranks(self) -> int:
        """Re-queue every active request with a KV page on a newly lost
        rank (page ``p`` stripes onto rank ``p % nranks``): preempt it
        with ``tokens_so_far`` intact and put it at the queue head, so
        re-admission re-prefills onto surviving pages and the greedy
        stream resumes token-identical. Returns the drain count."""
        inj = self._fault_schedule.injector
        new = inj.lost_ranks - self._drained_ranks
        if not new:
            return 0
        self._drained_ranks |= new
        lost = {r % self._nranks for r in new}
        victims = []
        for slot, req in sorted(self.scheduler.active.items()):
            row = self.alloc.block_table[slot]
            pages = row[row < self.pcfg.num_pages]
            if any(int(p) % self._nranks in lost for p in pages):
                victims.append(req)
        for req in victims:
            self.scheduler.preempt_request(req)
        for req in reversed(victims):
            self.scheduler.waiting.appendleft(req)
        return len(victims)

    def step(self) -> Dict:
        """One loop iteration: expire deadlines, drain requests whose KV
        pages died with a lost rank, admit + prefill within budget
        (preempting if armed), then one batched decode over every active
        slot. Returns step stats."""
        drained = 0
        if self._fault_schedule is not None:
            self._fault_schedule.apply(self._steps)
            drained = self._drain_lost_ranks()
        self._steps += 1
        expired = self.scheduler.expire(time.monotonic())
        pre_preempted = self.scheduler.preempted_total
        admitted = self.scheduler.admit()
        preempted = self.scheduler.preempted_total - pre_preempted

        # backpressure: a head past its retry budget is rejected so the
        # queue keeps moving (never-fitting requests were already refused
        # at submit(); this is for pools pinned by long-lived actives)
        rejected = 0
        while (self.scheduler.waiting
               and self.scheduler.waiting[0].wait_steps
               > self.admission_retries):
            head = self.scheduler.waiting.popleft()
            self.scheduler.finish(head, "rejected")
            rejected += 1

        if not admitted and not self.scheduler.active:
            if self.scheduler.waiting:
                head = self.scheduler.waiting[0]
                raise OutOfPagesError(
                    f"request {head.rid} ({head.total_budget} tokens) can "
                    f"never be admitted: pool is idle yet too small")
            return {"prefills": 0, "prefill_tokens": 0, "decode_tokens": 0,
                    "active": 0, "decode_s": 0.0, "preempted": preempted,
                    "timeouts": len(expired), "rejected": rejected,
                    "drained": drained}
        t0 = time.perf_counter()
        for req in admitted:
            self._prefill_one(req)
        prefill_s = time.perf_counter() - t0

        decode_tokens = 0
        decode_s = 0.0
        if self.scheduler.active:
            t0 = time.perf_counter()
            if self._fault_schedule is not None:
                # injected host delay lands inside the measured decode
                # window — tok/s during the fault degrades accordingly
                self._fault_schedule.injector.sleep("serve.step")
            bt, lengths = self.alloc.device_tables()
            logits, self.pages = self._decode(
                self.params, jnp.asarray(self._last_tok[:, None]),
                self.pages, bt, lengths)
            rows = np.asarray(logits[:, 0])  # sync: (max_slots, V)
            decode_s = time.perf_counter() - t0
            for slot, req in list(self.scheduler.active.items()):
                self.alloc.append(slot)
                self._advance(req, self._sample(rows[slot]))
                decode_tokens += 1
        return {"prefills": len(admitted),
                "prefill_tokens": sum(r.prefill_len for r in admitted),
                "decode_tokens": decode_tokens,
                "active": len(self.scheduler.active),
                "prefill_s": prefill_s, "decode_s": decode_s,
                "preempted": preempted, "timeouts": len(expired),
                "rejected": rejected, "drained": drained}

    def run(self, requests=None, *, max_new_tokens: int = 16,
            collect_stats: bool = False):
        """Drain the queue (optionally submitting ``requests`` first).

        Returns ``{rid: np.ndarray prompt+generated}`` — plus the per-step
        stats list when ``collect_stats``.
        """
        done: List[Request] = []
        for prompt in (requests or []):
            self.submit(prompt, max_new_tokens)
        tracked: Dict[int, Request] = {}
        for req in self.scheduler.waiting:
            tracked[req.rid] = req
        stats = []
        while self.scheduler.has_work:
            stats.append(self.step())
        for req in tracked.values():
            if not req.done:
                raise RuntimeError(
                    f"request {req.rid} never finished: scheduler drained "
                    f"with slot={req.slot}, {len(req.generated)}/"
                    f"{req.max_new_tokens} tokens generated")
            done.append(req)
        out = {req.rid: np.concatenate([req.prompt,
                                        np.asarray(req.generated, np.int32)])
               for req in done}
        return (out, stats) if collect_stats else out
