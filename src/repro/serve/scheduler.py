"""Continuous-batching scheduler: request queue + slot lifecycle.

Pure host-side bookkeeping, no jax: the scheduler decides *which* requests
enter the batch (admission against the page pool and a per-step
prefill-token budget) and *when* a slot is recycled (EOS / max-new); the
device work lives in :class:`repro.serve.engine.ServeEngine`.

Admission reserves the worst-case page count (prompt + max-new tokens) via
:class:`repro.models.kvcache.PageAllocator`, so an admitted request can
always decode to completion — out-of-pages is an admission-time condition,
never a mid-flight failure. The prefill-token budget bounds how much
prefill compute any single step may inject between decode batches, which
caps the per-token latency spike existing streams see when a long prompt
arrives (the classic continuous-batching interleave knob).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.models.kvcache import PageAllocator


@dataclass
class Request:
    """One generation request and its accumulated output."""
    rid: int
    prompt: np.ndarray            # (S0,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "max_new"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_budget(self) -> int:
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """FIFO admission over a :class:`PageAllocator` with a prefill budget.

    ``admit(budget)`` pops waiting requests while (a) the allocator can
    reserve their worst-case pages + a slot and (b) their prompt lengths
    fit the remaining per-step prefill-token budget; each admitted request
    gets its slot assigned. FIFO head-of-line blocking is deliberate — it
    keeps admission order deterministic and starvation-free.
    """

    def __init__(self, alloc: PageAllocator,
                 prefill_token_budget: int = 512):
        if prefill_token_budget <= 0:
            raise ValueError("prefill_token_budget must be positive")
        self.alloc = alloc
        self.prefill_token_budget = prefill_token_budget
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request

    # -- queue ------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.total_budget > self.alloc.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={req.total_budget} "
                f"exceeds max_seq={self.alloc.cfg.max_seq}")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active)

    # -- admission --------------------------------------------------------

    def admit(self) -> List[Request]:
        """Admit FIFO-head requests within this step's prefill budget."""
        admitted: List[Request] = []
        budget = self.prefill_token_budget
        while self.waiting:
            req = self.waiting[0]
            if req.prompt_len > budget and admitted:
                break  # budget spent this step; next step continues
            if not self.alloc.can_allocate(req.total_budget):
                break  # pool full: wait for a release
            self.waiting.popleft()
            req.slot = self.alloc.allocate(req.total_budget)
            self.active[req.slot] = req
            admitted.append(req)
            budget -= req.prompt_len
            if budget <= 0:
                break
        return admitted

    # -- lifecycle --------------------------------------------------------

    def finish(self, req: Request, reason: str) -> None:
        """Mark done and recycle the slot + pages."""
        req.done = True
        req.finish_reason = reason
        if req.slot is not None:
            self.alloc.release(req.slot)
            del self.active[req.slot]
            req.slot = None
